"""Sparse Tucker decomposition (HOOI) on the programmable memory controller.

The second real workload of the substrate: the paper designs the Tensor
Remapper / per-mode layouts / PMS to be *programmable*, i.e. reusable across
tensor-decomposition kernels, and sparse Tucker exercises exactly the same
irregular-access problem through the TTM chain (Jiang et al., "Sparse Tucker
Tensor Decomposition on a Hybrid FPGA-CPU Platform").  HOOI (higher-order
orthogonal iteration):

    repeat:
      for each mode n:
        Y_(n) = X_(n) (kron of U^(m), m != n)     # sparse TTMc — the kernel
        U^(n) = top-R_n left singular vectors of Y_(n)
      G = Y_(N-1) x_{N-1} U^(N-1)^T               # core, free from the last Y
      fit = 1 - sqrt(||X||^2 - ||G||^2) / ||X||   # factors orthonormal

The truncated SVD runs through the *unfolding Gram*: G_Y = Y^T Y is only
(P x P) with P = prod of the other core ranks, so the eigh never touches an
I_n-sized matrix; U^(n) = Y V_top diag(1/sigma_top) recovers the left
singular vectors (classic tall-matrix economy SVD).

Two methods, mirroring cp_als:
  * 'pallas'    — the planned TTM-chain kernel (kernels/ttm_pallas.py) on a
                  `PlannedTucker` workspace: one PMS-tunable BlockPlan +
                  device-resident layout per output mode, built once and
                  reused across every HOOI iteration (plan amortization,
                  exactly the PlannedCPALS posture).  jit_sweep=True runs
                  each iteration as one compiled sweep with rank-padded,
                  device-resident factors; jit_sweep=False keeps the eager
                  per-mode dispatch loop as the parity baseline.
  * 'reference' — the pure-jnp TTMc oracle (kernels/ref.py), also available
                  as a jitted whole-iteration sweep.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.coo import SparseTensor
from ..core.loop import (
    check_drive_extras,
    check_planned_method,
    check_workspace,
    finish_iter,
    require_sharded_sweep,
)
from ..core.memctrl import MemoryControllerConfig, TPUSpec
from ..kernels.ops import PlannedTTMC, make_planned_ttmc, planned_layout_bytes
from ..kernels.mttkrp_pallas import rank_padded
from ..kernels.ref import ttmc_ref
from ..kernels.workspace import PlannedWorkspace, plan_stream

__all__ = [
    "TuckerState",
    "tucker_hooi",
    "PlannedTucker",
    "make_planned_tucker",
    "init_tucker_factors",
    "core_fit_value",
]


@dataclasses.dataclass
class TuckerState:
    factors: list[jax.Array]  # one (I_m, R_m) per mode, orthonormal columns
    core: jax.Array  # (R_0, ..., R_{N-1}) in natural mode order
    fit_history: list[float]

    @property
    def core_ranks(self) -> tuple[int, ...]:
        return tuple(int(s) for s in self.core.shape)


def _validated_core_ranks(st: SparseTensor, core_ranks: Sequence[int]) -> tuple[int, ...]:
    cr = tuple(int(r) for r in core_ranks)
    if len(cr) != st.nmodes:
        raise ValueError(
            f"core_ranks has {len(cr)} entries for a {st.nmodes}-mode tensor"
        )
    for m, (r, s) in enumerate(zip(cr, st.shape)):
        if not 1 <= r <= s:
            raise ValueError(
                f"core rank {r} for mode {m} out of range [1, {s}] (mode length)"
            )
        others = math.prod(cr[k] for k in range(len(cr)) if k != m)
        if r > others:
            raise ValueError(
                f"core rank {r} for mode {m} exceeds the product of the other "
                f"ranks ({others}): the mode-{m} unfolding of the core cannot "
                f"have full row rank"
            )
    return cr


def init_tucker_factors(
    key: jax.Array, shape: Sequence[int], core_ranks: Sequence[int], dtype=jnp.float32
) -> list[jax.Array]:
    """Random *orthonormal* factor matrices (reduced QR of a Gaussian), one
    (I_m, R_m) per mode — HOOI's fit formula assumes orthonormal columns from
    the first iteration."""
    keys = jax.random.split(key, len(shape))
    facs = []
    for k, s, r in zip(keys, shape, core_ranks):
        q, _ = jnp.linalg.qr(jax.random.normal(k, (int(s), int(r)), dtype))
        facs.append(q)
    return facs


def _factor_from_unfolding(y: jax.Array, r: int) -> jax.Array:
    """Top-r left singular vectors of the unfolding y (I_n, P) via eigh of
    the (P, P) Gram — the truncated SVD never materializes an I_n x I_n
    matrix.  Columns with (relatively) vanishing singular values are zeroed
    rather than divided by ~0; HOOI only uses the spanned subspace."""
    g = y.T @ y
    w, v = jnp.linalg.eigh(g)  # ascending eigenvalues
    top_v = v[:, ::-1][:, :r]
    sigma = jnp.sqrt(jnp.maximum(w[::-1][:r], 0.0))
    thresh = jnp.maximum(sigma[0], 1e-30) * 1e-7
    inv = jnp.where(sigma > thresh, 1.0 / jnp.maximum(sigma, thresh), 0.0)
    return y @ (top_v * inv[None, :])


def _core_from_unfolding(
    y: jax.Array, u: jax.Array, mode: int, core_ranks: tuple[int, ...]
) -> jax.Array:
    """Fold U^(mode)^T Y_(mode) back into the (R_0, ..., R_{N-1}) core in
    natural mode order (Y's columns are row-major over ascending input
    mode)."""
    nmodes = len(core_ranks)
    in_modes = tuple(m for m in range(nmodes) if m != mode)
    mat = u.T @ y  # (R_mode, P)
    core = mat.reshape((core_ranks[mode],) + tuple(core_ranks[m] for m in in_modes))
    axes = (mode,) + in_modes  # axes[k] = the tensor mode of core axis k
    perm = tuple(axes.index(m) for m in range(nmodes))
    return jnp.transpose(core, perm)


def core_fit_value(core: jax.Array, norm_x_sq: jax.Array) -> jax.Array:
    """fit = 1 - ||X - X_hat|| / ||X||.  With orthonormal factors,
    ||X - X_hat||^2 = ||X||^2 - ||G||^2 — no pass over the non-zeros."""
    resid_sq = jnp.maximum(norm_x_sq - jnp.sum(core * core), 0.0)
    return 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)


@partial(jax.jit, static_argnames=("shape", "core_ranks"))
def _sweep_reference(factors, idx, val, norm_x_sq, *, shape, core_ranks):
    """One full jitted HOOI iteration on the pure-jnp TTMc oracle: every
    mode's TTMc -> Gram eigh -> factor update, plus core + fit, in a single
    compiled function."""
    factors = list(factors)
    y = None
    for m in range(len(shape)):
        y = ttmc_ref(idx, val, factors, m, shape[m])
        factors[m] = _factor_from_unfolding(y, core_ranks[m])
    last = len(shape) - 1
    core = _core_from_unfolding(y, factors[last], last, core_ranks)
    return tuple(factors), core, core_fit_value(core, norm_x_sq)


@dataclasses.dataclass
class PlannedTucker(PlannedWorkspace):
    """Per-mode plan cache driving the whole HOOI loop on the memory
    controller — the Tucker mirror of `PlannedCPALS`.

    One `PlannedTTMC` per output mode — each holds its own remapped,
    device-resident copy of the non-zero stream — constructed once and reused
    for every HOOI iteration.  The steady-state iteration is `sweep`: one
    jitted function running a full HOOI iteration (every mode's TTMc -> Gram
    eigh -> factor update, plus the core fold and fit).  Padding/residency
    (each mode to its own rank_padded(R_m)) and the host drive loop come
    from `PlannedWorkspace` — this class supplies only the HOOI sweep body.
    """

    ops: dict[int, PlannedTTMC]
    shape: tuple[int, ...]
    core_ranks: tuple[int, ...]

    @property
    def lane_ranks(self) -> tuple[int, ...]:
        return self.core_ranks

    def plan_for(self, mode: int):
        return self.ops[mode].plan

    def _geoms(self) -> dict:
        return {m: op.plan for m, op in self.ops.items()}

    def _layout_bytes(self) -> int:
        return planned_layout_bytes(self.ops)

    def _build_sweep(self) -> Callable:
        shape, core_ranks, nmodes = self.shape, self.core_ranks, self.nmodes
        rps, prows = self.rank_pads, self.padded_rows
        ops = self.ops

        def sweep(facs, norm_x_sq):
            facs = list(facs)
            y = None
            for m in range(nmodes):
                op, p = ops[m], ops[m].plan
                in_facs = tuple(
                    facs[im][: p.in_rows[n]] for n, im in enumerate(p.in_modes)
                )
                out = op.call_padded(in_facs)
                y = out[: shape[m], : op.out_cols]
                u = _factor_from_unfolding(y, core_ranks[m])
                # Re-pad in place of the old padded factor (padding rows and
                # lanes stay exactly zero, so the next mode's kernel gathers
                # zeros for padding elements).
                facs[m] = (
                    jnp.zeros((prows[m], rps[m]), u.dtype)
                    .at[: shape[m], : core_ranks[m]]
                    .set(u)
                )
            last = nmodes - 1
            u_last = facs[last][: shape[last], : core_ranks[last]]
            core = _core_from_unfolding(y, u_last, last, core_ranks)
            return tuple(facs), core, core_fit_value(core, norm_x_sq)

        return jax.jit(sweep)

    def sweep(self, facs, norm_x_sq):
        """One jitted HOOI iteration in padded space.  Returns
        (new padded factors, core, fit scalar on device)."""
        return super().sweep(facs, norm_x_sq)

    def vmem_model_bytes(self) -> int:
        return max(
            op.cfg.vmem_bytes_ttmc(
                rank_padded(math.prod(op.in_ranks)),
                tuple(rank_padded(r) for r in op.in_ranks),
            )
            for op in self.ops.values()
        )

    def pms_estimates(self, spec: TPUSpec = TPUSpec()) -> dict:
        """Per-mode exact PMS estimates from the built plans (the
        `obs.calibrate` hook — see PlannedCPALS.pms_estimates)."""
        from ..core.pms import predict_ttmc

        return {
            m: predict_ttmc(op.plan, self.core_ranks, op.cfg, spec)
            for m, op in self.ops.items()
        }

    def _build_fallback_sweep(self) -> Callable:
        """Reference degradation target of the "fallback" guard policy: the
        jitted `_sweep_reference` body on the SAME padded factors.  The HOOI
        sweep takes no stream arguments (the remapped copies live in the
        plans), so the COO stream is reconstructed from a host-side plan —
        padding slots carry value 0 and contribute nothing."""
        idx, val = plan_stream(self.ops[0].plan)
        idx, val = jnp.asarray(idx), jnp.asarray(val)
        shape, core_ranks, nmodes = self.shape, self.core_ranks, self.nmodes
        rps, prows = self.rank_pads, self.padded_rows

        def sweep(facs, norm_x_sq):
            facs = list(facs)
            y = None
            for m in range(nmodes):
                true = [f[:s, :r] for f, s, r in zip(facs, shape, core_ranks)]
                y = ttmc_ref(idx, val, true, m, shape[m])
                u = _factor_from_unfolding(y, core_ranks[m])
                facs[m] = (
                    jnp.zeros((prows[m], rps[m]), u.dtype)
                    .at[: shape[m], : core_ranks[m]]
                    .set(u)
                )
            last = nmodes - 1
            u_last = facs[last][: shape[last], : core_ranks[last]]
            core = _core_from_unfolding(y, u_last, last, core_ranks)
            return tuple(facs), core, core_fit_value(core, norm_x_sq)

        jitted = jax.jit(sweep)
        return lambda facs, *args, it: jitted(facs, *args)


def make_planned_tucker(
    st: SparseTensor,
    core_ranks: Sequence[int],
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> PlannedTucker:
    """Build the full HOOI workspace: one tuned TTMc plan per output mode.

    With auto_tune=True each mode gets its own PMS-selected controller
    configuration scored for the TTMc kernel (core-tensor tile in the VMEM
    model); otherwise `cfg` (or the default) is shared by every mode."""
    cr = _validated_core_ranks(st, core_ranks)
    ops = {
        m: make_planned_ttmc(
            st, m, cr, cfg=cfg, auto_tune=auto_tune, spec=spec, interpret=interpret
        )
        for m in range(st.nmodes)
    }
    return PlannedTucker(ops=ops, shape=st.shape, core_ranks=cr)


def tucker_hooi(
    st: SparseTensor,
    core_ranks: Sequence[int],
    *,
    iters: int = 10,
    method: str = "pallas",
    seed: int = 0,
    tol: float | None = None,
    planned: "PlannedTucker | None" = None,
    interpret: bool = True,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = "default",
    cfg: MemoryControllerConfig | None = None,
    jit_sweep: bool = True,
    devices: int | None = None,
    dist=None,
    verbose: bool = False,
    guards=None,
    checkpoint_every: int | None = None,
    checkpoint_path=None,
) -> TuckerState:
    """Run sparse Tucker HOOI.

    method: 'pallas' — the planned TTM-chain memory-controller kernel: a
            `PlannedTucker` workspace is built once (one remapped,
            device-resident BlockPlan per output mode) and reused for every
            iteration; 'pallas_sharded' — the distributed planned path
            (repro.dist.planned): per-mode balanced stream partitions,
            shard-local layouts, one jitted shard_map sweep per iteration
            with a single psum of the partial TTMc unfolding per mode;
            'reference' — the pure-jnp TTMc oracle.
    planned / interpret / auto_tune / cfg: pallas-path knobs — pass a
            prebuilt `PlannedTucker` (or `ShardedPlannedTucker`) to reuse
            plans across calls, or let auto_tune run the TTMc-aware PMS per
            mode (worst-shard makespan for the sharded path).
            auto_tune="cached" persists/reuses the winners on disk; spec may
            be a TPUSpec, "default", or "measured" (repro.tune).
    jit_sweep: run each iteration as one jitted sweep (factors stay
            device-resident, rank-padded for the pallas path); False keeps
            the eager per-mode dispatch loop as the parity baseline
            ('pallas_sharded' is sweep-only and rejects jit_sweep=False).
    devices / dist: 'pallas_sharded' placement — a device count for the
            default 1-D `shard` mesh, or an explicit ShardingPlan.
    guards / checkpoint_every / checkpoint_path: the resilience surface of
            the planned drive loop (repro.resilience).  Planned jitted
            paths only.
    """
    cr = _validated_core_ranks(st, core_ranks)
    nmodes = st.nmodes
    key = jax.random.PRNGKey(seed)
    factors = init_tucker_factors(key, st.shape, cr)
    norm_x_sq = jnp.asarray(float(np.sum(st.values.astype(np.float64) ** 2)), jnp.float32)
    fits: list[float] = []

    check_planned_method(method, planned, devices, dist)
    check_drive_extras(method, jit_sweep, guards, checkpoint_every,
                       checkpoint_path)
    if method == "pallas_sharded":
        require_sharded_sweep(jit_sweep)
        from ..kernels.ops import ShardedPlannedTucker, make_sharded_planned_tucker

        if planned is None:
            planned = make_sharded_planned_tucker(
                st, cr, dist=dist, devices=devices, cfg=cfg,
                auto_tune=auto_tune, spec=spec, interpret=interpret,
            )
        else:
            check_workspace(
                planned, ShardedPlannedTucker, method,
                {"shape": st.shape, "core_ranks": cr}, devices=devices,
            )
        factors, core, fits = planned.drive(
            factors, (norm_x_sq,), iters=iters, tol=tol, verbose=verbose,
            label="tucker_hooi", guards=guards,
            checkpoint_every=checkpoint_every, checkpoint_path=checkpoint_path,
        )
        return TuckerState(factors=factors, core=core, fit_history=fits)
    if method == "pallas":
        if planned is None:
            planned = make_planned_tucker(
                st, cr, cfg=cfg, auto_tune=auto_tune, spec=spec,
                interpret=interpret,
            )
        else:
            check_workspace(
                planned, PlannedTucker, method,
                {"shape": st.shape, "core_ranks": cr},
            )
        if jit_sweep:
            # Fast path: factors padded once, updated in padded space by one
            # jitted sweep per iteration; sliced back only for the state.
            factors, core, fits = planned.drive(
                factors, (norm_x_sq,), iters=iters, tol=tol, verbose=verbose,
                label="tucker_hooi", guards=guards,
                checkpoint_every=checkpoint_every,
                checkpoint_path=checkpoint_path,
            )
            return TuckerState(factors=factors, core=core, fit_history=fits)
    elif method != "reference":
        raise ValueError(f"unknown method {method!r}: expected 'pallas' or 'reference'")

    if method == "reference":
        # Only the reference oracle walks the raw COO stream; the pallas
        # paths consume the per-mode device-resident plan layouts instead,
        # so the transfer would duplicate HBM the plans already hold.
        idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)

    if method == "reference" and jit_sweep:
        factors_t = tuple(factors)
        core = None
        for it in range(iters):
            factors_t, core, fit = _sweep_reference(
                factors_t, idx, val, norm_x_sq, shape=st.shape, core_ranks=cr
            )
            if finish_iter(fits, fit, it, tol, verbose, "tucker_hooi"):
                break
        return TuckerState(factors=list(factors_t), core=core, fit_history=fits)

    # Eager per-mode dispatch loop: jit_sweep=False (both methods).
    core = None
    for it in range(iters):
        y = None
        for m in range(nmodes):
            if method == "pallas":
                y = planned.ops[m].output(factors, st.shape[m])
            else:
                y = ttmc_ref(idx, val, factors, m, st.shape[m])
            factors[m] = _factor_from_unfolding(y, cr[m])
        last = nmodes - 1
        core = _core_from_unfolding(y, factors[last], last, cr)
        if finish_iter(fits, core_fit_value(core, norm_x_sq), it, tol, verbose, "tucker_hooi"):
            break
    return TuckerState(factors=factors, core=core, fit_history=fits)
