"""Sparse Tucker decomposition (HOOI) on the programmable memory controller:
the TTM-chain kernel family reuses the MTTKRP BlockPlan substrate (see
kernels/ttm_pallas.py); `tucker_auto` is the one-shot TTMc dispatcher sharing
the kind-keyed plan cache in kernels/ops.py."""
from ..kernels.ops import PlannedTTMC, make_planned_ttmc, tucker_auto
from .hooi import (
    PlannedTucker,
    TuckerState,
    core_fit_value,
    init_tucker_factors,
    make_planned_tucker,
    tucker_hooi,
)

__all__ = [
    "TuckerState",
    "tucker_hooi",
    "PlannedTucker",
    "make_planned_tucker",
    "init_tucker_factors",
    "core_fit_value",
    "PlannedTTMC",
    "make_planned_ttmc",
    "tucker_auto",
]
