"""Batched serving: prefill + decode step builders and a host-side
generation loop.

`cache_specs` mirrors models.transformer.init_caches as ShapeDtypeStructs (the
decode dry-run's cache stand-in — a 500k-token cache is never allocated on
the CPU host), with the matching PartitionSpecs from the ShardingPlan.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..dist.sharding import NOPLAN, ShardingPlan
from ..models import transformer as T
from ..models.layers import dtype_of

__all__ = ["cache_specs", "cache_pspecs", "make_prefill_step", "make_decode_step", "generate"]


def cache_specs(cfg, batch: int, cache_len: int) -> tuple:
    """Abstract (ShapeDtypeStruct) version of init_caches — no allocation."""
    return jax.eval_shape(lambda: T.init_caches(cfg, batch, cache_len))


def cache_pspecs(cfg, plan: ShardingPlan) -> tuple:
    """PartitionSpec tree matching init_caches: KV (B,S,KVH,hd), ssm state
    (B,H,P,N), conv (B,K-1,C) — each with a leading n_reps (unsharded) dim."""
    from jax.sharding import PartitionSpec as P

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        base: P
        if name in ("k", "v", "xk", "xv"):
            base = plan.kv_cache(cfg.n_kv_heads)
        elif name == "h":
            base = plan.ssm_state()
        elif name == "conv":
            base = plan.conv_state()
        else:
            base = P()
        return P(None, *base)  # leading n_reps dim

    abstract = cache_specs(cfg, 1, 8)
    return jax.tree_util.tree_map_with_path(spec_for, abstract)


def make_prefill_step(cfg, plan: ShardingPlan = NOPLAN, *, cache_len: int | None = None, attn_chunk: int = 2048) -> Callable:
    def prefill_step(params, batch):
        return T.prefill(params, batch, cfg, cache_len=cache_len, plan=plan, attn_chunk=attn_chunk)

    return prefill_step


def make_decode_step(cfg, plan: ShardingPlan = NOPLAN, *, sample: str = "greedy") -> Callable:
    """decode_step(params, tokens (B,1), pos (B,), caches, batch) ->
    (next_tokens (B,1), logits, caches)."""

    def decode(params, tokens, pos, caches, batch):
        logits, caches = T.decode_step(params, tokens, pos, caches, batch, cfg, plan)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, caches

    return decode


def generate(
    params,
    batch: dict,
    cfg,
    *,
    max_new_tokens: int = 16,
    cache_margin: int = 0,
    plan: ShardingPlan = NOPLAN,
    attn_chunk: int = 2048,
) -> jax.Array:
    """Greedy generation driver (host loop over jitted steps)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = S + max_new_tokens + cache_margin
    prefill = jax.jit(make_prefill_step(cfg, plan, cache_len=cache_len, attn_chunk=attn_chunk))
    decode = jax.jit(make_decode_step(cfg, plan))
    logits, caches = prefill(params, batch)
    cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out = [cur]
    pos = jnp.full((B,), S, jnp.int32)
    for t in range(max_new_tokens - 1):
        cur, _, caches = decode(params, cur, pos, caches, batch)
        out.append(cur)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)
