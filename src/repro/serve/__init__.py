"""Serving substrate: cache specs + batched prefill/decode step builders."""
from .engine import make_prefill_step, make_decode_step, cache_specs, generate
