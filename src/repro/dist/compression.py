"""Gradient compression: int8 quantization with error feedback.

Applied at the microbatch-accumulation boundary (train_step.py): the
accumulated gradient tree is quantized to int8 + one fp32 scale per leaf,
dequantized, and the residual is carried in the optimizer-state dict under
``"ef"`` so the quantization bias averages out over steps (1-bit-Adam-style
error feedback; Seide et al. 2014).  This is the paper's "tensor-element
width" knob (Sec. 5.2, Remapper) applied to the gradient stream: a DP
all-reduce of int8 grads moves 4x fewer bytes than fp32.

The functions are pure and jit-safe; ``compress_decompress`` threads its
residual through whatever state dict the caller owns (adamw_update preserves
unknown keys, so the residual survives the optimizer update).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_decompress", "init_error_feedback"]

_EF_KEY = "ef"


def init_error_feedback(opt_state: dict, params) -> dict:
    """Pre-seed the zeroed residual tree so the opt-state structure is stable
    from step 0 (jit retrace- and checkpoint/restore-safe: the restore
    shardings tree must match the saved tree leaf-for-leaf)."""
    return {
        **opt_state,
        _EF_KEY: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q int8, scale f32)
    with q = round(x / scale), scale = max|x| / 127 (round-to-nearest, so the
    reconstruction error is bounded by scale/2 per element)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)) / 127.0, jnp.float32(1e-30))
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, opt_state: dict) -> tuple[dict, dict]:
    """Quantize->dequantize the gradient tree with error feedback.

    ``opt_state`` is any state dict the caller owns; the fp32 residual tree is
    kept under ``"ef"`` (created zeroed on first use).  Returns the
    dequantized gradients (in the input dtype) and the updated state dict —
    the round-trip models the int8 DP all-reduce wire format while keeping
    the unquantized residual on-device."""
    err = opt_state.get(_EF_KEY)
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = quantize_int8(x)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), x - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree.unflatten(tdef, [d for d, _ in outs])
    new_err = jax.tree.unflatten(tdef, [e for _, e in outs])
    return deq, {**opt_state, _EF_KEY: new_err}
