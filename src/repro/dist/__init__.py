"""Distribution layer: sharding plans (mesh-axis partitioning of every model,
the trainer and the server), the COO stream partitioner, gradient
compression, and the distributed planned decomposition path
(`repro.dist.planned` — imported lazily here, since it pulls in the kernel
layer).  The TPU analogue of the paper's programmable memory controller —
see sharding.py and docs/architecture.md."""
from .compression import compress_decompress, dequantize_int8, quantize_int8
from .sharding import (
    NOPLAN,
    ShardingPlan,
    StreamPartition,
    batch_pspecs,
    batch_specs,
    make_plan,
    param_pspecs,
    partition_stream,
    shard,
    valid_spec,
)


def __getattr__(name):
    # Lazy: repro.dist.planned imports repro.kernels.ops, which in turn may
    # be mid-import when this package loads (ops lazily imports dist).
    if name == "planned":
        import importlib

        return importlib.import_module(".planned", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
