"""Distribution layer: sharding plans (mesh-axis partitioning of every model,
the trainer and the server) and gradient compression.  The TPU analogue of
the paper's programmable memory controller — see sharding.py."""
from .compression import compress_decompress, dequantize_int8, quantize_int8
from .sharding import (
    NOPLAN,
    ShardingPlan,
    batch_pspecs,
    batch_specs,
    make_plan,
    param_pspecs,
    shard,
    valid_spec,
)
