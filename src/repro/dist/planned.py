"""Distributed planned decomposition — the composition of the repo's four
subsystems (see docs/architecture.md for the full data-path diagram):

    Tensor Remapper  (core/remap.plan_blocks, per shard)
      -> BlockPlan substrate  (shard-local remapped layouts)
        -> Pallas kernels  (kernels/mttkrp_pallas, kernels/ttm_pallas)
          -> shard_map over a ShardingPlan's data axes (this layer)
            -> one psum of partial factor rows per mode

The paper's traffic model already assumes this split: the non-zero stream is
partitioned and each partition's remapped layout is served independently by
its own DMA/Cache engine pair (Sec. 5); GenTen and the hybrid FPGA-CPU
Tucker system scale the same way — partition the stream across execution
units, reduce partial factor updates.  Here each "execution unit" is one
device of a 1-D `shard` mesh: `partition_stream` splits the COO stream into
balanced, tile-aligned output ranges per mode, every shard gets its own
BlockPlan (device-local remapped layout), and the unchanged Pallas kernels
run under shard_map with a single collective per mode.

Entry points (all re-exported here; built in kernels/ops.py):

  * ``cp_als(st, rank, method="pallas_sharded", devices=D)`` /
    ``tucker_hooi(st, core_ranks, method="pallas_sharded", devices=D)`` /
    ``tt_als(st, tt_ranks, method="pallas_sharded", devices=D)`` — the full
    decomposition loops, fully-jitted sweep preserved — or uniformly through
    the facade, ``decompose(st, format=..., method="pallas_sharded",
    devices=D)`` (repro/api.py);
  * ``make_sharded_planned_cp_als`` / ``make_sharded_planned_tucker`` /
    ``make_sharded_planned_tt`` — prebuilt workspaces for reuse across
    calls;
  * ``make_sharded_planned_mttkrp`` — one (tensor, mode) distributed kernel,
    also reachable through ``mttkrp_sharded(..., method="pallas")``;
  * ``shard_plan`` — the default 1-D mesh -> ShardingPlan;
  * ``partition_stream`` / ``StreamPartition`` — the stream partitioner.

CPU containers: force a multi-device host platform with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* importing
jax (``examples/quickstart.py --devices N`` does this for you).

Resilience (docs/robustness.md): the sharded sweep runs through the same
`drive` loop as the single-device path, so ``guards=GuardConfig(...)`` and
``checkpoint_every=/checkpoint_path=`` work unchanged — with one policy
caveat: the "fallback" policy has no reference degradation target for a
sharded workspace (there is no single-device reference sweep over shard
stacks), so it escalates to `DecompositionDiverged`; use "raise" or
"restart".  A silently dead shard (its remapped values zeroed, its device
contributing nothing to the psum) is exactly the fit-regression signature
the guards detect — see `repro.testing.faults.deaden_shard`.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from ..core.loop import DecompositionDiverged, GuardConfig
from ..kernels.ops import (
    ShardedPlannedCPALS,
    ShardedPlannedMTTKRP,
    ShardedPlannedTT,
    ShardedPlannedTucker,
    make_sharded_planned_cp_als,
    make_sharded_planned_mttkrp,
    make_sharded_planned_tt,
    make_sharded_planned_tucker,
)
from ..obs import metrics as _metrics
from .sharding import ShardingPlan, StreamPartition, partition_stream

__all__ = [
    "shard_plan",
    "partition_stream",
    "StreamPartition",
    "ShardingPlan",
    "ShardedPlannedMTTKRP",
    "ShardedPlannedCPALS",
    "ShardedPlannedTucker",
    "ShardedPlannedTT",
    "make_sharded_planned_mttkrp",
    "make_sharded_planned_cp_als",
    "make_sharded_planned_tucker",
    "make_sharded_planned_tt",
    "shard_makespan_report",
    "GuardConfig",
    "DecompositionDiverged",
]


def shard_plan(devices: int | None = None) -> ShardingPlan:
    """The canonical ShardingPlan for the sharded planned path: a 1-D
    ``shard`` mesh over the first `devices` local devices (None = all), as
    the plan's data axis — every spec rule of `ShardingPlan` (notably
    ``stream()``) then applies unchanged.

    Raises with the XLA_FLAGS recipe when more devices are requested than
    the platform exposes (on CPU the host device count locks at first jax
    init, so the flag must be set before importing jax)."""
    devs = jax.devices()
    n = len(devs) if devices is None else int(devices)
    if n < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if n > len(devs):
        raise ValueError(
            f"requested {n} devices but the platform exposes {len(devs)}; "
            f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before importing jax"
        )
    mesh = jax.sharding.Mesh(np.asarray(devs[:n]), ("shard",))
    return ShardingPlan(mesh=mesh, dp=("shard",))


def shard_makespan_report(ws: Any) -> dict:
    """Per-shard makespan accounting for a sharded planned workspace
    (docs/observability.md).

    The stacked shard_map sweep runs EVERY shard for the widest shard's
    block count (`_stack_shard_plans` pads narrower shards with repeated
    no-op blocks), so per mode the makespan in controller steps is
    ``max(shard_nblocks)`` and shard d's busy fraction is
    ``nblocks[d] / max``.  The report makes that visible per mode:

      * ``shard_nblocks`` / ``shard_nnz`` — the raw per-shard layout sizes;
      * ``makespan_blocks`` — the padded block count every device steps;
      * ``block_imbalance`` — max/mean shard blocks (1.0 = perfect balance;
        the direct makespan-inflation factor of the stacked sweep);
      * ``busy_fraction`` — per-shard useful fraction of the makespan.

    Each mode's imbalance is also recorded into the metrics registry
    (``sharded.block_imbalance{mode=..}`` / ``sharded.nnz_imbalance``) so a
    skewed partition shows up in `metrics.snapshot()` without holding onto
    the workspace."""
    stacks = getattr(ws, "stacks", None)
    if stacks is None:
        stack = getattr(ws, "stack", None)
        if stack is None:
            raise TypeError(
                f"{type(ws).__name__} exposes no shard stacks; the makespan "
                f"report needs a sharded planned workspace"
            )
        stacks = {stack.mode: stack}
    modes = {}
    for m, stack in sorted(stacks.items()):
        nb = [max(1, int(b)) for b in stack.shard_nblocks]
        nnz = [int(z) for z in stack.shard_nnz]
        makespan = max(nb)
        block_imb = makespan * len(nb) / sum(nb)
        nnz_imb = (
            max(nnz) * len(nnz) / sum(nnz) if sum(nnz) else float("inf")
        )
        _metrics.histogram("sharded.block_imbalance", mode=m).observe(block_imb)
        _metrics.histogram("sharded.nnz_imbalance", mode=m).observe(nnz_imb)
        modes[m] = {
            "shard_nblocks": tuple(nb),
            "shard_nnz": tuple(nnz),
            "makespan_blocks": makespan,
            "block_imbalance": block_imb,
            "nnz_imbalance": nnz_imb,
            "busy_fraction": tuple(b / makespan for b in nb),
        }
    return {
        "nshards": len(next(iter(modes.values()))["shard_nblocks"]),
        "modes": modes,
        "worst_block_imbalance": max(
            r["block_imbalance"] for r in modes.values()
        ),
    }
