"""Sharding plan: the distribution layer under every model, the trainer and
the server.

This is the TPU analogue of the paper's programmable memory controller
(Sec. 5): the controller partitions the spMTTKRP workload across fixed-function
engines under an on-chip SRAM budget; here the "engines" are mesh axes and the
budget is per-device HBM/VMEM.  One ``ShardingPlan`` holds the mesh plus the
axis assignment (``dp`` data axes, ``tp`` model axis, optional ``fsdp`` /
sequence-parallel flags) and every spec rule in the repo derives from it:

  * parameter specs   — ``param_pspecs`` / ``_leaf_spec`` (name conventions:
    column-parallel projections shard their output dim over ``tp``,
    row-parallel (wo/wd/out_proj) their input dim; fsdp adds the data axes);
  * activation specs  — ``plan.hidden() / logits() / scores() / kv_cache() /
    ssm_state() / conv_state()`` consumed by models/*;
  * batch specs       — ``batch_specs`` / ``batch_pspecs`` (dry-run stand-ins);
  * validity          — ``valid_spec`` drops any axis whose size does not
    divide the dim (whisper's 51866-row vocab falls back to replication; the
    embedding rule then moves TP onto d_model instead).

Everything is divisibility-checked *after* rule selection, so a spec rule may
optimistically name an axis and let ``valid_spec`` strike it per-shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = [
    "ShardingPlan",
    "NOPLAN",
    "make_plan",
    "shard",
    "valid_spec",
    "param_pspecs",
    "batch_specs",
    "batch_pspecs",
    "StreamPartition",
    "partition_stream",
    "stream_imbalance",
]


def _axes_size(mesh, axes) -> int:
    """Product of mesh-axis sizes for a spec entry (name or tuple of names).
    Duck-typed: only `.shape[name]` is consulted (tests use fake meshes)."""
    if mesh is None or axes is None:
        return 1
    names = axes if isinstance(axes, (tuple, list)) else (axes,)
    size = 1
    for n in names:
        size *= int(mesh.shape[n])
    return size


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Mesh + axis assignment.  ``dp`` is a tuple of data-parallel axis names
    (("pod", "data") on the multi-pod mesh), ``tp`` the tensor-parallel axis.
    ``fsdp`` additionally shards parameters/optimizer state over ``dp``
    (ZeRO-3 analogue); ``sp`` shards activation sequence dims over ``tp``."""

    mesh: Any = None
    dp: tuple[str, ...] | None = None
    tp: str | None = None
    fsdp: bool = False
    sp: bool = False

    # ------------------------------------------------------------ axis sizes

    def tp_size(self) -> int:
        return _axes_size(self.mesh, self.tp)

    def dp_size(self) -> int:
        return _axes_size(self.mesh, self.dp)

    def data_axes(self) -> tuple[str, ...]:
        """Flattened data axes (shard_map / psum axis names)."""
        if self.dp is None:
            return ()
        return tuple(self.dp) if isinstance(self.dp, (tuple, list)) else (self.dp,)

    # ------------------------------------------------- activation spec rules

    def hidden(self) -> P:
        """(B, S, D) residual-stream activations."""
        return P(self.dp, self.tp if self.sp else None, None)

    def memory(self) -> P:
        """(B, S_mem, D) encoder / image-token memory."""
        return P(self.dp, self.tp if self.sp else None, None)

    def logits(self) -> P:
        """(B, S, V): vocab over TP (the unembed is column-parallel)."""
        return P(self.dp, None, self.tp)

    def scores(self, n_heads: int) -> P:
        """(B, H, Sq, Sk) attention scores: prefer the head dim; fall back to
        the query-chunk dim when H doesn't divide the model axis (qwen2's 12
        heads, whisper's 20 on 16-way TP)."""
        if self.tp is not None and n_heads % self.tp_size() == 0:
            return P(self.dp, self.tp, None, None)
        return P(self.dp, None, self.tp, None)

    def kv_cache(self, n_kv_heads: int) -> P:
        """(B, S, KVH, hd) KV-cache layout: head-sharded when KVH divides the
        model axis, else sequence-sharded (KVH=8 cannot shard 16-way)."""
        if self.tp is not None and n_kv_heads > 0 and n_kv_heads % self.tp_size() == 0:
            return P(self.dp, None, self.tp, None)
        return P(self.dp, self.tp, None, None)

    def ssm_state(self) -> P:
        """(B, H, P, N) mamba state: heads over TP."""
        return P(self.dp, self.tp, None, None)

    def conv_state(self) -> P:
        """(B, K-1, C) conv tail: channels over TP."""
        return P(self.dp, None, self.tp)

    def stream(self) -> P:
        """Leading-dim sharding of a flat non-zero / token stream over the
        data axes (the DMA-engine partitioning of the COO stream)."""
        return P(self.dp)


NOPLAN = ShardingPlan()


def make_plan(mesh, cfg=None, *, sp: bool = False) -> ShardingPlan:
    """Build the canonical plan for a mesh: ``model`` is the TP axis, every
    other axis is data-parallel; ``fsdp`` comes from the arch config."""
    axis_names = tuple(mesh.axis_names)
    tp = "model" if "model" in axis_names else None
    dp = tuple(n for n in axis_names if n != "model") or None
    return ShardingPlan(
        mesh=mesh, dp=dp, tp=tp, fsdp=bool(getattr(cfg, "fsdp", False)), sp=sp
    )


# ---------------------------------------------------------------------------
# spec validity
# ---------------------------------------------------------------------------


def valid_spec(shape: tuple[int, ...], spec: P | None, mesh) -> P:
    """Strike every spec entry whose axis-size product does not divide the
    corresponding dim (fallback to replication on that dim).  Entry length is
    preserved; tuple entries are all-or-nothing."""
    if spec is None:
        return P(*([None] * len(shape)))
    entries = list(spec)[: len(shape)]
    out = []
    for dim, axis in zip(shape, entries):
        if axis is not None and dim % _axes_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def shard(x: jax.Array, spec: P | None, plan: ShardingPlan = NOPLAN) -> jax.Array:
    """with_sharding_constraint through the plan; identity off-mesh.  The spec
    is divisibility-filtered first, so rules can name axes optimistically."""
    if plan is None or plan.mesh is None or spec is None:
        return x
    spec = valid_spec(x.shape, spec, plan.mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(plan.mesh, spec)
    )


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

# Row-parallel projections: the TP-sharded dim is *contracted* by the matmul,
# inducing the single all-reduce per block (megatron convention).
_ROW_PARALLEL = {"wo", "wd", "out_proj"}
# Biases/vectors living in the output dim of a column-parallel projection.
_TP_VECTORS = {"bq", "bk", "bv", "bu", "conv_b"}
# 1-D-per-feature leaves that always replicate (norm scales, gates, SSM
# per-head constants): tiny, and sharding them buys nothing.
_REPLICATED = {"scale", "bias", "gate_attn", "gate_ffn", "A_log", "D", "dt_bias", "bd"}


def _leaf_spec(keys: tuple[str, ...], shape: tuple[int, ...], plan: ShardingPlan) -> P:
    """Parameter-leaf spec by name convention.  ``keys`` is the string path
    into the parameter tree; everything before the trailing matrix dims is a
    stack dim (layer repeats, expert stacks) and stays unsharded."""
    name = keys[-1] if keys else ""
    tp = plan.tp
    fs = plan.dp if plan.fsdp else None
    ndim = len(shape)
    if name in ("embed", "lm_head"):
        # vocab over TP; if the (unpadded) vocab doesn't divide, d_model
        # picks up TP instead of silently replicating the biggest table.
        if tp is not None and shape[0] % _axes_size(plan.mesh, tp) == 0:
            return P(tp, fs)
        return P(None, tp)
    if name in _REPLICATED:
        return P(*([None] * ndim))
    if ndim >= 2:
        lead = [None] * (ndim - 2)
        if name in _ROW_PARALLEL:
            return P(*lead, tp, fs)
        return P(*lead, fs, tp)  # column-parallel default (wq/wk/wv/wu/wg/...)
    if ndim == 1 and name in _TP_VECTORS:
        return P(tp)
    return P(*([None] * ndim))


def _key_str(entry) -> str:
    return entry.key if hasattr(entry, "key") else str(entry)


def param_pspecs(params, plan: ShardingPlan):
    """PartitionSpec tree mirroring ``params`` (works on arrays or
    ShapeDtypeStructs).  Callers run ``valid_spec`` per-leaf afterwards."""

    def f(path, leaf):
        keys = tuple(_key_str(p) for p in path)
        return _leaf_spec(keys, tuple(leaf.shape), plan)

    return jax.tree_util.tree_map_with_path(f, params)


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------


def _compute_dtype(cfg):
    return jnp.bfloat16 if getattr(cfg, "compute_dtype", "float32") == "bfloat16" else jnp.float32


def batch_specs(cfg, shape_cfg, plan: ShardingPlan) -> dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch stand-ins for one (arch, shape) cell — what the dry-run
    lowers against.  Decode carries one new token + per-row cache positions;
    audio/vlm archs add their (stubbed) memory streams."""
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    sds = jax.ShapeDtypeStruct
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if shape_cfg.kind == "decode":
        specs["tokens"] = sds((B, 1), jnp.int32)
        specs["pos"] = sds((B,), jnp.int32)
    else:
        specs["tokens"] = sds((B, S), jnp.int32)
        if shape_cfg.kind == "train":
            specs["labels"] = sds((B, S), jnp.int32)
    cd = _compute_dtype(cfg)
    if cfg.family == "audio":
        specs["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), cd)
    if cfg.family == "vlm":
        specs["images"] = sds((B, cfg.img_tokens, cfg.d_model), cd)
    return specs


def batch_pspecs(cfg, shape_cfg, plan: ShardingPlan) -> dict[str, P]:
    """PartitionSpecs matching ``batch_specs``: batch dim over the data axes,
    everything else replicated."""
    dp = plan.dp
    specs: dict[str, P] = {}
    for k, v in batch_specs(cfg, shape_cfg, plan).items():
        specs[k] = P(dp, *([None] * (len(v.shape) - 1)))
    return specs


# ---------------------------------------------------------------------------
# COO stream partitioner (the DMA-engine split of the non-zero stream)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamPartition:
    """A partition of one COO stream into per-device shards by *output-mode
    tile range* — the distribution posture of the paper's traffic model: each
    DMA engine serves a contiguous slice of the output coordinate space, so a
    shard's remapped layout (BlockPlan) writes a disjoint set of output tiles
    and the cross-device reduction of factor rows is a plain sum.

    Invariants (property-tested in tests/test_sharded_planned.py):
      * every non-zero lands in exactly one shard (no drops / duplicates at
        tile boundaries);
      * shard boundaries are multiples of ``tile`` in the output coordinate,
        so no output tile is split across two shards;
      * within a shard, non-zeros keep their original relative order
        (``positions`` is strictly increasing), and ``reassemble()``
        reconstructs the exact original stream, order included.
    """

    mode: int  # output mode the split keys on
    tile: int  # alignment granularity (the plan's tile_i)
    shape: tuple[int, ...]
    tile_bounds: tuple[int, ...]  # nshards+1 cut points, in tile units
    shards: list  # per-device SparseTensor views (global shape + coords)
    positions: list[np.ndarray]  # original stream position of each shard nnz

    @property
    def nshards(self) -> int:
        return len(self.shards)

    @property
    def shard_nnz(self) -> tuple[int, ...]:
        return tuple(s.nnz for s in self.shards)

    def row_ranges(self) -> tuple[tuple[int, int], ...]:
        """Per-shard [start, end) output-coordinate ranges (tile-aligned;
        the last is clipped to the mode length)."""
        n = self.shape[self.mode]
        return tuple(
            (min(b * self.tile, n), min(e * self.tile, n))
            for b, e in zip(self.tile_bounds[:-1], self.tile_bounds[1:])
        )

    def imbalance(self) -> float:
        """max / mean shard nnz — 1.0 is a perfect balance; the PMS makespan
        model (`pms.predict_sharded`) is what this ratio feeds."""
        return stream_imbalance(self.shard_nnz)

    def reassemble(self):
        """Scatter the shards back into the exact original stream (order
        included) — the no-dropped/duplicated-nonzeros contract."""
        from ..core.coo import SparseTensor

        total = sum(self.shard_nnz)
        nmodes = len(self.shape)
        idx = np.zeros((total, nmodes), np.int32)
        val = np.zeros((total,), np.float32)
        seen = np.zeros((total,), bool)
        for sh, pos in zip(self.shards, self.positions):
            if np.any(seen[pos]):
                raise ValueError("duplicated non-zeros across shards")
            seen[pos] = True
            idx[pos] = sh.indices
            val[pos] = sh.values
        if not np.all(seen):
            raise ValueError("dropped non-zeros: shards do not cover the stream")
        return SparseTensor(idx, val, self.shape)


def stream_imbalance(shard_nnz) -> float:
    """max / mean over a per-shard nnz tuple (1.0 = perfect balance; 1.0 for
    an empty stream).  THE balance metric — `StreamPartition.imbalance`, the
    PMS `ShardedPMSEstimate.imbalance` and the `sharded_partition` benchmark
    record all report exactly this ratio."""
    total = sum(shard_nnz)
    if total == 0:
        return 1.0
    return max(shard_nnz) / (total / len(shard_nnz))


def partition_stream(st, mode: int, nshards: int, *, tile: int = 1) -> StreamPartition:
    """Split a COO stream into ``nshards`` contiguous output-mode tile ranges
    with balanced nnz (greedy prefix split of the per-tile histogram).

    Every shard keeps the *global* shape and global coordinates, so a
    per-shard ``plan_blocks`` emits global output-tile ids — under shard_map
    each device's kernel writes its disjoint tile range of the full output
    and a single ``psum`` reassembles the factor matrix.  Boundaries are
    aligned to ``tile`` (pass the plan's ``tile_i``) so no output tile is
    ever co-owned by two devices.  Shards may be empty when nnz or the tile
    count is smaller than ``nshards`` (the plan stacker pads those with
    zero-value blocks)."""
    if nshards < 1:
        raise ValueError(f"nshards must be >= 1, got {nshards}")
    if not 0 <= mode < st.nmodes:
        raise ValueError(f"mode {mode} out of range for a {st.nmodes}-mode tensor")
    if tile < 1:
        raise ValueError(f"tile must be >= 1, got {tile}")
    ntiles = max(1, -(-st.shape[mode] // tile))
    tile_of = st.indices[:, mode].astype(np.int64) // tile
    hist = np.bincount(tile_of, minlength=ntiles)
    cum = np.cumsum(hist)
    total = int(st.nnz)
    # Greedy balanced prefix split: cut after the tile where the cumulative
    # nnz first reaches each d/nshards quantile.  searchsorted on the
    # nondecreasing cumsum keeps the cuts monotone.
    targets = total * np.arange(1, nshards, dtype=np.float64) / nshards
    cuts = np.searchsorted(cum, targets, side="left") + 1
    cuts = np.minimum(cuts, ntiles)
    bounds = np.concatenate([[0], cuts, [ntiles]]).astype(np.int64)
    # Tile t belongs to the last range whose start is <= t (duplicate cut
    # points produce empty ranges, resolved in favour of the later shard).
    shard_of = np.searchsorted(bounds, tile_of, side="right") - 1
    from ..core.coo import SparseTensor

    shards, positions = [], []
    for d in range(nshards):
        pos = np.flatnonzero(shard_of == d)
        positions.append(pos)
        shards.append(SparseTensor(st.indices[pos], st.values[pos], st.shape))
    return StreamPartition(
        mode=mode,
        tile=tile,
        shape=st.shape,
        tile_bounds=tuple(int(b) for b in bounds),
        shards=shards,
        positions=positions,
    )
