"""COO sparse-tensor container + synthetic FROSTT-like generators.

The paper (Sec. 2.1, Alg. 2) operates on third-or-higher-order sparse tensors
stored in coordinate (COO) format: per non-zero, one coordinate per mode plus a
value.  We keep a host-side numpy container (`SparseTensor`) for dataset
construction / remap planning, and a device pytree (`CooBatch`) with padded,
jit-stable shapes for compute.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SparseTensor",
    "CooBatch",
    "synthetic_tensor",
    "frostt_like",
    "to_device",
    "pad_nnz",
]


@dataclasses.dataclass
class SparseTensor:
    """Host-side COO tensor.  `indices[z, m]` is the mode-m coordinate of nnz z."""

    indices: np.ndarray  # (nnz, nmodes) int32
    values: np.ndarray  # (nnz,) float32
    shape: tuple[int, ...]

    def __post_init__(self):
        assert self.indices.ndim == 2 and self.indices.shape[1] == len(self.shape)
        assert self.values.shape == (self.indices.shape[0],)
        self.indices = np.asarray(self.indices, np.int32)
        self.values = np.asarray(self.values, np.float32)

    @property
    def nnz(self) -> int:
        return self.indices.shape[0]

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    @property
    def density(self) -> float:
        return self.nnz / float(np.prod([float(s) for s in self.shape]))

    def nbytes(self, index_bytes: int = 4, value_bytes: int = 4) -> int:
        """Size of the COO stream, |T| elements (paper's tensor-size metric)."""
        return self.nnz * (self.nmodes * index_bytes + value_bytes)

    def mode_histogram(self, mode: int) -> np.ndarray:
        """Non-zeros per coordinate of `mode` (hypergraph vertex degrees)."""
        return np.bincount(self.indices[:, mode], minlength=self.shape[mode])

    def sorted_by(self, mode: int) -> "SparseTensor":
        """Stable sort by one mode's coordinates (host-side reference remap)."""
        order = np.argsort(self.indices[:, mode], kind="stable")
        return SparseTensor(self.indices[order], self.values[order], self.shape)

    def is_sorted_by(self, mode: int) -> bool:
        c = self.indices[:, mode]
        return bool(np.all(c[1:] >= c[:-1]))

    def fingerprint(self) -> str:
        """Content hash of (shape, indices, values) — the plan-cache key
        (kernels/ops.py): two tensors with equal fingerprints get the same
        memory layout.  Cached on the instance; the arrays are treated as
        immutable after construction."""
        fp = getattr(self, "_fingerprint", None)
        if fp is None:
            h = hashlib.sha1()
            h.update(repr(self.shape).encode())
            h.update(np.ascontiguousarray(self.indices).tobytes())
            h.update(np.ascontiguousarray(self.values).tobytes())
            fp = h.hexdigest()
            object.__setattr__(self, "_fingerprint", fp)
        return fp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CooBatch:
    """Device-side COO with jit-stable (padded) nnz.  Padding rows have
    value 0 and coordinates 0, contributing nothing to MTTKRP."""

    indices: jax.Array  # (nnz_padded, nmodes) int32
    values: jax.Array  # (nnz_padded,) float dtype
    shape: tuple[int, ...]  # static
    nnz: int  # static true nnz (<= padded)

    def tree_flatten(self):
        return (self.indices, self.values), (self.shape, self.nnz)

    @classmethod
    def tree_unflatten(cls, aux, children):
        indices, values = children
        shape, nnz = aux
        return cls(indices=indices, values=values, shape=shape, nnz=nnz)

    @property
    def nmodes(self) -> int:
        return len(self.shape)


def pad_nnz(st: SparseTensor, multiple: int) -> SparseTensor:
    """Pad the nnz stream to a multiple (DMA-buffer granularity).  Padding
    values are zero so downstream compute is unchanged."""
    nnz = st.nnz
    padded = ((nnz + multiple - 1) // multiple) * multiple
    if padded == nnz:
        return st
    pad = padded - nnz
    idx = np.concatenate([st.indices, np.zeros((pad, st.nmodes), np.int32)], 0)
    val = np.concatenate([st.values, np.zeros((pad,), np.float32)], 0)
    return SparseTensor(idx, val, st.shape)


def to_device(st: SparseTensor, pad_multiple: int = 1, dtype=jnp.float32) -> CooBatch:
    stp = pad_nnz(st, pad_multiple) if pad_multiple > 1 else st
    return CooBatch(
        indices=jnp.asarray(stp.indices),
        values=jnp.asarray(stp.values, dtype),
        shape=st.shape,
        nnz=st.nnz,
    )


def _zipf_coords(rng: np.random.Generator, n: int, size: int, alpha: float) -> np.ndarray:
    """Skewed coordinates: real FROSTT tensors have power-law mode degree
    distributions (a few very hot rows).  alpha=0 -> uniform."""
    if alpha <= 0:
        return rng.integers(0, size, n, dtype=np.int64)
    # Sample from a discretized zipf over [0, size) via inverse-CDF on ranks.
    ranks = np.arange(1, size + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    coords = rng.choice(size, size=n, p=probs)
    # Random permutation of coordinate labels so hot rows are scattered.
    perm = rng.permutation(size)
    return perm[coords]


def synthetic_tensor(
    shape: Sequence[int],
    nnz: int,
    *,
    seed: int = 0,
    skew: float = 0.0,
    dedup: bool = False,
) -> SparseTensor:
    """Random sparse tensor with optional per-mode zipf skew.

    dedup=True removes duplicate coordinates (real tensors are sets); for
    large sparse shapes collisions are rare so we keep it optional.
    """
    rng = np.random.default_rng(seed)
    cols = [_zipf_coords(rng, nnz, s, skew) for s in shape]
    idx = np.stack(cols, axis=1).astype(np.int32)
    if dedup:
        idx = np.unique(idx, axis=0)
    vals = rng.standard_normal(idx.shape[0]).astype(np.float32)
    return SparseTensor(idx, vals, tuple(int(s) for s in shape))


def frostt_like(name: str = "small", seed: int = 0) -> SparseTensor:
    """Synthetic stand-ins shaped like FROSTT-repository tensors (paper
    Table 2: mode lengths 17–39 M, nnz 3–144 M, 3–5 modes).  Scaled-down
    presets keep CI fast; `paper` presets match Table 2 magnitudes and are
    used only by the dry-run / PMS (no allocation at full scale)."""
    presets = {
        # name: (shape, nnz, skew)
        "tiny": ((64, 48, 80), 2_000, 0.8),
        "small": ((1_000, 800, 1_200), 50_000, 0.9),
        "medium": ((20_000, 15_000, 25_000), 500_000, 1.0),
        "large": ((200_000, 150_000, 250_000), 4_000_000, 1.0),
        "nell2_like": ((12_092, 9_184, 28_818), 2_000_000, 1.1),
        "4d_small": ((500, 400, 600, 300), 40_000, 0.8),
        "5d_small": ((120, 100, 150, 80, 60), 20_000, 0.6),
    }
    shape, nnz, skew = presets[name]
    return synthetic_tensor(shape, nnz, seed=seed, skew=skew)


def random_factors(
    key: jax.Array, shape: Sequence[int], rank: int, dtype=jnp.float32
) -> list[jax.Array]:
    """Random dense factor matrices, one (I_m, R) per mode."""
    keys = jax.random.split(key, len(shape))
    return [
        jax.random.normal(k, (int(s), rank), dtype) / np.sqrt(rank)
        for k, s in zip(keys, shape)
    ]
