"""Hypergraph model of spMTTKRP (paper Sec. 3).

Vertices = tensor coordinates of every mode (|V| = sum(I_m)); hyperedges =
non-zeros (|E| = nnz).  The two traversal orders (Approach 1: by output-mode
vertex; Approach 2: by input-mode vertex) give the external-traffic models of
Table 1.  This module provides those analytical traffic models plus measured
statistics used by the PMS (Sec. 5.3).
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .coo import SparseTensor

__all__ = [
    "TrafficModel",
    "approach1_traffic",
    "approach2_traffic",
    "remap_overhead",
    "HypergraphStats",
    "stats",
]


@dataclasses.dataclass(frozen=True)
class TrafficModel:
    """External-memory element counts for one mode of spMTTKRP (Table 1)."""

    tensor_loads: int  # |T| hyperedge loads
    factor_elems: int  # input/output factor-matrix elements moved
    partial_sum_elems: int  # Approach-2 partial-sum store+load traffic
    compute_ops: int  # N * |T| * R multiply-adds

    @property
    def total_elems(self) -> int:
        return self.tensor_loads + self.factor_elems + self.partial_sum_elems

    def bytes(self, elem_bytes: int = 4, tensor_elem_bytes: int = 16) -> int:
        return (
            self.tensor_loads * tensor_elem_bytes
            + (self.factor_elems + self.partial_sum_elems) * elem_bytes
        )


def approach1_traffic(st: SparseTensor, mode: int, rank: int) -> TrafficModel:
    """Output-mode-direction traversal: |T| + (N-1)*|T|*R + I_out*R, no
    partial sums (Table 1, row 1)."""
    n = st.nmodes
    t = st.nnz
    i_out = st.shape[mode]
    return TrafficModel(
        tensor_loads=t,
        factor_elems=(n - 1) * t * rank + i_out * rank,
        partial_sum_elems=0,
        compute_ops=n * t * rank,
    )


def approach2_traffic(st: SparseTensor, mode: int, rank: int, in_mode: int | None = None) -> TrafficModel:
    """Input-mode-direction traversal: |T| + N*|T|*R + I_in*R with |T|*R
    partial sums stored + re-loaded (Table 1, row 2)."""
    n = st.nmodes
    t = st.nnz
    if in_mode is None:
        in_mode = (mode + 1) % n
    i_in = st.shape[in_mode]
    return TrafficModel(
        tensor_loads=t,
        factor_elems=n * t * rank + i_in * rank,
        partial_sum_elems=t * rank,  # stored once, accumulated later
        compute_ops=n * t * rank,
    )


def remap_overhead(st: SparseTensor, mode: int, rank: int) -> float:
    """Paper Sec. 3.1: remap adds 2|T| accesses; relative overhead
    2|T| / (|T| + (N-1)|T|R + I_out R)  ~=  2 / (1 + (N-1) R).
    Returns the exact ratio for this tensor."""
    base = approach1_traffic(st, mode, rank).total_elems
    return 2.0 * st.nnz / float(base)


@dataclasses.dataclass(frozen=True)
class HypergraphStats:
    """Measured hypergraph statistics feeding the PMS locality model."""

    nnz: int
    nmodes: int
    shape: tuple[int, ...]
    degree_mean: tuple[float, ...]  # mean hyperedges per vertex, per mode
    degree_max: tuple[int, ...]
    degree_cv: tuple[float, ...]  # coefficient of variation (skew measure)
    occupied_frac: tuple[float, ...]  # fraction of coordinates with >=1 nnz


def stats(st: SparseTensor) -> HypergraphStats:
    means, maxs, cvs, occ = [], [], [], []
    for m in range(st.nmodes):
        h = st.mode_histogram(m)
        nz = h[h > 0]
        means.append(float(nz.mean()) if nz.size else 0.0)
        maxs.append(int(nz.max()) if nz.size else 0)
        cvs.append(float(nz.std() / max(nz.mean(), 1e-9)) if nz.size else 0.0)
        occ.append(float(nz.size) / st.shape[m])
    return HypergraphStats(
        nnz=st.nnz,
        nmodes=st.nmodes,
        shape=st.shape,
        degree_mean=tuple(means),
        degree_max=tuple(maxs),
        degree_cv=tuple(cvs),
        occupied_frac=tuple(occ),
    )
