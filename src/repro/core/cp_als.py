"""CP-ALS driver (paper Alg. 1) built on the spMTTKRP substrate.

Faithful to the paper's system framing:
  * one tensor copy, remapped into the next output mode's order before each
    mode's MTTKRP (Alg. 5) — `layout="remap"`; or
  * one pre-sorted copy per mode (the alternative the paper rejects on FPGA
    for memory reasons; on TPU HBM it is a legitimate space/time trade) —
    `layout="copies"`.

The steady-state iteration is one jitted *sweep* — a single compiled function
running every mode's MTTKRP -> gram -> solve -> normalize plus the on-device
fit (`_sweep_streams` / `_sweep_remap` here; `PlannedCPALS.sweep` for the
Pallas memory-controller path).  Only the `tol` early-exit reads the
per-iteration fit scalar back to the host.  Pass `jit_sweep=False` (or an
`mttkrp_fn` override) to fall back to the eager per-mode dispatch loop.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .coo import SparseTensor, to_device, random_factors
from .loop import (
    check_drive_extras,
    check_planned_method,
    check_workspace,
    finish_iter,
    require_sharded_sweep,
)
from .mttkrp import mttkrp, hadamard_rows
from .remap import remap_stable

__all__ = ["CPState", "cp_als", "fit_value", "gram_hadamard"]


@dataclasses.dataclass
class CPState:
    factors: list[jax.Array]  # one (I_m, R) per mode
    lam: jax.Array  # (R,) column norms
    fit_history: list[float]

    @property
    def rank(self) -> int:
        return int(self.lam.shape[0])


def gram_hadamard(factors: Sequence[jax.Array], mode: int) -> jax.Array:
    """Hadamard product of Gram matrices F_n^T F_n for all n != mode. (R, R)."""
    g = None
    for n, f in enumerate(factors):
        if n == mode:
            continue
        gn = f.T @ f
        g = gn if g is None else g * gn
    assert g is not None
    return g


def _solve(mttkrp_out: jax.Array, g: jax.Array, ridge: float = 1e-8) -> jax.Array:
    """A = M @ (G + ridge I)^-1 ; ridge keeps near-rank-deficient iterations
    stable (G is PSD)."""
    r = g.shape[0]
    gi = g + ridge * jnp.eye(r, dtype=g.dtype)
    return jax.scipy.linalg.solve(gi, mttkrp_out.T, assume_a="pos").T


def _normalize(f: jax.Array, it: int) -> tuple[jax.Array, jax.Array]:
    """Column-normalize; first iteration uses the standard CP-ALS
    max(norm, 1) convention: the initial random factors can carry tiny
    column norms on poorly scaled tensors, and dividing by them inflates
    noise columns before the scale has been absorbed into lambda.  Later
    iterations normalize by the exact column 2-norm (guarded against 0)."""
    norms = jnp.linalg.norm(f, axis=0)
    if it == 0:
        norms = jnp.maximum(norms, 1.0)
    else:
        norms = jnp.where(norms > 1e-12, norms, 1.0)
    return f / norms, norms


def inner_with_model(
    indices: jax.Array, values: jax.Array, factors: Sequence[jax.Array], lam: jax.Array
) -> jax.Array:
    """<X, [[lam; factors]]> evaluated only at the non-zeros (exact, since the
    model is dense but X is zero elsewhere ... the inner product only needs
    X's support)."""
    prod = None
    for n, f in enumerate(factors):
        rows = f[indices[:, n]]
        prod = rows if prod is None else prod * rows
    return jnp.sum(values * (prod @ lam))


def model_norm_sq(factors: Sequence[jax.Array], lam: jax.Array) -> jax.Array:
    """||[[lam; factors]]||_F^2 = lam^T (hadamard_n F_n^T F_n) lam."""
    g = None
    for f in factors:
        gn = f.T @ f
        g = gn if g is None else g * gn
    return lam @ g @ lam


def fit_value(
    indices: jax.Array,
    values: jax.Array,
    factors: Sequence[jax.Array],
    lam: jax.Array,
    norm_x_sq: jax.Array,
) -> jax.Array:
    """fit = 1 - ||X - X_hat|| / ||X||."""
    inner = inner_with_model(indices, values, factors, lam)
    resid_sq = jnp.maximum(norm_x_sq + model_norm_sq(factors, lam) - 2.0 * inner, 0.0)
    return 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)


def _update_mode(mt: jax.Array, factors: list, m: int, first: bool):
    """Shared mode update: gram -> solve -> normalize (one Alg. 1 step)."""
    g = gram_hadamard(factors, m)
    f = _solve(mt, g)
    f, lam = _normalize(f, 0 if first else 1)
    factors[m] = f
    return factors, lam


@partial(jax.jit, static_argnames=("shape", "method", "first"))
def _sweep_streams(factors, streams_idx, streams_val, norm_x_sq, *, shape, method, first):
    """One full jitted ALS iteration over per-mode pre-sorted streams
    (layout='copies'): every mode's MTTKRP -> gram -> solve -> normalize,
    plus the fit, in a single compiled function."""
    factors = list(factors)
    lam = None
    for m in range(len(shape)):
        mt = mttkrp(streams_idx[m], streams_val[m], factors, m, shape[m], method=method)
        factors, lam = _update_mode(mt, factors, m, first)
    fit = fit_value(streams_idx[-1], streams_val[-1], factors, lam, norm_x_sq)
    return tuple(factors), lam, fit


@partial(jax.jit, static_argnames=("shape", "method", "first"))
def _sweep_remap(factors, idx, val, norm_x_sq, *, shape, method, first):
    """One full jitted ALS iteration for the single-stream layout: the
    on-device Tensor Remapper (Alg. 5) re-sorts the carried stream before
    each mode inside the same compiled function; the remapped stream is
    returned as carry for the next iteration."""
    factors = list(factors)
    lam = None
    for m in range(len(shape)):
        idx, val, _ = remap_stable(idx, val, m)
        mt = mttkrp(idx, val, factors, m, shape[m], method=method)
        factors, lam = _update_mode(mt, factors, m, first)
    fit = fit_value(idx, val, factors, lam, norm_x_sq)
    return tuple(factors), lam, idx, val, fit


def cp_als(
    st: SparseTensor,
    rank: int,
    *,
    iters: int = 10,
    method: str = "approach1",
    layout: str = "remap",
    seed: int = 0,
    tol: float | None = None,
    mttkrp_fn: Callable | None = None,
    planned=None,
    interpret: bool = True,
    auto_tune: bool | str = False,
    spec="default",
    cfg=None,
    jit_sweep: bool = True,
    devices: int | None = None,
    dist=None,
    verbose: bool = False,
    guards=None,
    checkpoint_every: int | None = None,
    checkpoint_path=None,
) -> CPState:
    """Run CP-ALS.

    method: 'approach1' | 'approach2'  (Sec. 3 compute patterns), or
            'pallas' — the memory-controller kernel: a `PlannedCPALS`
            workspace (kernels/ops.py) is built once — one remapped,
            device-resident BlockPlan per output mode — and reused for every
            iteration (plan amortization, Alg. 1 on the Alg. 5 layout); or
            'pallas_sharded' — the distributed planned path
            (repro.dist.planned): the stream is partitioned into balanced
            output-tile ranges per mode, each shard's remapped layout is
            device-local, and every iteration is one jitted shard_map sweep
            with a single psum of partial factor rows per mode.
    layout: 'remap'  — single stream, remapped (re-sorted) before each mode
                       (Alg. 5; remap runs on device via remap_stable);
            'copies' — per-mode pre-sorted copies (more HBM, no remap traffic).
            Ignored for the pallas paths: the per-mode plans *are* the copies.
    mttkrp_fn: optional override with signature (indices, values, factors,
               mode, out_rows) -> (I_mode, R).  Forces the eager loop (the
               override may not be jit-traceable).
    planned / interpret / auto_tune / cfg: pallas-path knobs — pass a
               prebuilt `PlannedCPALS` (or `ShardedPlannedCPALS` for
               'pallas_sharded') to reuse plans across calls, or let
               auto_tune run the PMS per mode (Sec. 5.3; worst-shard
               makespan for the sharded path).  auto_tune="cached" persists
               and reuses the PMS winners on disk (repro.tune.cache).
    spec:      PMS hardware constants — a TPUSpec, "default" (datasheet
               guesses), or "measured" (this backend's calibrated spec from
               the autotune cache; see repro.tune).
    jit_sweep: run each iteration as one jitted sweep (factors stay
               device-resident — rank-padded for the pallas path — across
               iterations; `tol` is checked on the host against the
               per-iteration fit scalar).  False restores the eager per-mode
               dispatch loop, kept as the parity baseline ('pallas_sharded'
               is sweep-only and rejects jit_sweep=False).
    devices / dist: 'pallas_sharded' placement — a device count for the
               default 1-D `shard` mesh, or an explicit ShardingPlan.
    guards / checkpoint_every / checkpoint_path: the resilience surface of
               the planned drive loop (repro.resilience): a `GuardConfig`
               for divergence detection + raise/restart/fallback recovery,
               and periodic checkpointing with automatic resume.  Planned
               jitted paths only.
    """
    if layout not in ("remap", "copies"):
        raise ValueError(f"unknown layout {layout!r}: expected 'remap' or 'copies'")
    nmodes = st.nmodes
    key = jax.random.PRNGKey(seed)
    factors = random_factors(key, st.shape, rank)
    lam = jnp.ones((rank,), jnp.float32)
    norm_x_sq = jnp.asarray(float(np.sum(st.values.astype(np.float64) ** 2)), jnp.float32)
    fits: list[float] = []

    check_planned_method(method, planned, devices, dist)
    # mttkrp_fn forces the eager loop, which never reaches drive's
    # guard/checkpoint surface — fold it into the jit_sweep condition.
    check_drive_extras(method, jit_sweep and mttkrp_fn is None, guards,
                       checkpoint_every, checkpoint_path)
    if method == "pallas_sharded":
        if mttkrp_fn is not None:
            raise ValueError("mttkrp_fn cannot override the sharded planned path")
        require_sharded_sweep(jit_sweep)
        from ..kernels.ops import ShardedPlannedCPALS, make_sharded_planned_cp_als

        if planned is None:
            planned = make_sharded_planned_cp_als(
                st, rank, dist=dist, devices=devices, cfg=cfg,
                auto_tune=auto_tune, spec=spec, interpret=interpret,
            )
        else:
            check_workspace(
                planned, ShardedPlannedCPALS, method,
                {"shape": st.shape, "rank": rank}, devices=devices,
            )
        factors, lam, fits = planned.drive(
            factors, (norm_x_sq,), iters=iters, tol=tol, verbose=verbose,
            label="cp_als", guards=guards,
            checkpoint_every=checkpoint_every, checkpoint_path=checkpoint_path,
        )
        return CPState(factors=factors, lam=lam, fit_history=fits)
    if method == "pallas" and mttkrp_fn is None:
        # Lazy import: kernels builds on core, not the other way around.
        from ..kernels.ops import PlannedCPALS, make_planned_cp_als

        if planned is None:
            planned = make_planned_cp_als(
                st, rank, cfg=cfg, auto_tune=auto_tune, spec=spec,
                interpret=interpret,
            )
        else:
            check_workspace(
                planned, PlannedCPALS, method, {"shape": st.shape, "rank": rank}
            )
        if jit_sweep:
            # Fast path: factors padded once, updated in padded space by one
            # jitted sweep per iteration; sliced back only for the CPState.
            base_idx, base_val = jnp.asarray(st.indices), jnp.asarray(st.values)
            factors, lam, fits = planned.drive(
                factors, (base_idx, base_val, norm_x_sq), iters=iters, tol=tol,
                verbose=verbose, label="cp_als", guards=guards,
                checkpoint_every=checkpoint_every,
                checkpoint_path=checkpoint_path,
            )
            return CPState(factors=factors, lam=lam, fit_history=fits)
        mttkrp_fn = planned.mttkrp_fn
        layout = "planned"

    if layout == "planned":
        # The per-mode remapped copies live inside the plans; keep one
        # (order-irrelevant) stream only for the fit computation.
        base_idx, base_val = jnp.asarray(st.indices), jnp.asarray(st.values)
    elif layout == "copies":
        streams = []
        for m in range(nmodes):
            sm = st.sorted_by(m)
            streams.append((jnp.asarray(sm.indices), jnp.asarray(sm.values)))
    else:
        # Single stream; keep it sorted by the *previous* output mode and
        # remap on device before each mode, exactly Alg. 5.
        s0 = st.sorted_by(0)
        cur_idx, cur_val = jnp.asarray(s0.indices), jnp.asarray(s0.values)

    if jit_sweep and mttkrp_fn is None and layout in ("copies", "remap"):
        factors_t = tuple(factors)
        if layout == "copies":
            streams_idx = tuple(s[0] for s in streams)
            streams_val = tuple(s[1] for s in streams)
        for it in range(iters):
            if layout == "copies":
                factors_t, lam, fit = _sweep_streams(
                    factors_t, streams_idx, streams_val, norm_x_sq,
                    shape=st.shape, method=method, first=(it == 0),
                )
            else:
                factors_t, lam, cur_idx, cur_val, fit = _sweep_remap(
                    factors_t, cur_idx, cur_val, norm_x_sq,
                    shape=st.shape, method=method, first=(it == 0),
                )
            if finish_iter(fits, fit, it, tol, verbose, "cp_als"):
                break
        return CPState(factors=list(factors_t), lam=lam, fit_history=fits)

    # Eager per-mode dispatch loop: mttkrp_fn overrides and jit_sweep=False.
    def do_mttkrp(indices, values, facs, mode):
        if mttkrp_fn is not None:
            return mttkrp_fn(indices, values, facs, mode, st.shape[mode])
        return mttkrp(indices, values, facs, mode, st.shape[mode], method=method)

    for it in range(iters):
        for m in range(nmodes):
            if layout == "planned":
                idx, val = base_idx, base_val
            elif layout == "copies":
                idx, val = streams[m]
            else:
                idx, val, _ = remap_stable(cur_idx, cur_val, m)  # Tensor Remapper
                cur_idx, cur_val = idx, val
            mt = do_mttkrp(idx, val, factors, m)
            g = gram_hadamard(factors, m)
            f = _solve(mt, g)
            f, lam = _normalize(f, it)
            factors[m] = f
        if finish_iter(fits, fit_value(idx, val, factors, lam, norm_x_sq), it, tol, verbose, "cp_als"):
            break
    return CPState(factors=factors, lam=lam, fit_history=fits)
