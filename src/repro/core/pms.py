"""Performance Model Simulator (paper Sec. 5.3), retargeted to TPU.

The paper's PMS estimates spMTTKRP execution time for a controller
configuration + dataset, and checks the configuration fits on-chip memory, so
the (hours-long) synthesis loop never runs on a bad configuration.  Our PMS
does the same for the Pallas kernel: given tensor statistics (or an actual
BlockPlan) and a MemoryControllerConfig, estimate the three roofline terms and
search the parameter space under the VMEM budget.  Re-instantiating the kernel
is a re-jit (seconds), but the model is still what makes the search tractable
for large datasets.

Model (per output mode):
  t_stream  = stream_bytes / hbm_bw          (DMA Engine term)
  t_factor  = tile_fill_bytes / hbm_bw       (Cache Engine miss term)
  t_out     = out_tile_bytes / hbm_bw        (single flush per A tile; Approach 1)
  t_mem     = t_stream + t_factor + t_out
  t_compute = kernel_flops / peak_flops      (MXU one-hot segment matmul)
  t_total   ~= max(t_mem, t_compute)         (double-buffered overlap)
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, Sequence

import numpy as np

from .coo import SparseTensor
from .hypergraph import HypergraphStats, stats as hg_stats
from .memctrl import MemoryControllerConfig, CacheEngineConfig, DMAEngineConfig, RemapperConfig, TPUSpec
from .remap import BlockPlan, plan_blocks

__all__ = [
    "PMSEstimate",
    "ShardedPMSEstimate",
    "predict_from_plan",
    "predict_analytic",
    "predict_ttmc",
    "predict_ttmc_analytic",
    "predict_tt",
    "predict_tt_analytic",
    "predict_sharded",
    "resolve_spec",
    "search",
    "search_sharded",
    "DEFAULT_TILE_CHOICES",
]


@dataclasses.dataclass(frozen=True)
class PMSEstimate:
    cfg: MemoryControllerConfig
    t_stream: float
    t_factor: float
    t_out: float
    t_compute: float
    vmem_bytes: int
    nblocks: int
    padding_fraction: float

    @property
    def t_mem(self) -> float:
        return self.t_stream + self.t_factor + self.t_out

    @property
    def t_total(self) -> float:
        return max(self.t_mem, self.t_compute)

    @property
    def bottleneck(self) -> str:
        return "memory" if self.t_mem >= self.t_compute else "compute"


def _rank_padded(rank: int) -> int:
    return max(128, ((rank + 127) // 128) * 128)


def resolve_spec(spec) -> TPUSpec:
    """Resolve the `spec=` argument of the search entry points: a `TPUSpec`
    passes through, ``"default"`` is the datasheet `TPUSpec()`, and
    ``"measured"`` is this backend's calibrated spec from the autotune cache
    (`repro.tune`), auto-calibrating on a cache miss."""
    if isinstance(spec, TPUSpec):
        return spec
    from ..tune import resolve_spec as _tune_resolve  # deferred: tune -> pms

    return _tune_resolve(spec)


def _count_configs(kernel: str, n: int, *, sharded: bool = False) -> None:
    """Account every configuration the search actually priced in
    `obs.metrics` (``pms.configs_evaluated``) — the parity tests assert this
    stays at zero on a warm autotune-cache hit."""
    from ..obs import metrics as _metrics  # deferred: keep core leaf-light

    _metrics.counter(
        "pms.configs_evaluated", kernel=kernel, sharded=str(sharded).lower()
    ).inc(n)
    _metrics.counter(
        "pms.searches", kernel=kernel, sharded=str(sharded).lower()
    ).inc()


def _kernel_times(
    cfg: MemoryControllerConfig,
    rank: int,
    nblocks: int,
    fills: dict[str, int],
    spec: TPUSpec,
    n_in: int = 2,
    *,
    tile_i: int | None = None,
    in_tiles: tuple[int, ...] | None = None,
    blk: int | None = None,
) -> tuple[float, float, float, float]:
    """Roofline terms.  Tile/block geometry defaults to the controller
    configuration; predict_from_plan overrides it with the *plan's* measured
    geometry so 'exact' estimates stay exact when a plan was built with
    different tiles than cfg describes."""
    rp = _rank_padded(rank)
    c, r = cfg.cache, cfg.remapper
    tile_i = c.tile_i if tile_i is None else tile_i
    in_tiles = c.input_tiles(n_in) if in_tiles is None else in_tiles
    blk = cfg.dma.blk if blk is None else blk
    # stream: value + N local index vectors (output + N-1 inputs), element
    # widths from the Remapper configuration (not hardcoded 4-byte literals)
    stream_bytes = nblocks * blk * (r.value_bytes + (n_in + 1) * r.index_bytes)
    factor_bytes = (
        sum(fills[chr(ord("B") + n)] * t for n, t in enumerate(in_tiles))
        * rp
        * r.value_bytes
    )
    out_bytes = fills["A"] * tile_i * rp * r.value_bytes
    # one-hot segment matmul (TI x blk)@(blk x Rp) + hadamard/gather vector
    # work (one multiply+gather pair per input mode)
    flops = nblocks * (2 * tile_i * blk * rp + (2 + 2 * n_in) * blk * rp)
    return (
        stream_bytes / spec.hbm_bw,
        factor_bytes / spec.hbm_bw,
        out_bytes / spec.hbm_bw,
        flops / spec.peak_flops_f32,
    )


def predict_from_plan(plan: BlockPlan, rank: int, cfg: MemoryControllerConfig, spec: TPUSpec = TPUSpec()) -> PMSEstimate:
    """Exact PMS terms from a built memory layout (measured fills/padding)."""
    fills = plan.tile_fills()
    n_in = plan.n_in
    ts, tf, to, tc = _kernel_times(
        cfg, rank, plan.nblocks, fills, spec, n_in=n_in,
        tile_i=plan.tile_i, in_tiles=plan.in_tiles, blk=plan.blk,
    )
    return PMSEstimate(
        cfg=cfg,
        t_stream=ts,
        t_factor=tf,
        t_out=to,
        t_compute=tc,
        vmem_bytes=cfg.vmem_bytes(_rank_padded(rank), n_in=n_in),
        nblocks=plan.nblocks,
        padding_fraction=plan.padding_fraction(),
    )


def _ttmc_kernel_times(
    cfg: MemoryControllerConfig,
    in_ranks: tuple[int, ...],
    nblocks: int,
    fills: dict[str, int],
    spec: TPUSpec,
    *,
    tile_i: int | None = None,
    in_tiles: tuple[int, ...] | None = None,
    blk: int | None = None,
) -> tuple[float, float, float, float]:
    """Roofline terms for the TTM-chain kernel.  Same stream model as MTTKRP
    (the BlockPlan layout is shared); the factor term pays each input mode's
    own lane padding, the output term pays the core-tensor slice width
    Pp = cols_padded(prod(in_ranks)), and compute adds the Kronecker-chain
    widening (one (blk, P_k) elementwise multiply per input mode) on top of
    the one-hot segment matmul."""
    n_in = len(in_ranks)
    pp = _rank_padded(math.prod(in_ranks))
    c, r = cfg.cache, cfg.remapper
    tile_i = c.tile_i if tile_i is None else tile_i
    in_tiles = c.input_tiles(n_in) if in_tiles is None else in_tiles
    blk = cfg.dma.blk if blk is None else blk
    stream_bytes = nblocks * blk * (r.value_bytes + (n_in + 1) * r.index_bytes)
    factor_bytes = (
        sum(
            fills[chr(ord("B") + n)] * t * _rank_padded(rk)
            for n, (t, rk) in enumerate(zip(in_tiles, in_ranks))
        )
        * r.value_bytes
    )
    out_bytes = fills["A"] * tile_i * pp * r.value_bytes
    # Kronecker chain: after input mode k the per-element row is prod(R_1..R_k)
    # wide; each widening step is one multiply per produced element (+ the
    # gather), then the one-hot segment matmul runs at the padded width.
    widen = 0
    p_k = 1
    for rk in in_ranks:
        p_k *= rk
        widen += 2 * p_k
    flops = nblocks * (2 * tile_i * blk * pp + blk * widen)
    return (
        stream_bytes / spec.hbm_bw,
        factor_bytes / spec.hbm_bw,
        out_bytes / spec.hbm_bw,
        flops / spec.peak_flops_f32,
    )


def _ttmc_in_ranks(core_ranks: Sequence[int], mode: int) -> tuple[int, ...]:
    return tuple(int(r) for m, r in enumerate(core_ranks) if m != mode)


def _ttmc_vmem(cfg: MemoryControllerConfig, in_ranks: tuple[int, ...]) -> int:
    return cfg.vmem_bytes_ttmc(
        _rank_padded(math.prod(in_ranks)), tuple(_rank_padded(r) for r in in_ranks)
    )


def predict_ttmc(
    plan: BlockPlan,
    core_ranks: Sequence[int],
    cfg: MemoryControllerConfig,
    spec: TPUSpec = TPUSpec(),
) -> PMSEstimate:
    """Exact PMS terms for the TTM-chain kernel from a built memory layout
    (measured fills/padding; the layout is the same one MTTKRP uses)."""
    in_ranks = tuple(int(core_ranks[m]) for m in plan.in_modes)
    fills = plan.tile_fills()
    ts, tf, to, tc = _ttmc_kernel_times(
        cfg, in_ranks, plan.nblocks, fills, spec,
        tile_i=plan.tile_i, in_tiles=plan.in_tiles, blk=plan.blk,
    )
    return PMSEstimate(
        cfg=cfg,
        t_stream=ts,
        t_factor=tf,
        t_out=to,
        t_compute=tc,
        vmem_bytes=_ttmc_vmem(cfg, in_ranks),
        nblocks=plan.nblocks,
        padding_fraction=plan.padding_fraction(),
    )


def _expected_occupied(bins: float, balls: float) -> float:
    """E[# occupied bins] for `balls` uniform balls in `bins` bins."""
    if bins <= 1:
        return 1.0
    return bins * (1.0 - math.exp(-balls / bins))


def _analytic_layout(
    hs: HypergraphStats, mode: int, cfg: MemoryControllerConfig
) -> tuple[int, dict[str, int], float]:
    """Balls-in-bins occupancy estimate of the BlockPlan geometry — shared by
    the MTTKRP and TTMc analytic predictors (the group structure depends only
    on the layout, not the kernel).  Returns (nblocks, fills, padding)."""
    in_modes = [m for m in range(hs.nmodes) if m != mode]
    n_in = len(in_modes)
    c, d = cfg.cache, cfg.dma
    in_tiles = c.input_tiles(n_in)
    n_it = math.ceil(hs.shape[mode] / c.tile_i)
    n_ins = [math.ceil(hs.shape[m] / t) for m, t in zip(in_modes, in_tiles)]

    groups = _expected_occupied(n_it * math.prod(n_ins), hs.nnz)
    # each occupied tile-id group costs >= 1 block; remaining nnz fill blocks
    nblocks = int(groups + hs.nnz / d.blk)
    fills = {"A": _expected_occupied(n_it, hs.nnz)}
    for n in range(n_in):
        fills[chr(ord("B") + n)] = groups  # each id changes at most once/group
    fills = {k: int(max(1, v)) for k, v in fills.items()}
    padding = max(0.0, 1.0 - hs.nnz / float(nblocks * d.blk))
    return nblocks, fills, padding


def predict_ttmc_analytic(
    hs: HypergraphStats,
    mode: int,
    core_ranks: Sequence[int],
    cfg: MemoryControllerConfig,
    spec: TPUSpec = TPUSpec(),
) -> PMSEstimate:
    """Analytic TTMc PMS: the shared occupancy model (`_analytic_layout`)
    with TTMc roofline terms."""
    in_ranks = _ttmc_in_ranks(core_ranks, mode)
    nblocks, fills, padding = _analytic_layout(hs, mode, cfg)
    ts, tf, to, tc = _ttmc_kernel_times(cfg, in_ranks, nblocks, fills, spec)
    return PMSEstimate(
        cfg=cfg,
        t_stream=ts,
        t_factor=tf,
        t_out=to,
        t_compute=tc,
        vmem_bytes=_ttmc_vmem(cfg, in_ranks),
        nblocks=nblocks,
        padding_fraction=padding,
    )


def _tt_pairs(
    core_ranks: Sequence[int], nmodes: int, mode: int
) -> tuple[tuple[tuple[int, int], ...], tuple[int, int]]:
    """Per-core (rl, rr) bond pairs from the N-1 interior TT ranks, split
    into the input pairs (ascending in_modes order — the first `mode` of
    them chain from the left) and the output mode's own pair."""
    tr = tuple(int(r) for r in core_ranks)
    bounds = (1,) + tr + (1,)
    pairs = tuple((bounds[k], bounds[k + 1]) for k in range(nmodes))
    in_pairs = tuple(p for m, p in enumerate(pairs) if m != mode)
    return in_pairs, pairs[mode]


def _tt_iface_cols(in_pairs: tuple[tuple[int, int], ...], n_left: int) -> int:
    """Widest live columns of the two interface-chain scratch vectors: the
    left chain's intermediates are (blk, rr_k) wide, the right chain's
    (blk, rl_k); both start at width 1."""
    left = max([1] + [p[1] for p in in_pairs[:n_left]])
    right = max([1] + [p[0] for p in in_pairs[n_left:]])
    return left + right


def _tt_vmem(
    cfg: MemoryControllerConfig,
    in_pairs: tuple[tuple[int, int], ...],
    out_pair: tuple[int, int],
    n_left: int,
) -> int:
    return cfg.vmem_bytes_tt(
        _rank_padded(out_pair[0] * out_pair[1]),
        tuple(_rank_padded(a * b) for a, b in in_pairs),
        _tt_iface_cols(in_pairs, n_left),
    )


def _tt_kernel_times(
    cfg: MemoryControllerConfig,
    in_pairs: tuple[tuple[int, int], ...],
    out_pair: tuple[int, int],
    n_left: int,
    nblocks: int,
    fills: dict[str, int],
    spec: TPUSpec,
    *,
    tile_i: int | None = None,
    in_tiles: tuple[int, ...] | None = None,
    blk: int | None = None,
) -> tuple[float, float, float, float]:
    """Roofline terms for the TT-core kernel.  Same stream model as MTTKRP /
    TTMc (the BlockPlan layout is shared); the factor term pays each core
    interface's own lane padding rank_padded(rl_k*rr_k), the output term the
    rank_padded(rl_m*rr_m) accumulator width, and compute replaces the
    Kronecker-chain widening with the two interface chains (one (rl, rr)
    matrix-vector product per input core) plus the final Kronecker of two."""
    n_in = len(in_pairs)
    out_cols = out_pair[0] * out_pair[1]
    pp = _rank_padded(out_cols)
    c, r = cfg.cache, cfg.remapper
    tile_i = c.tile_i if tile_i is None else tile_i
    in_tiles = c.input_tiles(n_in) if in_tiles is None else in_tiles
    blk = cfg.dma.blk if blk is None else blk
    stream_bytes = nblocks * blk * (r.value_bytes + (n_in + 1) * r.index_bytes)
    factor_bytes = (
        sum(
            fills[chr(ord("B") + n)] * t * _rank_padded(a * b)
            for n, (t, (a, b)) in enumerate(zip(in_tiles, in_pairs))
        )
        * r.value_bytes
    )
    out_bytes = fills["A"] * tile_i * pp * r.value_bytes
    # Interface chains: folding core k into a chain vector is a (rl_k, rr_k)
    # matrix-vector product (2*rl*rr flops per element); the Kronecker of
    # the two finished interfaces plus the value scale adds 2*out_cols; the
    # one-hot segment matmul then runs at the padded width.
    chain = sum(2 * a * b for a, b in in_pairs) + 2 * out_cols
    flops = nblocks * (2 * tile_i * blk * pp + blk * chain)
    return (
        stream_bytes / spec.hbm_bw,
        factor_bytes / spec.hbm_bw,
        out_bytes / spec.hbm_bw,
        flops / spec.peak_flops_f32,
    )


def predict_tt(
    plan: BlockPlan,
    core_ranks: Sequence[int],
    cfg: MemoryControllerConfig,
    spec: TPUSpec = TPUSpec(),
) -> PMSEstimate:
    """Exact PMS terms for the TT-core kernel from a built memory layout
    (measured fills/padding; the layout is the same one MTTKRP uses).
    `core_ranks` are the N-1 INTERIOR TT bond ranks."""
    nmodes = plan.n_in + 1
    in_pairs, out_pair = _tt_pairs(core_ranks, nmodes, plan.mode)
    n_left = plan.mode
    fills = plan.tile_fills()
    ts, tf, to, tc = _tt_kernel_times(
        cfg, in_pairs, out_pair, n_left, plan.nblocks, fills, spec,
        tile_i=plan.tile_i, in_tiles=plan.in_tiles, blk=plan.blk,
    )
    return PMSEstimate(
        cfg=cfg,
        t_stream=ts,
        t_factor=tf,
        t_out=to,
        t_compute=tc,
        vmem_bytes=_tt_vmem(cfg, in_pairs, out_pair, n_left),
        nblocks=plan.nblocks,
        padding_fraction=plan.padding_fraction(),
    )


def predict_tt_analytic(
    hs: HypergraphStats,
    mode: int,
    core_ranks: Sequence[int],
    cfg: MemoryControllerConfig,
    spec: TPUSpec = TPUSpec(),
) -> PMSEstimate:
    """Analytic TT-core PMS: the shared occupancy model (`_analytic_layout`)
    with TT roofline terms.  `core_ranks` are the N-1 interior TT ranks."""
    in_pairs, out_pair = _tt_pairs(core_ranks, hs.nmodes, mode)
    n_left = mode
    nblocks, fills, padding = _analytic_layout(hs, mode, cfg)
    ts, tf, to, tc = _tt_kernel_times(
        cfg, in_pairs, out_pair, n_left, nblocks, fills, spec
    )
    return PMSEstimate(
        cfg=cfg,
        t_stream=ts,
        t_factor=tf,
        t_out=to,
        t_compute=tc,
        vmem_bytes=_tt_vmem(cfg, in_pairs, out_pair, n_left),
        nblocks=nblocks,
        padding_fraction=padding,
    )


def predict_analytic(
    hs: HypergraphStats,
    mode: int,
    rank: int,
    cfg: MemoryControllerConfig,
    spec: TPUSpec = TPUSpec(),
) -> PMSEstimate:
    """Analytic PMS: no plan construction.  Estimates group structure with a
    balls-in-bins occupancy model (skew makes it conservative: skewed tensors
    have fewer, hotter groups, i.e. fewer fills than predicted)."""
    n_in = hs.nmodes - 1
    nblocks, fills, padding = _analytic_layout(hs, mode, cfg)
    ts, tf, to, tc = _kernel_times(cfg, rank, nblocks, fills, spec, n_in=n_in)
    return PMSEstimate(
        cfg=cfg,
        t_stream=ts,
        t_factor=tf,
        t_out=to,
        t_compute=tc,
        vmem_bytes=cfg.vmem_bytes(_rank_padded(rank), n_in=n_in),
        nblocks=nblocks,
        padding_fraction=padding,
    )


DEFAULT_TILE_CHOICES: tuple[int, ...] = (128, 256, 512, 1024)
DEFAULT_BLK_CHOICES: tuple[int, ...] = (128, 256, 512, 1024)


def _validate_kernel_args(kernel: str, core_ranks, nmodes: int) -> None:
    """Shared argument contract of every per-kernel PMS entry point."""
    if kernel not in ("mttkrp", "ttmc", "tt"):
        raise ValueError(
            f"unknown kernel {kernel!r}: expected 'mttkrp', 'ttmc' or 'tt'"
        )
    if kernel == "ttmc":
        if core_ranks is None:
            raise ValueError("kernel='ttmc' requires core_ranks (the full N-tuple)")
        if len(core_ranks) != nmodes:
            raise ValueError(
                f"core_ranks has {len(core_ranks)} entries for a "
                f"{nmodes}-mode tensor (pass the full N-tuple, not the "
                f"N-1 input ranks)"
            )
    if kernel == "tt":
        if core_ranks is None:
            raise ValueError(
                "kernel='tt' requires core_ranks (the N-1 interior TT ranks)"
            )
        if len(core_ranks) != nmodes - 1:
            raise ValueError(
                f"core_ranks has {len(core_ranks)} entries for a "
                f"{nmodes}-mode tensor (pass the N-1 interior TT ranks, "
                f"not per-mode ranks)"
            )


def _search_kernel_ranks(kernel: str, core_ranks, nmodes: int, mode: int):
    """The kernel-specific rank payload `_feasible_configs` consumes: TTMc's
    input-rank tuple, TT's `(in_pairs, out_pair, n_left)` triple (n_left ==
    mode: plan.in_modes is ascending), None for MTTKRP."""
    if kernel == "ttmc":
        return _ttmc_in_ranks(core_ranks, mode)
    if kernel == "tt":
        in_pairs, out_pair = _tt_pairs(core_ranks, nmodes, mode)
        return (in_pairs, out_pair, mode)
    return None


def _feasible_configs(
    n_in: int,
    rank: int,
    spec: TPUSpec,
    tile_choices: Sequence[int],
    blk_choices: Sequence[int],
    kernel: str,
    kernel_ranks,
):
    """The one enumeration of the controller design space, pruned by the
    per-kernel VMEM-fit constraint — `search` and `search_sharded` both
    consume this, so they always explore the identical candidate grid.
    `kernel_ranks` is the kernel-specific rank payload: the input-rank tuple
    for 'ttmc', the `(in_pairs, out_pair, n_left)` triple for 'tt', unused
    for 'mttkrp'."""
    for ti, tj, tk, blk in itertools.product(
        tile_choices, tile_choices, tile_choices, blk_choices
    ):
        cfg = MemoryControllerConfig(
            cache=CacheEngineConfig(tile_i=ti, tile_j=tj, tile_k=tk),
            dma=DMAEngineConfig(blk=blk),
        )
        if kernel == "ttmc":
            in_ranks = kernel_ranks
            fits = cfg.fits_ttmc(
                spec,
                _rank_padded(math.prod(in_ranks)),
                tuple(_rank_padded(r) for r in in_ranks),
            )
        elif kernel == "tt":
            in_pairs, out_pair, n_left = kernel_ranks
            fits = cfg.fits_tt(
                spec,
                _rank_padded(out_pair[0] * out_pair[1]),
                tuple(_rank_padded(a * b) for a, b in in_pairs),
                _tt_iface_cols(in_pairs, n_left),
            )
        else:
            fits = cfg.fits(spec, _rank_padded(rank), n_in=n_in)
        if fits:
            yield cfg


def search(
    st_or_stats: SparseTensor | HypergraphStats,
    mode: int,
    rank: int,
    *,
    spec: TPUSpec = TPUSpec(),
    tile_choices: Sequence[int] = DEFAULT_TILE_CHOICES,
    blk_choices: Sequence[int] = DEFAULT_BLK_CHOICES,
    exact: bool = False,
    top_k: int = 5,
    kernel: str = "mttkrp",
    core_ranks: Sequence[int] | None = None,
) -> list[PMSEstimate]:
    """Exhaustive module-by-module parameter search (paper Sec. 5.3), pruned
    by the VMEM-fit constraint.  exact=True builds a BlockPlan per candidate
    (accurate, slower) — use for final configuration of a dataset domain.

    kernel: 'mttkrp' (CP-ALS, scored at `rank`), 'ttmc' (Tucker HOOI,
    scored at `core_ranks` — the full N-tuple; `rank` is ignored) or 'tt'
    (TT-ALS, scored at `core_ranks` — the N-1 interior TT bond ranks).  The
    search tunes the controller *per kernel*: TTMc's core-tensor output tile,
    TT's two-interface scratch, and the per-factor lane paddings change both
    the VMEM constraint and the roofline, so the best configuration generally
    differs between kernels."""
    spec = resolve_spec(spec)
    if isinstance(st_or_stats, SparseTensor):
        hs = hg_stats(st_or_stats)
        st = st_or_stats
    else:
        hs, st = st_or_stats, None
        exact = False
    _validate_kernel_args(kernel, core_ranks, hs.nmodes)
    n_in = hs.nmodes - 1
    kernel_ranks = _search_kernel_ranks(kernel, core_ranks, hs.nmodes, mode)

    results: list[PMSEstimate] = []
    for cfg in _feasible_configs(
        n_in, rank, spec, tile_choices, blk_choices, kernel, kernel_ranks
    ):
        if exact and st is not None:
            plan = plan_blocks(
                st, mode, tile_i=cfg.cache.tile_i, blk=cfg.dma.blk,
                in_tiles=cfg.cache.input_tiles(n_in),
            )
            if kernel == "ttmc":
                results.append(predict_ttmc(plan, core_ranks, cfg, spec))
            elif kernel == "tt":
                results.append(predict_tt(plan, core_ranks, cfg, spec))
            else:
                results.append(predict_from_plan(plan, rank, cfg, spec))
        elif kernel == "ttmc":
            results.append(predict_ttmc_analytic(hs, mode, core_ranks, cfg, spec))
        elif kernel == "tt":
            results.append(predict_tt_analytic(hs, mode, core_ranks, cfg, spec))
        else:
            results.append(predict_analytic(hs, mode, rank, cfg, spec))
    _count_configs(kernel, len(results))
    results.sort(key=lambda e: e.t_total)
    return results[:top_k]


# ---------------------------------------------------------------------------
# Sharded PMS: score a configuration by its worst shard (parallel makespan)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedPMSEstimate:
    """PMS estimate for the distributed planned path: the stream is
    partitioned into `nshards` balanced output-tile ranges
    (dist/sharding.partition_stream) and every shard runs the kernel on its
    own device, so wall-clock is the *makespan* — the slowest shard, not the
    sum.  `t_total` therefore reports max over shards; the collective's
    `I_out*R` all-reduce is shared by every configuration of the same rank
    and does not reorder candidates, so it is not modeled here."""

    cfg: MemoryControllerConfig
    per_shard: tuple[PMSEstimate, ...]
    shard_nnz: tuple[int, ...]

    @property
    def nshards(self) -> int:
        return len(self.per_shard)

    @property
    def t_total(self) -> float:
        """Parallel makespan: the slowest shard's roofline time."""
        return max(e.t_total for e in self.per_shard)

    @property
    def critical_shard(self) -> int:
        """Index of the shard that sets the makespan."""
        ts = [e.t_total for e in self.per_shard]
        return ts.index(max(ts))

    @property
    def vmem_bytes(self) -> int:
        """Per-device VMEM footprint (identical across shards: one cfg)."""
        return self.per_shard[0].vmem_bytes

    @property
    def imbalance(self) -> float:
        """max / mean shard nnz (1.0 = perfectly balanced partition)."""
        from ..dist.sharding import stream_imbalance

        return stream_imbalance(self.shard_nnz)

    @property
    def bottleneck(self) -> str:
        return self.per_shard[self.critical_shard].bottleneck


def _empty_shard_estimate(
    cfg: MemoryControllerConfig,
    rank: int,
    n_in: int,
    kernel: str,
    kernel_ranks,
) -> PMSEstimate:
    """Zero-cost estimate for a shard that owns no non-zeros (its kernel
    streams one all-padding block; negligible against any real shard)."""
    if kernel == "ttmc":
        vmem = _ttmc_vmem(cfg, kernel_ranks)
    elif kernel == "tt":
        vmem = _tt_vmem(cfg, *kernel_ranks)
    else:
        vmem = cfg.vmem_bytes(_rank_padded(rank), n_in=n_in)
    return PMSEstimate(
        cfg=cfg, t_stream=0.0, t_factor=0.0, t_out=0.0, t_compute=0.0,
        vmem_bytes=vmem, nblocks=0, padding_fraction=0.0,
    )


def _shard_estimate(
    shard: SparseTensor,
    hs: HypergraphStats | None,
    mode: int,
    rank: int,
    cfg: MemoryControllerConfig,
    spec: TPUSpec,
    kernel: str,
    core_ranks: Sequence[int] | None,
    exact: bool,
) -> PMSEstimate:
    n_in = shard.nmodes - 1
    if shard.nnz == 0:
        kernel_ranks = _search_kernel_ranks(kernel, core_ranks, shard.nmodes, mode)
        return _empty_shard_estimate(cfg, rank, n_in, kernel, kernel_ranks)
    if exact:
        plan = plan_blocks(
            shard, mode, tile_i=cfg.cache.tile_i, blk=cfg.dma.blk,
            in_tiles=cfg.cache.input_tiles(n_in),
        )
        if kernel == "ttmc":
            return predict_ttmc(plan, core_ranks, cfg, spec)
        if kernel == "tt":
            return predict_tt(plan, core_ranks, cfg, spec)
        return predict_from_plan(plan, rank, cfg, spec)
    hs = hs if hs is not None else hg_stats(shard)
    if kernel == "ttmc":
        return predict_ttmc_analytic(hs, mode, core_ranks, cfg, spec)
    if kernel == "tt":
        return predict_tt_analytic(hs, mode, core_ranks, cfg, spec)
    return predict_analytic(hs, mode, rank, cfg, spec)


def predict_sharded(
    st: SparseTensor,
    mode: int,
    rank: int,
    nshards: int,
    cfg: MemoryControllerConfig,
    *,
    spec: TPUSpec = TPUSpec(),
    kernel: str = "mttkrp",
    core_ranks: Sequence[int] | None = None,
    exact: bool = True,
) -> ShardedPMSEstimate:
    """PMS terms for one configuration of the sharded planned path: the
    stream is partitioned exactly as the workspace builder partitions it
    (balanced nnz, tile_i-aligned) and each shard is scored independently —
    exact=True builds every shard's BlockPlan (measured fills), exact=False
    uses the analytic occupancy model per shard (conservative: it spreads
    each shard's nnz over the *global* tile space, overestimating fills)."""
    _validate_kernel_args(kernel, core_ranks, st.nmodes)
    from ..dist.sharding import partition_stream

    part = partition_stream(st, mode, nshards, tile=cfg.cache.tile_i)
    ests = tuple(
        _shard_estimate(sh, None, mode, rank, cfg, spec, kernel, core_ranks, exact)
        for sh in part.shards
    )
    return ShardedPMSEstimate(cfg=cfg, per_shard=ests, shard_nnz=part.shard_nnz)


def search_sharded(
    st: SparseTensor,
    mode: int,
    rank: int,
    nshards: int,
    *,
    spec: TPUSpec = TPUSpec(),
    tile_choices: Sequence[int] = DEFAULT_TILE_CHOICES,
    blk_choices: Sequence[int] = DEFAULT_BLK_CHOICES,
    exact: bool = False,
    top_k: int = 5,
    kernel: str = "mttkrp",
    core_ranks: Sequence[int] | None = None,
) -> list[ShardedPMSEstimate]:
    """`search`, distributed: rank every VMEM-feasible configuration by the
    time of its *worst shard* — a configuration that wins on the balanced
    average can lose on the critical shard, and the critical shard is what
    the shard_map sweep waits for (the makespan).  Partitions (and per-shard
    hypergraph stats) are cached per tile_i, since the split depends only on
    the output tile granularity."""
    spec = resolve_spec(spec)
    _validate_kernel_args(kernel, core_ranks, st.nmodes)
    from ..dist.sharding import partition_stream

    n_in = st.nmodes - 1
    kernel_ranks = _search_kernel_ranks(kernel, core_ranks, st.nmodes, mode)
    parts: dict[int, tuple] = {}  # tile_i -> (partition, per-shard stats)
    results: list[ShardedPMSEstimate] = []
    for cfg in _feasible_configs(
        n_in, rank, spec, tile_choices, blk_choices, kernel, kernel_ranks
    ):
        ti = cfg.cache.tile_i
        if ti not in parts:
            part = partition_stream(st, mode, nshards, tile=ti)
            sstats = [hg_stats(s) if s.nnz else None for s in part.shards]
            parts[ti] = (part, sstats)
        part, sstats = parts[ti]
        ests = tuple(
            _shard_estimate(sh, hs, mode, rank, cfg, spec, kernel, core_ranks, exact)
            for sh, hs in zip(part.shards, sstats)
        )
        results.append(
            ShardedPMSEstimate(cfg=cfg, per_shard=ests, shard_nnz=part.shard_nnz)
        )
    _count_configs(kernel, len(results), sharded=True)
    results.sort(key=lambda e: e.t_total)
    return results[:top_k]
