"""Tensor Remapper (paper Alg. 5 + Sec. 5.1.3), adapted to TPU.

The paper remaps (re-sorts) the non-zero stream into the *output mode's*
order before each mode's MTTKRP, so Approach 1 (no DRAM partial sums) applies
to every mode with a single tensor copy.  The FPGA mechanism is a table of
per-output-coordinate *address pointers* (a counting sort); when the table
exceeds on-chip memory the paper flags it as a key design problem.

TPU adaptation:
  * `remap_stable`           — XLA stable sort (production path, jittable).
  * `remap_pointer_machine`  — faithful pointer-table emulation (lax.scan FIFO,
                               one element per step) used to *validate* that the
                               sort path implements exactly the paper's mapping.
  * `remap_radix`            — hierarchical counting sort for when the pointer
                               table exceeds the budget (paper's overflow case):
                               digits of `pointer_budget` bins per pass.
  * `plan_blocks`            — two-level *tile* remap producing the Pallas
                               kernel's memory layout: blocks sorted by
                               (output tile, input tile pair) with per-block
                               metadata. This is the "ideal memory layout" of
                               Sec. 3.1 (bounded pointer table + equal-sized
                               partitions).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .coo import SparseTensor

__all__ = [
    "pointer_table",
    "remap_stable",
    "remap_pointer_machine",
    "remap_radix",
    "BlockPlan",
    "plan_blocks",
]


def pointer_table(coords: jax.Array, nbins: int) -> tuple[jax.Array, jax.Array]:
    """The paper's address-pointer table: per-bin base addresses.

    Returns (offsets, counts): offsets[b] = where bin b's first element goes
    (exclusive prefix sum of the histogram)."""
    counts = jnp.zeros((nbins,), jnp.int32).at[coords].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    return offsets, counts


@partial(jax.jit, static_argnames=("mode",))
def remap_stable(indices: jax.Array, values: jax.Array, mode: int):
    """Stable sort of the COO stream by one mode's coordinates.

    Production remap: XLA's sort is the TPU-native equivalent of the streaming
    counting sort (same output order — stability preserves the FIFO property
    the paper's weak-consistency model requires).
    Returns (indices_sorted, values_sorted, perm)."""
    perm = jnp.argsort(indices[:, mode], stable=True)
    return indices[perm], values[perm], perm


def remap_pointer_machine(indices: np.ndarray, values: np.ndarray, mode: int, nbins: int):
    """Paper-faithful Tensor Remapper emulation: stream elements one by one,
    looking up + bumping the per-output-coordinate address pointer (Alg. 5
    lines 3-6).  Host-side (numpy); used in tests to certify `remap_stable`
    produces the identical layout."""
    coords = indices[:, mode]
    counts = np.bincount(coords, minlength=nbins)
    ptr = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int64)
    out_idx = np.empty_like(indices)
    out_val = np.empty_like(values)
    for z in range(indices.shape[0]):  # the element-wise store stream
        c = coords[z]
        p = ptr[c]
        out_idx[p] = indices[z]
        out_val[p] = values[z]
        ptr[c] = p + 1
    return out_idx, out_val


@partial(jax.jit, static_argnames=("mode", "nbins", "pointer_budget"))
def remap_radix(indices: jax.Array, values: jax.Array, mode: int, nbins: int, pointer_budget: int):
    """Hierarchical remap for pointer tables larger than on-chip memory
    (paper Sec. 3.1: 10M-coordinate modes need 40 MB of pointers).

    Runs ceil(log_budget(nbins)) stable counting-sort passes, least-significant
    digit first, with at most `pointer_budget` pointers live per pass — the
    direct analogue of splitting the sort into on-chip-sized rounds."""
    ndigits = max(1, math.ceil(math.log(max(nbins, 2)) / math.log(pointer_budget)))
    coords = indices[:, mode]
    order = jnp.arange(coords.shape[0])
    key = coords
    for _ in range(ndigits):
        digit = key % pointer_budget
        p = jnp.argsort(digit, stable=True)  # counting-sort pass with <= budget bins
        order = order[p]
        key = key[p] // pointer_budget
    return indices[order], values[order], order


# ---------------------------------------------------------------------------
# Tile-level block plan for the Pallas kernel (the "memory layout")
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BlockPlan:
    """Kernel memory layout: the remapped non-zero stream plus per-block tile
    metadata.  All arrays host-side numpy; `to_device` happens in ops.py.

    Layout contract (consumed by kernels/mttkrp_pallas.py):
      * non-zeros are grouped into blocks of `blk` elements;
      * blocks are sorted by (output tile, then input tile pair) — Approach 1
        at tile granularity, so each output tile's blocks are contiguous;
      * within a block every element's coordinates fall inside the block's
        (it, jt, kt) tiles; local indices are precomputed;
      * padding elements have value 0 (and local index 0).
    """

    vals: np.ndarray  # (nblocks*blk,) f32
    iloc: np.ndarray  # (nblocks*blk,) int32 — output-row index within tile
    jloc: np.ndarray  # (nblocks*blk,) int32
    kloc: np.ndarray  # (nblocks*blk,) int32
    block_it: np.ndarray  # (nblocks,) int32
    block_jt: np.ndarray  # (nblocks,) int32
    block_kt: np.ndarray  # (nblocks,) int32
    tile_i: int
    tile_j: int
    tile_k: int
    blk: int
    out_rows: int  # padded I_out (multiple of tile_i)
    rows_j: int  # padded I_j
    rows_k: int  # padded I_k
    mode: int
    in_modes: tuple[int, int]
    nnz: int  # true nnz before padding

    @property
    def nblocks(self) -> int:
        return self.block_it.shape[0]

    # --- locality statistics (feed the PMS / Cache-Engine model) ---
    def tile_fills(self) -> dict[str, int]:
        """Number of HBM->VMEM tile fetches Pallas will issue: a tile is
        re-fetched only when the block's tile id *changes* between consecutive
        grid steps (Pallas skips the copy when the index map is unchanged —
        the run-length structure of the plan IS the cache)."""

        def fills(ids: np.ndarray) -> int:
            if ids.size == 0:
                return 0
            return int(1 + np.count_nonzero(ids[1:] != ids[:-1]))

        return {
            "A": fills(self.block_it),
            "B": fills(self.block_jt),
            "C": fills(self.block_kt),
        }

    def padding_fraction(self) -> float:
        return 1.0 - self.nnz / float(self.vals.shape[0]) if self.vals.size else 0.0

    def a_tile_single_flush(self) -> bool:
        """Approach-1 invariant: each output tile's blocks are contiguous."""
        it = self.block_it
        seen_last = {}
        for pos, t in enumerate(it):
            if t in seen_last and seen_last[t] != pos - 1:
                return False
            seen_last[t] = pos
        return True


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def plan_blocks(
    st: SparseTensor,
    mode: int,
    *,
    tile_i: int = 256,
    tile_j: int = 256,
    tile_k: int = 256,
    blk: int = 256,
) -> BlockPlan:
    """Two-level tile remap (host-side preprocessing == the Tensor Remapper +
    memory-layout generator).  3-mode tensors only — the Pallas kernel is the
    3-mode hot path; N-mode tensors use the pure-JAX path (core/mttkrp.py)."""
    assert st.nmodes == 3, "kernel block plan supports 3-mode tensors"
    in_modes = tuple(m for m in range(3) if m != mode)
    i = st.indices[:, mode].astype(np.int64)
    j = st.indices[:, in_modes[0]].astype(np.int64)
    k = st.indices[:, in_modes[1]].astype(np.int64)
    v = st.values

    it, jt, kt = i // tile_i, j // tile_j, k // tile_k
    # Remap: sort by (output tile, input tile pair). lexsort's last key is
    # primary. Stable => preserves prior order within a tile triple.
    order = np.lexsort((kt, jt, it))
    i, j, k, v = i[order], j[order], k[order], v[order]
    it, jt, kt = it[order], jt[order], kt[order]

    # Group boundaries over identical (it, jt, kt) triples.
    key = (it * ((max(st.shape[in_modes[0]] // tile_j, 0)) + 2) + jt) * (
        (st.shape[in_modes[1]] // tile_k) + 2
    ) + kt
    boundaries = np.flatnonzero(np.concatenate([[True], key[1:] != key[:-1]]))
    group_sizes = np.diff(np.concatenate([boundaries, [key.size]]))

    # Pad each group to a multiple of blk and emit per-block metadata.
    padded_sizes = np.maximum(_ceil_to(1, blk), ((group_sizes + blk - 1) // blk) * blk)
    total = int(padded_sizes.sum())
    nblocks = total // blk

    vals = np.zeros((total,), np.float32)
    iloc = np.zeros((total,), np.int32)
    jloc = np.zeros((total,), np.int32)
    kloc = np.zeros((total,), np.int32)
    block_it = np.empty((nblocks,), np.int32)
    block_jt = np.empty((nblocks,), np.int32)
    block_kt = np.empty((nblocks,), np.int32)

    src = 0
    dst = 0
    b = 0
    for g, (gsize, psize) in enumerate(zip(group_sizes, padded_sizes)):
        s, e = src, src + gsize
        vals[dst : dst + gsize] = v[s:e]
        iloc[dst : dst + gsize] = (i[s:e] - it[s] * tile_i).astype(np.int32)
        jloc[dst : dst + gsize] = (j[s:e] - jt[s] * tile_j).astype(np.int32)
        kloc[dst : dst + gsize] = (k[s:e] - kt[s] * tile_k).astype(np.int32)
        nb = psize // blk
        block_it[b : b + nb] = it[s]
        block_jt[b : b + nb] = jt[s]
        block_kt[b : b + nb] = kt[s]
        src = e
        dst += psize
        b += nb

    return BlockPlan(
        vals=vals,
        iloc=iloc,
        jloc=jloc,
        kloc=kloc,
        block_it=block_it,
        block_jt=block_jt,
        block_kt=block_kt,
        tile_i=tile_i,
        tile_j=tile_j,
        tile_k=tile_k,
        blk=blk,
        out_rows=_ceil_to(st.shape[mode], tile_i),
        rows_j=_ceil_to(st.shape[in_modes[0]], tile_j),
        rows_k=_ceil_to(st.shape[in_modes[1]], tile_k),
        mode=mode,
        in_modes=in_modes,
        nnz=st.nnz,
    )
