"""Tensor Remapper (paper Alg. 5 + Sec. 5.1.3), adapted to TPU.

The paper remaps (re-sorts) the non-zero stream into the *output mode's*
order before each mode's MTTKRP, so Approach 1 (no DRAM partial sums) applies
to every mode with a single tensor copy.  The FPGA mechanism is a table of
per-output-coordinate *address pointers* (a counting sort); when the table
exceeds on-chip memory the paper flags it as a key design problem.

TPU adaptation:
  * `remap_stable`           — XLA stable sort (production path, jittable).
  * `remap_pointer_machine`  — faithful pointer-table emulation (lax.scan FIFO,
                               one element per step) used to *validate* that the
                               sort path implements exactly the paper's mapping.
  * `remap_radix`            — hierarchical counting sort for when the pointer
                               table exceeds the budget (paper's overflow case):
                               digits of `pointer_budget` bins per pass.
  * `plan_blocks`            — two-level *tile* remap producing the Pallas
                               kernel's memory layout: blocks sorted by
                               (output tile, input tile id tuple) with
                               per-block metadata, for any order >= 3. This is
                               the "ideal memory layout" of Sec. 3.1 (bounded
                               pointer table + equal-sized partitions).
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .coo import SparseTensor

__all__ = [
    "pointer_table",
    "remap_stable",
    "remap_pointer_machine",
    "remap_radix",
    "radix_digits",
    "BlockPlan",
    "group_key",
    "plan_blocks",
    "plan_blocks_reference",
]


def pointer_table(coords: jax.Array, nbins: int) -> tuple[jax.Array, jax.Array]:
    """The paper's address-pointer table: per-bin base addresses.

    Returns (offsets, counts): offsets[b] = where bin b's first element goes
    (exclusive prefix sum of the histogram)."""
    counts = jnp.zeros((nbins,), jnp.int32).at[coords].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    return offsets, counts


@partial(jax.jit, static_argnames=("mode",))
def remap_stable(indices: jax.Array, values: jax.Array, mode: int):
    """Stable sort of the COO stream by one mode's coordinates.

    Production remap: XLA's sort is the TPU-native equivalent of the streaming
    counting sort (same output order — stability preserves the FIFO property
    the paper's weak-consistency model requires).
    Returns (indices_sorted, values_sorted, perm)."""
    perm = jnp.argsort(indices[:, mode], stable=True)
    return indices[perm], values[perm], perm


def remap_pointer_machine(indices: np.ndarray, values: np.ndarray, mode: int, nbins: int):
    """Paper-faithful Tensor Remapper emulation: stream elements one by one,
    looking up + bumping the per-output-coordinate address pointer (Alg. 5
    lines 3-6).  Host-side (numpy); used in tests to certify `remap_stable`
    produces the identical layout."""
    coords = indices[:, mode]
    counts = np.bincount(coords, minlength=nbins)
    ptr = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int64)
    out_idx = np.empty_like(indices)
    out_val = np.empty_like(values)
    for z in range(indices.shape[0]):  # the element-wise store stream
        c = coords[z]
        p = ptr[c]
        out_idx[p] = indices[z]
        out_val[p] = values[z]
        ptr[c] = p + 1
    return out_idx, out_val


def radix_digits(nbins: int, pointer_budget: int) -> int:
    """Number of counting-sort passes so that pointer_budget**ndigits >= nbins.

    Pure integer arithmetic: the float formulation
    ceil(log(nbins)/log(budget)) is off by one at exact powers of the budget
    (log(64)/log(4) = 3.0000000000000004 -> 4 passes instead of 3)."""
    assert pointer_budget >= 2, "need at least two bins per pass"
    ndigits, span = 1, pointer_budget
    while span < nbins:
        span *= pointer_budget
        ndigits += 1
    return ndigits


@partial(jax.jit, static_argnames=("mode", "nbins", "pointer_budget"))
def remap_radix(indices: jax.Array, values: jax.Array, mode: int, nbins: int, pointer_budget: int):
    """Hierarchical remap for pointer tables larger than on-chip memory
    (paper Sec. 3.1: 10M-coordinate modes need 40 MB of pointers).

    Runs radix_digits(nbins, budget) stable counting-sort passes,
    least-significant digit first, with at most `pointer_budget` pointers live
    per pass — the direct analogue of splitting the sort into on-chip-sized
    rounds."""
    ndigits = radix_digits(max(nbins, 2), pointer_budget)
    coords = indices[:, mode]
    order = jnp.arange(coords.shape[0])
    key = coords
    for _ in range(ndigits):
        digit = key % pointer_budget
        p = jnp.argsort(digit, stable=True)  # counting-sort pass with <= budget bins
        order = order[p]
        key = key[p] // pointer_budget
    return indices[order], values[order], order


# ---------------------------------------------------------------------------
# Tile-level block plan for the Pallas kernel (the "memory layout")
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BlockPlan:
    """Kernel memory layout: the remapped non-zero stream plus per-block tile
    metadata.  All arrays host-side numpy; `to_device` happens in ops.py.

    Layout contract (consumed by kernels/mttkrp_pallas.py):
      * non-zeros are grouped into blocks of `blk` elements;
      * blocks are sorted by (output tile, then input tile id-tuple) —
        Approach 1 at tile granularity, so each output tile's blocks are
        contiguous;
      * within a block every element's coordinates fall inside the block's
        (it, t_0, ..., t_{N-2}) tiles; local indices are precomputed;
      * padding elements have value 0 (and local index 0).

    N-mode: the N-1 *input* modes each carry one tile-id stream
    (`block_in[n]`) and one local-index vector (`in_locs[n]`).  For 3-mode
    tensors the legacy `jt`/`kt` names are provided as views.
    """

    vals: np.ndarray  # (nblocks*blk,) f32
    iloc: np.ndarray  # (nblocks*blk,) int32 — output-row index within tile
    in_locs: tuple[np.ndarray, ...]  # N-1 x (nblocks*blk,) int32
    block_it: np.ndarray  # (nblocks,) int32
    block_in: tuple[np.ndarray, ...]  # N-1 x (nblocks,) int32
    tile_i: int
    in_tiles: tuple[int, ...]  # N-1 input-mode tile sizes
    blk: int
    out_rows: int  # padded I_out (multiple of tile_i)
    in_rows: tuple[int, ...]  # N-1 padded input-mode row counts
    mode: int
    in_modes: tuple[int, ...]
    nnz: int  # true nnz before padding

    @property
    def nblocks(self) -> int:
        return self.block_it.shape[0]

    @property
    def n_in(self) -> int:
        return len(self.in_modes)

    # --- 3-mode legacy views (every tensor has >= 2 input modes) ---
    @property
    def jloc(self) -> np.ndarray:
        return self.in_locs[0]

    @property
    def kloc(self) -> np.ndarray:
        return self.in_locs[1]

    @property
    def block_jt(self) -> np.ndarray:
        return self.block_in[0]

    @property
    def block_kt(self) -> np.ndarray:
        return self.block_in[1]

    @property
    def tile_j(self) -> int:
        return self.in_tiles[0]

    @property
    def tile_k(self) -> int:
        return self.in_tiles[1]

    @property
    def rows_j(self) -> int:
        return self.in_rows[0]

    @property
    def rows_k(self) -> int:
        return self.in_rows[1]

    # --- locality statistics (feed the PMS / Cache-Engine model) ---
    def tile_fills(self) -> dict[str, int]:
        """Number of HBM->VMEM tile fetches Pallas will issue: a tile is
        re-fetched only when the block's tile id *changes* between consecutive
        grid steps (Pallas skips the copy when the index map is unchanged —
        the run-length structure of the plan IS the cache).

        Keys: "A" for the output accumulator tile, then one letter per input
        mode ("B", "C", "D", "E", ...)."""

        def fills(ids: np.ndarray) -> int:
            if ids.size == 0:
                return 0
            return int(1 + np.count_nonzero(ids[1:] != ids[:-1]))

        out = {"A": fills(self.block_it)}
        for n, ids in enumerate(self.block_in):
            out[chr(ord("B") + n)] = fills(ids)
        return out

    def padding_fraction(self) -> float:
        return 1.0 - self.nnz / float(self.vals.shape[0]) if self.vals.size else 0.0

    def a_tile_single_flush(self) -> bool:
        """Approach-1 invariant: each output tile's blocks are contiguous."""
        it = self.block_it
        seen_last = {}
        for pos, t in enumerate(it):
            if t in seen_last and seen_last[t] != pos - 1:
                return False
            seen_last[t] = pos
        return True


class PlanValidationError(ValueError):
    """A BlockPlan violates its layout contract (see `validate_plan`)."""


def plans_validated() -> bool:
    """True when `REPRO_VALIDATE_PLANS` requests integrity validation of
    every plan at build time and on plan-cache hits.  Read per call, so tests
    (and tenants) can flip it without re-importing."""
    return os.environ.get("REPRO_VALIDATE_PLANS", "").strip().lower() in (
        "1", "true", "yes", "on",
    )


def validate_plan(plan: BlockPlan) -> BlockPlan:
    """Assert every BlockPlan invariant of the layout contract; raise
    `PlanValidationError` naming the first violation.  Opt-in on the hot
    paths via `REPRO_VALIDATE_PLANS=1` (`plans_validated`); always available
    directly for debugging a suspect layout.  Returns the plan for chaining.

    Invariants checked:
      * stream arrays span exactly `nblocks * blk` slots, one tile-id stream
        and one local-index vector per input mode;
      * padded row counts are tile-aligned and cover the true rows;
      * values are finite; at most `nnz` slots are non-zero (padding slots
        carry value 0) and the stream has room for all `nnz` non-zeros;
      * local indices lie inside their tile (`0 <= iloc < tile_i`,
        `0 <= in_locs[n] < in_tiles[n]`);
      * block tile ids are in range for the padded row counts;
      * Approach-1 contiguity: each output tile's blocks are contiguous
        (`a_tile_single_flush`).
    """

    def fail(msg: str):
        raise PlanValidationError(
            f"BlockPlan(mode={plan.mode}, nnz={plan.nnz}): {msg}"
        )

    n_in = plan.n_in
    if not (len(plan.in_locs) == len(plan.block_in) == len(plan.in_tiles)
            == len(plan.in_rows) == n_in):
        fail("inconsistent input-mode arity across "
             "in_locs/block_in/in_tiles/in_rows/in_modes")
    if plan.mode in plan.in_modes or len(set(plan.in_modes)) != n_in:
        fail(f"in_modes {plan.in_modes} must be distinct and exclude the "
             f"output mode {plan.mode}")
    if plan.blk < 1:
        fail(f"blk={plan.blk} must be >= 1")
    total = plan.nblocks * plan.blk
    for name, arr in (("vals", plan.vals), ("iloc", plan.iloc),
                      *((f"in_locs[{n}]", plan.in_locs[n]) for n in range(n_in))):
        if arr.shape != (total,):
            fail(f"{name} has shape {arr.shape}, expected ({total},) "
                 f"= nblocks*blk")
    for n in range(n_in):
        if plan.block_in[n].shape != (plan.nblocks,):
            fail(f"block_in[{n}] has shape {plan.block_in[n].shape}, "
                 f"expected ({plan.nblocks},)")
    if plan.out_rows % plan.tile_i != 0:
        fail(f"out_rows={plan.out_rows} not a multiple of tile_i={plan.tile_i}")
    for n in range(n_in):
        if plan.in_rows[n] % plan.in_tiles[n] != 0:
            fail(f"in_rows[{n}]={plan.in_rows[n]} not a multiple of "
                 f"in_tiles[{n}]={plan.in_tiles[n]}")
    if not np.all(np.isfinite(plan.vals)):
        fail("non-finite values in the remapped stream")
    if total < plan.nnz:
        fail(f"stream holds {total} slots but the plan claims nnz={plan.nnz}")
    nz = int(np.count_nonzero(plan.vals))
    if nz > plan.nnz:
        fail(f"{nz} non-zero slots exceed nnz={plan.nnz} — padding slots "
             f"must be zero-valued")
    if plan.iloc.size and (plan.iloc.min() < 0 or plan.iloc.max() >= plan.tile_i):
        fail(f"iloc out of tile bounds [0, {plan.tile_i}): "
             f"range [{plan.iloc.min()}, {plan.iloc.max()}]")
    for n in range(n_in):
        loc = plan.in_locs[n]
        if loc.size and (loc.min() < 0 or loc.max() >= plan.in_tiles[n]):
            fail(f"in_locs[{n}] out of tile bounds [0, {plan.in_tiles[n]}): "
                 f"range [{loc.min()}, {loc.max()}]")
    ntiles = plan.out_rows // plan.tile_i
    if plan.block_it.size and (plan.block_it.min() < 0
                               or plan.block_it.max() >= ntiles):
        fail(f"block_it out of range [0, {ntiles}): "
             f"range [{plan.block_it.min()}, {plan.block_it.max()}]")
    for n in range(n_in):
        nt = plan.in_rows[n] // plan.in_tiles[n]
        bt = plan.block_in[n]
        if bt.size and (bt.min() < 0 or bt.max() >= nt):
            fail(f"block_in[{n}] out of range [0, {nt}): "
                 f"range [{bt.min()}, {bt.max()}]")
    if not plan.a_tile_single_flush():
        fail("Approach-1 contiguity violated: an output tile's blocks are "
             "not contiguous")
    return plan


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _ceil_div(x: int, m: int) -> int:
    return max(1, (x + m - 1) // m)


def group_key(tile_cols: list[np.ndarray], tile_counts: list[int]) -> np.ndarray:
    """Mixed-radix encoding of per-mode tile ids into one collision-free
    int64 key.  `tile_counts[m]` is the explicit per-mode tile count
    (ceil(shape/tile)); every id in `tile_cols[m]` must be < tile_counts[m],
    so two distinct id tuples can never alias."""
    assert len(tile_cols) == len(tile_counts)
    radix = math.prod(int(c) for c in tile_counts)
    if radix > np.iinfo(np.int64).max:
        raise OverflowError(
            f"group_key radix {radix} overflows int64: tile counts "
            f"{tuple(tile_counts)} — use larger tiles for the big modes"
        )
    key = np.zeros_like(tile_cols[0], dtype=np.int64)
    for col, count in zip(tile_cols, tile_counts):
        assert count >= 1
        key = key * np.int64(count) + col.astype(np.int64)
    return key


def default_in_tiles(n_in: int, tile_j: int, tile_k: int) -> tuple[int, ...]:
    """Expand the legacy (tile_j, tile_k) pair to N-1 input tile sizes.
    The expansion policy lives in CacheEngineConfig.input_tiles — this is a
    convenience wrapper so plan_blocks' default never diverges from what the
    PMS scores."""
    from .memctrl import CacheEngineConfig  # local: keep remap importable alone

    return CacheEngineConfig(tile_j=tile_j, tile_k=tile_k).input_tiles(n_in)


@dataclasses.dataclass
class _GroupedStream:
    """Shared prologue of the layout build: the remap permutation plus the
    group geometry, with the stream arrays kept in *original* order.  Both
    the vectorized production build and the loop reference consume this; the
    reference gathers full sorted copies (part of its per-element cost), the
    vectorized build gathers only what it scatters."""

    order: np.ndarray  # the remap permutation (stable sort by group key)
    i: np.ndarray  # output-mode coordinates, original order (int64)
    ins: list[np.ndarray]  # input-mode coordinates, original order
    v: np.ndarray  # values, original order
    it: np.ndarray  # output tile ids, original order
    in_ts: list[np.ndarray]  # input tile ids, original order
    boundaries: np.ndarray  # first *sorted* position of each group
    group_sizes: np.ndarray
    padded_sizes: np.ndarray  # group sizes rounded up to a multiple of blk
    in_modes: tuple[int, ...]
    in_tiles: tuple[int, ...]

    @property
    def total(self) -> int:
        return int(self.padded_sizes.sum())


def _grouped_stream(
    st: SparseTensor,
    mode: int,
    tile_i: int,
    tile_j: int,
    tile_k: int,
    blk: int,
    in_tiles: tuple[int, ...] | None,
) -> _GroupedStream:
    assert st.nmodes >= 3, "kernel block plan needs >= 3-mode tensors"
    in_modes = tuple(m for m in range(st.nmodes) if m != mode)
    n_in = len(in_modes)
    if in_tiles is None:
        in_tiles = default_in_tiles(n_in, tile_j, tile_k)
    assert len(in_tiles) == n_in
    i = st.indices[:, mode].astype(np.int64)
    ins = [st.indices[:, m].astype(np.int64) for m in in_modes]
    v = st.values

    it = i // tile_i
    in_ts = [c // t for c, t in zip(ins, in_tiles)]
    # Remap: sort by (output tile, input tile tuple).  The collision-free
    # mixed-radix group key IS that tuple in lexicographic order, so one
    # stable argsort on it replaces an N-key lexsort (~2x cheaper) while
    # producing the identical permutation; stability preserves prior order
    # within a tile tuple.  Explicit per-mode tile counts keep the key
    # collision-free.
    n_tiles = [_ceil_div(st.shape[mode], tile_i)] + [
        _ceil_div(st.shape[m], t) for m, t in zip(in_modes, in_tiles)
    ]
    key = group_key([it] + in_ts, n_tiles)
    order = np.argsort(key, kind="stable")
    key_sorted = key[order]

    # Group boundaries over identical (it, t_0, ..., t_{N-2}) tuples.
    boundaries = np.flatnonzero(
        np.concatenate([[True], key_sorted[1:] != key_sorted[:-1]])
    )
    group_sizes = np.diff(np.concatenate([boundaries, [key_sorted.size]]))
    padded_sizes = np.maximum(_ceil_to(1, blk), ((group_sizes + blk - 1) // blk) * blk)
    return _GroupedStream(
        order=order,
        i=i,
        ins=ins,
        v=v,
        it=it,
        in_ts=in_ts,
        boundaries=boundaries,
        group_sizes=group_sizes,
        padded_sizes=padded_sizes,
        in_modes=in_modes,
        in_tiles=tuple(in_tiles),
    )


def _assemble_plan(
    st: SparseTensor,
    mode: int,
    g: _GroupedStream,
    tile_i: int,
    blk: int,
    vals: np.ndarray,
    iloc: np.ndarray,
    in_locs: list[np.ndarray],
    block_it: np.ndarray,
    block_in: list[np.ndarray],
) -> BlockPlan:
    plan = BlockPlan(
        vals=vals,
        iloc=iloc,
        in_locs=tuple(in_locs),
        block_it=block_it,
        block_in=tuple(block_in),
        tile_i=tile_i,
        in_tiles=g.in_tiles,
        blk=blk,
        out_rows=_ceil_to(st.shape[mode], tile_i),
        in_rows=tuple(
            _ceil_to(st.shape[m], t) for m, t in zip(g.in_modes, g.in_tiles)
        ),
        mode=mode,
        in_modes=g.in_modes,
        nnz=st.nnz,
    )
    # Opt-in build-time integrity gate (REPRO_VALIDATE_PLANS=1): both the
    # vectorized and the reference builder funnel through this assembly tail.
    if plans_validated():
        validate_plan(plan)
    return plan


def _record_plan_metrics(plan: BlockPlan, dt: float, builder: str) -> None:
    """Layout statistics every build records (docs/observability.md): build
    wall time, padding/occupancy of the padded stream, block count, and the
    blocks-per-output-tile imbalance (max over occupied tiles / mean — the
    skew the Cache Engine's A-tile residency sees)."""
    pad = plan.padding_fraction()
    _metrics.histogram("plan.build_seconds", builder=builder).observe(dt)
    _metrics.histogram("plan.padding_fraction").observe(pad)
    _metrics.histogram("plan.occupancy").observe(1.0 - pad)
    _metrics.histogram("plan.nblocks").observe(plan.nblocks)
    if plan.block_it.size:
        per_tile = np.bincount(plan.block_it)
        per_tile = per_tile[per_tile > 0]
        _metrics.histogram("plan.tile_block_imbalance").observe(
            float(per_tile.max() / per_tile.mean())
        )


def plan_blocks(
    st: SparseTensor,
    mode: int,
    *,
    tile_i: int = 256,
    tile_j: int = 256,
    tile_k: int = 256,
    blk: int = 256,
    in_tiles: tuple[int, ...] | None = None,
) -> BlockPlan:
    """Two-level tile remap (host-side preprocessing == the Tensor Remapper +
    memory-layout generator).  Supports any order >= 3 (paper Table 2 has
    3–5-mode tensors): the N-1 input modes each get a tile-id stream and a
    local-index vector.  `in_tiles` overrides the per-input-mode tile sizes;
    by default the first input mode uses tile_j and the rest tile_k.

    Vectorized build: one fancy-index scatter moves every non-zero to its
    padded destination (cumsum of padded group sizes -> per-group destination
    offsets), and `np.repeat` expands per-group tile ids to per-block
    metadata.  Local indices are computed in original stream order and
    gathered through the remap permutation, so no fully-sorted copies of the
    coordinate arrays are ever materialized.  Bit-identical to
    `plan_blocks_reference` (the per-group Python loop it replaced), which is
    kept for parity testing; the vectorized path is what makes layout
    generation cheap enough to amortize (paper Sec. 3.1 treats layout-build
    cost as a first-class quantity)."""
    t0 = time.perf_counter()
    with _trace.span("plan_build", mode=mode, builder="vectorized",
                     nnz=st.nnz, blk=blk):
        g = _grouped_stream(st, mode, tile_i, tile_j, tile_k, blk, in_tiles)
        n_in = len(g.in_modes)
        total = g.total
        nnz = g.i.size
        order = g.order

        # Destination of each sorted non-zero: its group's padded base offset
        # plus its rank within the group.
        dst_off = np.concatenate([[0], np.cumsum(g.padded_sizes)[:-1]])
        # per-element group id via boundary flags (O(nnz), no repeat allocation)
        flags = np.zeros((nnz,), np.int64)
        flags[g.boundaries[1:]] = 1
        gid = np.cumsum(flags)
        dest = dst_off[gid] + (np.arange(nnz, dtype=np.int64) - g.boundaries[gid])

        vals = np.zeros((total,), np.float32)
        iloc = np.zeros((total,), np.int32)
        in_locs = [np.zeros((total,), np.int32) for _ in range(n_in)]
        vals[dest] = g.v[order]
        iloc[dest] = (g.i - g.it * tile_i).astype(np.int32)[order]
        for n in range(n_in):
            in_locs[n][dest] = (g.ins[n] - g.in_ts[n] * g.in_tiles[n]).astype(np.int32)[order]

        # Per-block tile-id metadata: each group contributes padded_size/blk
        # identical blocks; `leaders` are the original positions of each
        # group's first sorted element.
        nb_per_group = g.padded_sizes // blk
        leaders = order[g.boundaries]
        block_it = np.repeat(g.it[leaders], nb_per_group).astype(np.int32)
        block_in = [
            np.repeat(t[leaders], nb_per_group).astype(np.int32) for t in g.in_ts
        ]
        plan = _assemble_plan(
            st, mode, g, tile_i, blk, vals, iloc, in_locs, block_it, block_in
        )
    _record_plan_metrics(plan, time.perf_counter() - t0, "vectorized")
    return plan


def plan_blocks_reference(
    st: SparseTensor,
    mode: int,
    *,
    tile_i: int = 256,
    tile_j: int = 256,
    tile_k: int = 256,
    blk: int = 256,
    in_tiles: tuple[int, ...] | None = None,
) -> BlockPlan:
    """Per-group Python-loop layout build: the original O(#groups)
    interpreter-loop implementation, kept as the executable specification
    `plan_blocks` must match bit-for-bit (see the hypothesis parity property
    in tests/test_remap.py)."""
    t0 = time.perf_counter()
    with _trace.span("plan_build", mode=mode, builder="reference",
                     nnz=st.nnz, blk=blk):
        g = _grouped_stream(st, mode, tile_i, tile_j, tile_k, blk, in_tiles)
        n_in = len(g.in_modes)
        total = g.total
        nblocks = total // blk

        # The loop walks the stream in sorted order: materialize sorted copies.
        order = g.order
        i, v, it = g.i[order], g.v[order], g.it[order]
        ins = [c[order] for c in g.ins]
        in_ts = [t[order] for t in g.in_ts]

        vals = np.zeros((total,), np.float32)
        iloc = np.zeros((total,), np.int32)
        in_locs = [np.zeros((total,), np.int32) for _ in range(n_in)]
        block_it = np.empty((nblocks,), np.int32)
        block_in = [np.empty((nblocks,), np.int32) for _ in range(n_in)]

        src = 0
        dst = 0
        b = 0
        for gsize, psize in zip(g.group_sizes, g.padded_sizes):
            s, e = src, src + gsize
            vals[dst : dst + gsize] = v[s:e]
            iloc[dst : dst + gsize] = (i[s:e] - it[s] * tile_i).astype(np.int32)
            for n in range(n_in):
                in_locs[n][dst : dst + gsize] = (
                    ins[n][s:e] - in_ts[n][s] * g.in_tiles[n]
                ).astype(np.int32)
            nb = psize // blk
            block_it[b : b + nb] = it[s]
            for n in range(n_in):
                block_in[n][b : b + nb] = in_ts[n][s]
            src = e
            dst += psize
            b += nb

        plan = _assemble_plan(
            st, mode, g, tile_i, blk, vals, iloc, in_locs, block_it, block_in
        )
    _record_plan_metrics(plan, time.perf_counter() - t0, "reference")
    return plan
