"""Shared host-side driver pieces for the decomposition loops.

Every format driver (`cp_als`, `tucker_hooi`, `tt_als`) runs the same outer
shape: validate the method/workspace arguments, pad the factors once, call one
jitted sweep per iteration, read a single fit scalar back for the tol
early-exit, and unpad at materialization.  The per-iteration bookkeeping and
the argument contracts live here so the drivers stay format-specific only in
their math — `repro.kernels.workspace.PlannedWorkspace.drive` is the matching
device-side loop.

This module is importable from `repro.core` (it must not import
`repro.kernels`: kernels builds on core, not the other way around) — workspace
classes are passed in as arguments where needed.

The numerical-guard surface of the resilience layer also lives here
(`GuardConfig` / `GuardState` / `DecompositionDiverged`): divergence detection
is pure host-side fit bookkeeping, so it sits next to `finish_iter` and is
consumed by `PlannedWorkspace.drive` and re-exported from `repro.resilience`.
"""
from __future__ import annotations

import dataclasses
import math
import warnings

from ..obs import metrics as _metrics
from ..obs import trace as _trace

__all__ = [
    "finish_iter",
    "check_planned_method",
    "check_drive_extras",
    "require_sharded_sweep",
    "check_workspace",
    "GuardConfig",
    "GuardState",
    "DecompositionDiverged",
]

GUARD_POLICIES = ("raise", "fallback", "restart")

#: A fit must drop this far below the best seen before an iteration counts
#: toward the divergence patience — plain convergence noise stays inert.
REGRESSION_TOL = 1e-6


class DecompositionDiverged(RuntimeError):
    """A guarded decomposition detected divergence and could not (or was not
    asked to) recover.  Carries the diagnostic context the multi-tenant
    engine needs to report the incident: which driver, at which iteration,
    why, and the fit trajectory up to the failure."""

    def __init__(self, label: str, iteration: int, reason: str,
                 fit_history: list[float]):
        self.label = label
        self.iteration = iteration
        self.reason = reason
        self.fit_history = list(fit_history)
        super().__init__(
            f"[{label}] diverged at iteration {iteration}: {reason} "
            f"(fit history: {self._tail()})"
        )

    def _tail(self) -> str:
        tail = self.fit_history[-4:]
        pre = "..., " if len(self.fit_history) > len(tail) else ""
        return "[" + pre + ", ".join(f"{f:.6g}" for f in tail) + "]"


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Numerical-guard policy for `PlannedWorkspace.drive`.

    policy:
      * "raise"    — raise `DecompositionDiverged` with diagnostics;
      * "restart"  — re-initialize with jittered factors and retry the whole
                     decomposition, at most `max_restarts` times;
      * "fallback" — degrade the pallas sweep to the reference sweep mid-run,
                     reusing the same padded factors (last good iterate).
    divergence_patience: consecutive fit-regression iterations tolerated
      before the guard fires (non-finite fit always fires immediately).
    max_restarts: bound on "restart" retries before escalating to raise.
    check_factors_every: if > 0, additionally check factor finiteness every k
      iterations (one extra host sync per check); 0 disables the factor check
      (the fit check is free — the fit scalar is already synced every
      iteration).
    """

    policy: str = "raise"
    divergence_patience: int = 3
    max_restarts: int = 2
    check_factors_every: int = 0

    def __post_init__(self):
        if self.policy not in GUARD_POLICIES:
            raise ValueError(
                f"unknown guard policy {self.policy!r}: expected one of "
                f"{GUARD_POLICIES}"
            )
        if self.divergence_patience < 1:
            raise ValueError("divergence_patience must be >= 1")
        if self.max_restarts < 0:
            raise ValueError("max_restarts must be >= 0")
        if self.check_factors_every < 0:
            raise ValueError("check_factors_every must be >= 0")


class GuardState:
    """Host-side divergence tracker: feed it the per-iteration fit scalar
    (`observe_fit`) and it returns a non-None reason string when the guard
    should fire.  `reset()` clears the trajectory state (after a restart or a
    fallback rebase) but keeps the restart budget."""

    def __init__(self, cfg: GuardConfig):
        self.cfg = cfg
        self.restarts = 0
        self.reset()

    def reset(self) -> None:
        self.best = -math.inf
        self.regress_streak = 0

    def observe_fit(self, fit: float) -> str | None:
        if not math.isfinite(fit):
            return f"non-finite fit ({fit})"
        if fit < self.best - REGRESSION_TOL:
            self.regress_streak += 1
            if self.regress_streak >= self.cfg.divergence_patience:
                return (
                    f"fit regressed below best {self.best:.6g} for "
                    f"{self.regress_streak} consecutive iterations "
                    f"(latest {fit:.6g})"
                )
        else:
            self.regress_streak = 0
            self.best = max(self.best, fit)
        return None


def finish_iter(fits, fit, it: int, tol, verbose: bool, label: str) -> bool:
    """Host-side bookkeeping per iteration: record the fit scalar and decide
    the tol early-exit (the only device->host sync in the jitted loops).

    A non-finite fit terminates the loop immediately (returns True) and is
    surfaced as a RuntimeWarning even with guards off — it used to fail the
    tol comparison silently and burn every remaining iteration.  The same
    incident is recorded as a structured obs event + counter
    (`resilience.nonfinite_fit`), so resilience actions are countable
    across a run, not just printed."""
    fits.append(float(fit))
    if verbose:
        print(f"[{label}] iter {it:3d} fit={fits[-1]:.6f}")
    if not math.isfinite(fits[-1]):
        _metrics.counter("resilience.nonfinite_fit", label=label).inc()
        _trace.event("nonfinite_fit", label=label, it=it, fit=repr(fits[-1]))
        warnings.warn(
            f"[{label}] non-finite fit ({fits[-1]}) at iteration {it}; "
            f"stopping early — pass guards=GuardConfig(...) for "
            f"raise/restart/fallback recovery",
            RuntimeWarning,
            stacklevel=2,
        )
        return True
    return tol is not None and it > 0 and abs(fits[-1] - fits[-2]) < tol


def check_planned_method(method: str, planned, devices, dist) -> None:
    """The argument contract every planned driver shares: a workspace only
    makes sense for the pallas paths, and placement arguments only for the
    sharded one — both would otherwise be silently ignored."""
    if planned is not None and method not in ("pallas", "pallas_sharded"):
        raise ValueError(
            "a planned workspace was passed but method is not 'pallas' / "
            "'pallas_sharded'; the workspace would be silently ignored"
        )
    if method != "pallas_sharded" and (devices is not None or dist is not None):
        raise ValueError(
            f"devices/dist apply only to method='pallas_sharded' (got "
            f"method={method!r}); they would be silently ignored"
        )


def check_drive_extras(method: str, jit_sweep: bool, guards,
                       checkpoint_every, checkpoint_path) -> None:
    """The resilience kwargs (guards / checkpoint) are consumed by the
    planned `drive` loop only; reject combinations that would silently
    ignore them (mirrors `check_planned_method`)."""
    if guards is None and checkpoint_every is None and checkpoint_path is None:
        return
    if method not in ("pallas", "pallas_sharded") or not jit_sweep:
        raise ValueError(
            "guards/checkpoint_every/checkpoint_path are consumed by the "
            "planned drive loop: they require method='pallas' or "
            "'pallas_sharded' with jit_sweep=True (they would be silently "
            "ignored here)"
        )


def require_sharded_sweep(jit_sweep: bool) -> None:
    if not jit_sweep:
        raise ValueError(
            "method='pallas_sharded' runs only as the jitted shard_map "
            "sweep; use method='pallas' for the eager parity baseline"
        )


def check_workspace(planned, cls, method: str, attrs: dict, devices=None) -> None:
    """Validate a caller-supplied workspace against the call: right class for
    the method, built for the same tensor geometry/ranks, spanning the
    requested device count.  `attrs` maps attribute name -> the value this
    call requires (compared against the workspace's attribute)."""
    if not isinstance(planned, cls):
        extra = (
            ""
            if method == "pallas_sharded"
            else " (use method='pallas_sharded' for sharded workspaces)"
        )
        raise ValueError(
            f"method={method!r} needs a {cls.__name__} workspace, got "
            f"{type(planned).__name__}{extra}"
        )
    if any(getattr(planned, k) != v for k, v in attrs.items()):
        built = " ".join(f"{k}={getattr(planned, k)}" for k in attrs)
        want = " ".join(f"{k}={v}" for k, v in attrs.items())
        raise ValueError(
            f"{cls.__name__} workspace was built for {built}, got {want}"
        )
    if devices is not None and getattr(planned, "nshards", devices) != devices:
        raise ValueError(
            f"{cls.__name__} workspace spans {planned.nshards} shards but "
            f"devices={devices} was requested"
        )
