"""Shared host-side driver pieces for the decomposition loops.

Every format driver (`cp_als`, `tucker_hooi`, `tt_als`) runs the same outer
shape: validate the method/workspace arguments, pad the factors once, call one
jitted sweep per iteration, read a single fit scalar back for the tol
early-exit, and unpad at materialization.  The per-iteration bookkeeping and
the argument contracts live here so the drivers stay format-specific only in
their math — `repro.kernels.workspace.PlannedWorkspace.drive` is the matching
device-side loop.

This module is importable from `repro.core` (it must not import
`repro.kernels`: kernels builds on core, not the other way around) — workspace
classes are passed in as arguments where needed.
"""
from __future__ import annotations

__all__ = [
    "finish_iter",
    "check_planned_method",
    "require_sharded_sweep",
    "check_workspace",
]


def finish_iter(fits, fit, it: int, tol, verbose: bool, label: str) -> bool:
    """Host-side bookkeeping per iteration: record the fit scalar and decide
    the tol early-exit (the only device->host sync in the jitted loops)."""
    fits.append(float(fit))
    if verbose:
        print(f"[{label}] iter {it:3d} fit={fits[-1]:.6f}")
    return tol is not None and it > 0 and abs(fits[-1] - fits[-2]) < tol


def check_planned_method(method: str, planned, devices, dist) -> None:
    """The argument contract every planned driver shares: a workspace only
    makes sense for the pallas paths, and placement arguments only for the
    sharded one — both would otherwise be silently ignored."""
    if planned is not None and method not in ("pallas", "pallas_sharded"):
        raise ValueError(
            "a planned workspace was passed but method is not 'pallas' / "
            "'pallas_sharded'; the workspace would be silently ignored"
        )
    if method != "pallas_sharded" and (devices is not None or dist is not None):
        raise ValueError(
            f"devices/dist apply only to method='pallas_sharded' (got "
            f"method={method!r}); they would be silently ignored"
        )


def require_sharded_sweep(jit_sweep: bool) -> None:
    if not jit_sweep:
        raise ValueError(
            "method='pallas_sharded' runs only as the jitted shard_map "
            "sweep; use method='pallas' for the eager parity baseline"
        )


def check_workspace(planned, cls, method: str, attrs: dict, devices=None) -> None:
    """Validate a caller-supplied workspace against the call: right class for
    the method, built for the same tensor geometry/ranks, spanning the
    requested device count.  `attrs` maps attribute name -> the value this
    call requires (compared against the workspace's attribute)."""
    if not isinstance(planned, cls):
        extra = (
            ""
            if method == "pallas_sharded"
            else " (use method='pallas_sharded' for sharded workspaces)"
        )
        raise ValueError(
            f"method={method!r} needs a {cls.__name__} workspace, got "
            f"{type(planned).__name__}{extra}"
        )
    if any(getattr(planned, k) != v for k, v in attrs.items()):
        built = " ".join(f"{k}={getattr(planned, k)}" for k in attrs)
        want = " ".join(f"{k}={v}" for k, v in attrs.items())
        raise ValueError(
            f"{cls.__name__} workspace was built for {built}, got {want}"
        )
    if devices is not None and getattr(planned, "nshards", devices) != devices:
        raise ValueError(
            f"{cls.__name__} workspace spans {planned.nshards} shards but "
            f"devices={devices} was requested"
        )
