"""Core paper contribution: spMTTKRP compute patterns, tensor remapper,
programmable memory-controller model, PMS, CP-ALS driver."""
from .coo import SparseTensor, CooBatch, synthetic_tensor, frostt_like, to_device, random_factors
from .hypergraph import TrafficModel, approach1_traffic, approach2_traffic, remap_overhead, stats
from .remap import remap_stable, remap_pointer_machine, remap_radix, radix_digits, plan_blocks, plan_blocks_reference, BlockPlan, pointer_table, group_key
from .mttkrp import mttkrp, mttkrp_approach1, mttkrp_approach2, mttkrp_sharded, hadamard_rows
from .memctrl import MemoryControllerConfig, CacheEngineConfig, DMAEngineConfig, RemapperConfig, TPUSpec
from .pms import PMSEstimate, ShardedPMSEstimate, predict_from_plan, predict_analytic, predict_sharded, search, search_sharded
from .cp_als import cp_als, CPState, fit_value, gram_hadamard
