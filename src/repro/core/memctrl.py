"""Programmable memory-controller configuration (paper Sec. 5).

The paper's controller has three engines whose parameters are fixed at FPGA
synthesis time and share a finite on-chip SRAM budget (BRAM/URAM).  The TPU
analogue fixes the parameters at *trace/compile* time and shares the VMEM
budget.  The mapping of each paper parameter (Sec. 5.2):

  Cache Engine  — cache-line width        -> factor-tile row width  (R_pad lanes)
                  number of cache lines   -> tile rows (tile_j / tile_k)
                  associativity           -> resident tiles per operand (1 in the
                                             kernel; modeled for the PMS)
  DMA Engine    — number of DMAs          -> concurrent BlockSpec streams (fixed
                                             by kernel arity)
                  buffers per DMA         -> double-buffer depth (pipelined grid)
                  DMA buffer size         -> blk (non-zeros per grid step)
  Remapper      — DMA buffer size         -> remap chunk
                  tensor-element width    -> index+value bytes
                  max address pointers    -> pointer_budget (hierarchical remap
                                             when a mode exceeds it)
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "CacheEngineConfig",
    "DMAEngineConfig",
    "RemapperConfig",
    "MemoryControllerConfig",
    "TPUSpec",
    "spec_to_dict",
    "spec_from_dict",
    "config_to_dict",
    "config_from_dict",
]


@dataclasses.dataclass(frozen=True)
class CacheEngineConfig:
    tile_i: int = 256  # output-tile rows resident in VMEM (accumulator)
    tile_j: int = 256  # input factor tile rows ("number of cache lines")
    tile_k: int = 256
    resident_tiles: int = 1  # "associativity": tiles kept per operand

    def input_tiles(self, n_in: int = 2) -> tuple[int, ...]:
        """Per-input-mode tile sizes for an N-mode tensor (n_in = N-1 input
        factor tiles resident in VMEM): the first input mode uses tile_j,
        every further one tile_k."""
        assert n_in >= 1
        return ((self.tile_j,) + (self.tile_k,) * (n_in - 1))[:n_in]


@dataclasses.dataclass(frozen=True)
class DMAEngineConfig:
    blk: int = 256  # non-zeros per grid step ("DMA buffer size")
    buffers: int = 2  # double buffering depth (Pallas pipelines grid steps)


@dataclasses.dataclass(frozen=True)
class RemapperConfig:
    pointer_budget: int = 1 << 20  # max address pointers on-chip (Sec. 3.1)
    index_bytes: int = 4
    value_bytes: int = 4


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """Target-hardware constants (TPU v5e)."""

    peak_flops: float = 197e12  # bf16
    peak_flops_f32: float = 98.5e12
    hbm_bw: float = 819e9  # bytes/s
    vmem_bytes: int = 128 * 1024 * 1024
    vmem_usable_frac: float = 0.5  # compiler scratch, double buffers
    ici_bw_per_link: float = 50e9  # bytes/s/link
    ici_links: int = 4  # 2D torus on v5e: 4 links/chip
    hbm_bytes: int = 16 * 1024**3


@dataclasses.dataclass(frozen=True)
class MemoryControllerConfig:
    cache: CacheEngineConfig = CacheEngineConfig()
    dma: DMAEngineConfig = DMAEngineConfig()
    remapper: RemapperConfig = RemapperConfig()

    def vmem_bytes(self, rank_padded: int, n_in: int = 2) -> int:
        """VMEM footprint of one kernel instance (per buffer set): the output
        accumulator tile + n_in (= N-1) resident input factor tiles + the
        non-zero block stream (vals + N local index vectors).  Element widths
        come from the Remapper configuration, not hardcoded 4-byte literals.
        Pallas double-buffers streamed operands -> multiply by dma.buffers."""
        c, d, r = self.cache, self.dma, self.remapper
        tiles = (
            (c.tile_i + sum(c.input_tiles(n_in)) * c.resident_tiles)
            * rank_padded
            * r.value_bytes
        )
        stream = d.blk * (r.value_bytes + (n_in + 1) * r.index_bytes)
        return d.buffers * (tiles + stream)

    def fits(self, spec: TPUSpec, rank_padded: int, n_in: int = 2) -> bool:
        return self.vmem_bytes(rank_padded, n_in) <= spec.vmem_bytes * spec.vmem_usable_frac

    def vmem_bytes_ttmc(self, out_cols_padded: int, in_rank_pads: tuple[int, ...]) -> int:
        """VMEM footprint of one TTM-chain kernel instance (per buffer set).

        Differs from the MTTKRP model in the tile widths: the output
        accumulator is a *core-tensor slice* of out_cols_padded =
        cols_padded(prod input ranks) lanes — the Kronecker chain widens the
        accumulator multiplicatively in the ranks, which is exactly why the
        TTMc search needs its own fit constraint — and each resident input
        factor tile carries its own lane padding rank_padded(R_m) instead of
        a shared R_pad.  Stream cost is identical (same BlockPlan layout)."""
        c, d, r = self.cache, self.dma, self.remapper
        n_in = len(in_rank_pads)
        tiles = (
            c.tile_i * out_cols_padded
            + sum(t * rp for t, rp in zip(c.input_tiles(n_in), in_rank_pads))
            * c.resident_tiles
        ) * r.value_bytes
        stream = d.blk * (r.value_bytes + (n_in + 1) * r.index_bytes)
        return d.buffers * (tiles + stream)

    def fits_ttmc(self, spec: TPUSpec, out_cols_padded: int, in_rank_pads: tuple[int, ...]) -> bool:
        return (
            self.vmem_bytes_ttmc(out_cols_padded, in_rank_pads)
            <= spec.vmem_bytes * spec.vmem_usable_frac
        )

    def vmem_bytes_tt(
        self,
        out_cols_padded: int,
        in_rank_pads: tuple[int, ...],
        iface_cols: int,
    ) -> int:
        """VMEM footprint of one TT-core kernel instance (per buffer set).

        Same tile/stream structure as the TTMc model — the output accumulator
        carries out_cols_padded = rank_padded(rl_m*rr_m) lanes and each
        resident core-interface tile its own rank_padded(rl_k*rr_k) — plus
        the two-interface scratch: the left and right chain vectors live at
        (blk, iface_cols) where iface_cols bounds the widest left- and
        right-chain intermediates.  The chains are recomputed per block in
        registers/VMEM scratch, not double-buffered (they are not streamed
        operands), so the scratch term sits outside the buffers multiplier."""
        c, d, r = self.cache, self.dma, self.remapper
        n_in = len(in_rank_pads)
        tiles = (
            c.tile_i * out_cols_padded
            + sum(t * rp for t, rp in zip(c.input_tiles(n_in), in_rank_pads))
            * c.resident_tiles
        ) * r.value_bytes
        stream = d.blk * (r.value_bytes + (n_in + 1) * r.index_bytes)
        scratch = d.blk * iface_cols * r.value_bytes
        return d.buffers * (tiles + stream) + scratch

    def fits_tt(
        self,
        spec: TPUSpec,
        out_cols_padded: int,
        in_rank_pads: tuple[int, ...],
        iface_cols: int,
    ) -> bool:
        return (
            self.vmem_bytes_tt(out_cols_padded, in_rank_pads, iface_cols)
            <= spec.vmem_bytes * spec.vmem_usable_frac
        )


# ---------------------------------------------------------------------------
# JSON-ready (de)serialization — the autotune cache (repro.tune.cache) persists
# fitted TPUSpecs and winning MemoryControllerConfigs across processes.  The
# converters live here, next to the dataclasses whose schema they mirror.
# ---------------------------------------------------------------------------


def _from_known_fields(cls, d: dict):
    """Rebuild a dataclass from a plain dict, rejecting unknown keys (a key
    this schema does not know about means the entry was written by a different
    code version — the caller treats that as a cache miss, never a crash)."""
    if not isinstance(d, dict):
        raise ValueError(f"{cls.__name__}: expected a dict, got {type(d).__name__}")
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(f"{cls.__name__}: unknown fields {sorted(unknown)}")
    return cls(**d)


def spec_to_dict(spec: TPUSpec) -> dict:
    """TPUSpec -> plain JSON-ready dict."""
    return dataclasses.asdict(spec)


def spec_from_dict(d: dict) -> TPUSpec:
    """Plain dict -> TPUSpec.  Raises ValueError on unknown fields."""
    return _from_known_fields(TPUSpec, d)


def config_to_dict(cfg: MemoryControllerConfig) -> dict:
    """MemoryControllerConfig -> nested JSON-ready dict."""
    return dataclasses.asdict(cfg)


def config_from_dict(d: dict) -> MemoryControllerConfig:
    """Nested dict -> MemoryControllerConfig.  Raises ValueError on unknown
    fields at any level (version drift reads as invalid, not as silence)."""
    if not isinstance(d, dict):
        raise ValueError(f"config: expected a dict, got {type(d).__name__}")
    known = {"cache", "dma", "remapper"}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"config: unknown fields {sorted(unknown)}")
    return MemoryControllerConfig(
        cache=_from_known_fields(CacheEngineConfig, d.get("cache", {})),
        dma=_from_known_fields(DMAEngineConfig, d.get("dma", {})),
        remapper=_from_known_fields(RemapperConfig, d.get("remapper", {})),
    )
