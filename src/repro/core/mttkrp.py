"""spMTTKRP compute patterns (paper Sec. 3, Algorithms 2-5), pure JAX.

Both approaches compute, for each non-zero x at (i0..iN-1) and output mode m:

    out[i_m, :] += x * prod_{n != m} F_n[i_n, :]

They differ only in traversal order — which on TPU becomes *which lowering
XLA picks*:

  * Approach 1 (output-direction, stream sorted by output coordinate):
    `segment_sum` with `indices_are_sorted=True` — a streaming segmented
    reduction, no partial-sum materialization (matches Alg. 3 / Alg. 5).
  * Approach 2 (input-direction, unsorted stream): scatter-add — XLA
    materializes and re-reads accumulator traffic, the moral equivalent of the
    paper's DRAM partial sums (matches Alg. 4).

The hot 3-mode path additionally has a Pallas kernel (kernels/mttkrp_pallas.py)
driven by the BlockPlan layout; this module is the N-mode reference + the
distributed (shard_map) implementation.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "hadamard_rows",
    "mttkrp_approach1",
    "mttkrp_approach2",
    "mttkrp",
    "mttkrp_sharded",
]


def hadamard_rows(indices: jax.Array, values: jax.Array, factors: Sequence[jax.Array], mode: int) -> jax.Array:
    """Per-non-zero Hadamard products: rows of the Khatri-Rao product gathered
    through the tensor's indices.  (nnz, R)."""
    prod = None
    for n, f in enumerate(factors):
        if n == mode:
            continue
        rows = f[indices[:, n]]  # gather: the Cache-Engine access pattern
        prod = rows if prod is None else prod * rows
    assert prod is not None
    return prod * values[:, None].astype(prod.dtype)


@partial(jax.jit, static_argnames=("mode", "out_rows", "sorted_by_mode"))
def mttkrp_approach1(
    indices: jax.Array,
    values: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    out_rows: int,
    sorted_by_mode: bool = True,
) -> jax.Array:
    """Approach 1: output-direction computation over a stream sorted by the
    output mode (Alg. 3).  Lowered as a sorted segmented reduction."""
    contrib = hadamard_rows(indices, values, factors, mode)
    return jax.ops.segment_sum(
        contrib,
        indices[:, mode],
        num_segments=out_rows,
        indices_are_sorted=sorted_by_mode,
    )


@partial(jax.jit, static_argnames=("mode", "out_rows"))
def mttkrp_approach2(
    indices: jax.Array,
    values: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    out_rows: int,
) -> jax.Array:
    """Approach 2: input-direction computation (Alg. 4) — unsorted stream,
    scatter-add accumulation (partial sums materialized by the backend)."""
    contrib = hadamard_rows(indices, values, factors, mode)
    out = jnp.zeros((out_rows, contrib.shape[1]), contrib.dtype)
    return out.at[indices[:, mode]].add(contrib, indices_are_sorted=False, unique_indices=False)


def mttkrp(
    indices: jax.Array,
    values: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    out_rows: int,
    *,
    method: str = "approach1",
    sorted_by_mode: bool = True,
) -> jax.Array:
    """Dispatcher. `method` in {approach1, approach2}.  The Pallas path is
    dispatched in kernels/ops.py (it needs the host-side BlockPlan)."""
    if method == "approach1":
        return mttkrp_approach1(
            indices, values, factors, mode, out_rows, sorted_by_mode=sorted_by_mode
        )
    if method == "approach2":
        return mttkrp_approach2(indices, values, factors, mode, out_rows)
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------------------
# Distributed MTTKRP (shard_map over the non-zero stream)
# ---------------------------------------------------------------------------


def mttkrp_sharded(
    plan,
    mode: int,
    out_rows: int,
    method: str = "approach1",
    *,
    sorted_by_mode: bool = False,
    st=None,
    rank: int | None = None,
    cfg=None,
    interpret: bool = True,
):
    """Build a shard_map'd MTTKRP from a ``ShardingPlan``: the non-zero
    stream is sharded over the plan's data axes (``plan.stream()``), factor
    matrices replicated, outputs psum-reduced over the same axes.

    This is the production distribution of the paper's kernel: every device
    runs Approach 1 on its local remapped shard; the output factor matrix is
    reduced across the stream shards (one all-reduce of I_out x R — the
    `I_out*R` store term of Table 1, now a collective).  Pass
    ``sorted_by_mode=True`` only when every local shard is sorted by the
    output-mode coordinate (sorting globally then sharding contiguously
    satisfies this — the remap posture); the default assumes an unsorted
    stream, since ``indices_are_sorted`` is a correctness promise to XLA,
    not a hint.

    method="pallas" dispatches the *planned* route instead: the host-side
    ``st`` (SparseTensor) and ``rank`` are required, the stream is
    partitioned into balanced output-tile ranges and each shard gets its own
    device-local BlockPlan layout (kernels/ops.make_sharded_planned_mttkrp).
    The returned callable keeps the (indices, values, factors) signature for
    drop-in use, but the stream arguments are ignored — each shard's
    remapped copy already lives on its device."""
    from jax.experimental.shard_map import shard_map

    if method == "pallas":
        if st is None or rank is None:
            raise ValueError(
                "mttkrp_sharded(method='pallas') needs the host-side stream: "
                "pass st=<SparseTensor> and rank=<int> (the BlockPlan "
                "partitioner runs on host-side numpy)"
            )
        from ..kernels.ops import make_sharded_planned_mttkrp

        op = make_sharded_planned_mttkrp(
            st, mode, rank, dist=plan, cfg=cfg, interpret=interpret
        )

        def call_planned(indices, values, factors):
            del indices, values  # per-shard layouts are device-resident
            return op.output(factors, out_rows)

        return call_planned

    axis_names = plan.data_axes()

    def local_fn(indices, values, *factors):
        out = mttkrp(
            indices, values, factors, mode, out_rows,
            method=method, sorted_by_mode=sorted_by_mode,
        )
        return jax.lax.psum(out, axis_names)

    def call(indices, values, factors):
        in_specs = (plan.stream(), plan.stream()) + tuple(
            P(None, None) for _ in factors
        )
        return shard_map(
            local_fn,
            mesh=plan.mesh,
            in_specs=in_specs,
            out_specs=P(None, None),
            check_rep=False,
        )(indices, values, *factors)

    return call
