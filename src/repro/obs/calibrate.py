"""Predicted-vs-achieved PMS accounting — the observability layer's headline
consumer.

The PMS (core/pms.py) is the paper's Parameterized Memory Search: an
analytic roofline that picks memory-controller configurations.  Until now
nothing ever measured whether its predictions held.  This module closes the
loop, joining the *exact* per-plan predictors (`predict_from_plan` /
`predict_ttmc` / `predict_tt` — computed from the workspace's built
BlockPlans, not the analytic occupancy model) against measured sweep wall
times:

    achieved_pct = 100 * t_predicted / t_measured

100% means the sweep ran exactly at the modeled roofline; far below 100%
means the model is optimistic for that (format, config, preset) — on CPU
interpret-mode Pallas the absolute numbers are small (the model describes
TPU hardware), but the *trajectory* of achieved_pct across PRs is the
regression signal ROADMAP asks for ("achieved vs predicted roofline % per
config in BENCH_kernel.json so PMS mispredictions become visible
regressions").

Two join paths:

  * `calibration_row(ws, measured_s, ...)` — direct: a built planned
    workspace plus a measured steady-state sweep time (bench_e2e's
    `pms_accuracy` section).
  * `join_trace(path)` — offline: a trace JSONL whose "sweep" spans carry a
    `predicted_s` attribute (the drive loop attaches it when tracing is on);
    steady-state measured time is the median span duration excluding the
    first sweep per group (the first pays jit compilation).

Imports of `repro.core` stay inside functions: `core.remap` imports
`repro.obs` for its build-time spans, so a module-level import here would
be circular.
"""
from __future__ import annotations

import dataclasses
import statistics
from pathlib import Path
from typing import Any, Mapping, Sequence

__all__ = [
    "pms_estimates",
    "predicted_sweep_seconds",
    "CalibrationRow",
    "calibration_row",
    "accuracy_records",
    "join_trace",
    "format_table",
]


def pms_estimates(ws: Any, spec=None) -> dict:
    """Per-mode exact PMS estimates for a planned workspace, via the
    format's `pms_estimates` hook (PlannedCPALS / PlannedTucker /
    PlannedTT).  Raises TypeError for workspaces without the hook (the
    sharded stacks predict through `core.pms.predict_sharded` instead)."""
    hook = getattr(ws, "pms_estimates", None)
    if hook is None:
        raise TypeError(
            f"{type(ws).__name__} exposes no pms_estimates() hook; "
            f"calibration needs a single-device planned workspace"
        )
    return hook(spec) if spec is not None else hook()


def predicted_sweep_seconds(ws: Any, spec=None) -> float:
    """The PMS-predicted time of ONE full sweep: the sum over output modes
    of each mode's exact roofline t_total (per-mode kernels run
    sequentially inside the jitted sweep)."""
    return float(sum(e.t_total for e in pms_estimates(ws, spec).values()))


@dataclasses.dataclass(frozen=True)
class CalibrationRow:
    """One (format, preset) entry of the achieved-vs-predicted table."""

    format: str
    preset: str
    predicted_s: float
    measured_s: float

    @property
    def achieved_pct(self) -> float:
        return 100.0 * self.predicted_s / self.measured_s


def calibration_row(ws: Any, measured_s: float, *, format: str,
                    preset: str, spec=None) -> CalibrationRow:
    """Join one workspace's exact PMS prediction against a measured
    steady-state sweep time (seconds per full sweep, compile excluded)."""
    if measured_s <= 0:
        raise ValueError(f"measured_s must be > 0, got {measured_s}")
    return CalibrationRow(
        format=format,
        preset=preset,
        predicted_s=predicted_sweep_seconds(ws, spec),
        measured_s=float(measured_s),
    )


def accuracy_records(rows: Sequence[CalibrationRow]) -> list[dict]:
    """Render calibration rows as benchmark-trajectory result records (the
    `pms_accuracy` section of BENCH_kernel.json; schema repro/bench.py)."""
    from ..bench import result_record

    out = []
    for r in rows:
        name = f"pms_accuracy_{r.format}"
        out += [
            result_record(name, r.preset, "predicted_s", r.predicted_s, "s"),
            result_record(name, r.preset, "measured_s", r.measured_s, "s"),
            result_record(name, r.preset, "achieved_pct", r.achieved_pct, "%"),
        ]
    return out


def _steady_state_s(durs_us: Sequence[float]) -> float:
    """Median sweep duration in seconds, excluding the first sweep when more
    than one was recorded (the first pays jit compilation)."""
    steady = list(durs_us[1:]) if len(durs_us) > 1 else list(durs_us)
    return statistics.median(steady) / 1e6


def join_trace(path: str | Path | Sequence[Mapping]) -> list[dict]:
    """The offline join: group a trace's "sweep" spans by (label, preset)
    and compute achieved_pct where the spans carry `predicted_s`.

    Accepts a JSONL path or pre-loaded records.  Returns one dict per group:
    ``{"label", "preset", "n_sweeps", "measured_s", "predicted_s",
    "achieved_pct"}`` — the last two are None for untagged spans (tracing
    was on but the workspace had no PMS hook)."""
    if isinstance(path, (str, Path)):
        from .trace import load_jsonl

        records: Sequence[Mapping] = load_jsonl(path)
    else:
        records = path
    groups: dict[tuple, dict] = {}
    for r in records:
        if r.get("ph") != "X" or r.get("name") != "sweep":
            continue
        args = r.get("args", {})
        key = (str(args.get("label", "?")), str(args.get("preset", "?")))
        g = groups.setdefault(key, {"durs": [], "predicted": None})
        g["durs"].append(float(r.get("dur", 0.0)))
        if args.get("predicted_s") is not None:
            g["predicted"] = float(args["predicted_s"])
    rows = []
    for (label, preset), g in sorted(groups.items()):
        measured = _steady_state_s(g["durs"])
        pred = g["predicted"]
        rows.append({
            "label": label,
            "preset": preset,
            "n_sweeps": len(g["durs"]),
            "measured_s": measured,
            "predicted_s": pred,
            "achieved_pct": (
                100.0 * pred / measured if pred and measured > 0 else None
            ),
        })
    return rows


def format_table(rows: Sequence[Mapping]) -> str:
    """Plain-text achieved_pct table (scripts/trace_report.py --pms)."""
    header = (f"{'label':<14} {'preset':<10} {'sweeps':>6} "
              f"{'measured_s':>11} {'predicted_s':>12} {'achieved':>9}")
    lines = [header, "-" * len(header)]
    for r in rows:
        pred = r.get("predicted_s")
        ach = r.get("achieved_pct")
        pred_s = f"{pred:>12.3e}" if pred is not None else f"{'-':>12}"
        ach_s = f"{ach:>8.2f}%" if ach is not None else f"{'-':>9}"
        lines.append(
            f"{r['label']:<14} {r['preset']:<10} {r['n_sweeps']:>6d} "
            f"{r['measured_s']:>11.6f} {pred_s} {ach_s}"
        )
    return "\n".join(lines)
