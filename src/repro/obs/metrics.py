"""Metrics registry: counters / gauges / histograms for the planned engine.

Stdlib-only and always-on: unlike spans (obs.trace), metric updates are a
dict lookup plus an integer/float update under a small lock, cheap enough
for every hot path that wants one — the drive loop's per-iteration wall
time, the Tensor Remapper's plan-build stats, the plan cache's hit/miss
latencies, the resilience layer's guard/admission events.

Series are keyed by (metric name, sorted label items), Prometheus-style:

    from repro.obs import metrics
    metrics.counter("plan_cache.hits", kind="mttkrp").inc()
    metrics.histogram("drive.iter_seconds", label="cp_als").observe(dt)
    metrics.snapshot()["histograms"]["drive.iter_seconds{label=cp_als}"]

`snapshot()` renders everything to plain dicts (JSON-ready); `reset()`
clears the default registry (tests isolate themselves with it).  Histograms
keep running count/sum/min/max plus a bounded sample of the first
`Histogram.SAMPLE_CAP` observations for percentile estimates — enough for
the per-iteration and per-build distributions this repo records, without
unbounded growth on long runs.
"""
from __future__ import annotations

import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    "reset",
]


class Counter:
    """Monotonically increasing count (guard firings, cache hits, ...)."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins scalar (resident bytes, shard makespan, ...)."""

    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = None

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class Histogram:
    """Streaming distribution: running count/sum/min/max plus a bounded
    sample (the first SAMPLE_CAP observations) for percentile estimates."""

    SAMPLE_CAP = 4096

    __slots__ = ("_lock", "count", "total", "vmin", "vmax", "sample")

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.total = 0.0
        self.vmin = None
        self.vmax = None
        self.sample: list[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.vmin = v if self.vmin is None else min(self.vmin, v)
            self.vmax = v if self.vmax is None else max(self.vmax, v)
            if len(self.sample) < self.SAMPLE_CAP:
                self.sample.append(v)

    @property
    def mean(self) -> float | None:
        return self.total / self.count if self.count else None

    def percentile(self, q: float) -> float | None:
        """Nearest-rank percentile over the retained sample (q in [0, 100])."""
        with self._lock:
            s = sorted(self.sample)
        if not s:
            return None
        rank = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
        return s[rank]

    def summary(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin,
            "max": self.vmax,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
        }


def _series_key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Thread-safe get-or-create store of metric series.  A series' type is
    fixed by its first registration; re-registering the same series name
    with a different type raises (catches accidental name collisions)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._series: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, cls, name: str, labels: dict):
        key = _series_key(name, labels)
        with self._lock:
            m = self._series.get(key)
            if m is None:
                m = self._series[key] = cls()
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {key!r} is a {type(m).__name__}, "
                    f"requested as {cls.__name__}"
                )
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def snapshot(self) -> dict:
        """Everything, rendered to plain JSON-ready dicts."""
        with self._lock:
            items = list(self._series.items())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for key, m in items:
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = m.value
            else:
                out["histograms"][key] = m.summary()
        return out

    def reset(self) -> None:
        with self._lock:
            self._series.clear()


#: The process-global default registry every instrumented module records to.
REGISTRY = MetricsRegistry()


def counter(name: str, **labels) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return REGISTRY.histogram(name, **labels)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def reset() -> None:
    REGISTRY.reset()
