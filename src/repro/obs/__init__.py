"""repro.obs — observability for the planned decomposition engine.

Three stdlib-only pieces (docs/observability.md):

  * `obs.trace`   — span/event tracing: `span("plan_build", mode=..)`
    context managers recorded into a thread-safe collector, exported as
    JSONL or Chrome-trace JSON, bridged into `jax.profiler.TraceAnnotation`
    so device work lines up in xprof.  Off by default; enabled by
    ``REPRO_TRACE=1`` (or a path), `trace.enable()`, or per call via
    ``decompose(..., trace=...)``.  Disabled calls are no-ops.
  * `obs.metrics` — always-on counters/gauges/histograms recorded by the
    hot paths: drive-loop iteration times and fit deltas, plan-build and
    padding/occupancy stats, plan-cache hit/miss/eviction latencies,
    guard/restart/fallback/admission events, shard imbalance.
  * `obs.calibrate` — joins the PMS `predict_*` estimates against measured
    sweep times (`achieved_pct`); feeds the `pms_accuracy` section of
    BENCH_kernel.json and `scripts/trace_report.py --pms`.

This package imports nothing from the rest of `repro` at module scope
(`calibrate` resolves its `core.pms` / `bench` imports lazily), so every
layer — including `repro.core` — can record into it without cycles.
"""
from . import metrics  # noqa: F401
from .trace import (  # noqa: F401
    Tracer,
    active,
    configure_from_env,
    disable,
    enable,
    event,
    install,
    span,
    tracing,
)

__all__ = [
    "metrics",
    "Tracer",
    "active",
    "configure_from_env",
    "disable",
    "enable",
    "event",
    "install",
    "span",
    "tracing",
]
