"""Span/event tracer for the planned decomposition engine.

Zero-dependency (stdlib only; jax is bridged lazily and optionally): the hot
paths call `span("sweep", ...)` / `event("guard", ...)` unconditionally, and
when no tracer is installed those calls compile down to one module-global
read and the return of a shared no-op context manager — the traced-off
overhead bound (<= 2% on a small-preset drive(), tests/test_obs.py) holds
because a disabled call allocates nothing.

Enable switches (process-global):

  * ``REPRO_TRACE=1`` — collect spans in a process-global `Tracer` (read at
    import; `configure_from_env()` re-reads it for tests).  Any other
    non-empty value is treated as a JSONL path and the collected trace is
    exported there at interpreter exit.
  * ``enable(path=None)`` / ``disable()`` — the programmatic switch.
  * ``tracing(target)`` — scoped enablement: `decompose(st, r, trace=...)`
    wraps the whole call in it (`target` may be True, a path, or a Tracer).

Every span additionally enters `jax.profiler.TraceAnnotation(name)` when jax
is importable, so device work stays attributable in xprof/Perfetto next to
the host-side spans.

Export formats: JSONL (one span/event object per line — the format
`scripts/trace_report.py` and `obs.calibrate.join_trace` consume) and the
Chrome trace-event JSON (``chrome://tracing`` / Perfetto ``ui.perfetto.dev``).
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from pathlib import Path

__all__ = [
    "Tracer",
    "span",
    "event",
    "active",
    "enable",
    "disable",
    "install",
    "tracing",
    "configure_from_env",
]

_PID = os.getpid()


def _jax_annotation(name: str):
    """`jax.profiler.TraceAnnotation` when jax is importable, else None.
    Resolved lazily (and memoized) so the tracer stays importable — and
    testable — without jax on the path."""
    global _TRACE_ANNOTATION
    if _TRACE_ANNOTATION is None:
        try:
            from jax.profiler import TraceAnnotation as _TA
            _TRACE_ANNOTATION = _TA
        except Exception:
            _TRACE_ANNOTATION = False
    return _TRACE_ANNOTATION(name) if _TRACE_ANNOTATION else None


_TRACE_ANNOTATION = None  # unresolved | class | False (jax unavailable)


class _NullSpan:
    """The shared disabled-path context manager: no state, no allocation."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):  # same surface as _Span
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records (name, ts, dur, thread, parent, attrs) into its
    tracer on exit.  Nesting is tracked per thread via the tracer's
    thread-local span stack, so concurrent drives trace independently."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "id", "parent", "_ann")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs):
        """Attach attributes mid-span (e.g. a fit computed inside it)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        tr = self._tracer
        stack = tr._stack()
        self.parent = stack[-1] if stack else None
        self.id = tr._next_id()
        stack.append(self.id)
        self._ann = _jax_annotation(self.name)
        if self._ann is not None:
            self._ann.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        if self._ann is not None:
            self._ann.__exit__(*exc)
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] == self.id:
            stack.pop()
        tr._record({
            "ph": "X",
            "name": self.name,
            "ts": (self._t0 - tr._epoch) / 1e3,  # µs since tracer epoch
            "dur": dur / 1e3,
            "pid": _PID,
            "tid": threading.get_ident(),
            "id": self.id,
            "parent": self.parent,
            "args": self.attrs,
        })
        return False


class Tracer:
    """Thread-safe span/event collector.

    Spans are recorded at exit (duration events, ``ph="X"``), instantaneous
    events at emission (``ph="i"``); both carry microsecond timestamps
    relative to the tracer's construction epoch, the recording thread id,
    and a per-tracer span id / parent id for nesting round-trips."""

    def __init__(self):
        self._lock = threading.Lock()
        self._local = threading.local()
        self._epoch = time.perf_counter_ns()
        self._counter = 0
        self.records: list[dict] = []

    # -- recording ---------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _next_id(self) -> int:
        with self._lock:
            self._counter += 1
            return self._counter

    def _record(self, rec: dict) -> None:
        with self._lock:
            self.records.append(rec)

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        self._record({
            "ph": "i",
            "name": name,
            "ts": (time.perf_counter_ns() - self._epoch) / 1e3,
            "pid": _PID,
            "tid": threading.get_ident(),
            "id": self._next_id(),
            "parent": (self._stack() or [None])[-1],
            "args": attrs,
        })

    # -- inspection / export ----------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            return len(self.records)

    def spans(self, name: str | None = None) -> list[dict]:
        with self._lock:
            recs = list(self.records)
        return [r for r in recs
                if r["ph"] == "X" and (name is None or r["name"] == name)]

    def events(self, name: str | None = None) -> list[dict]:
        with self._lock:
            recs = list(self.records)
        return [r for r in recs
                if r["ph"] == "i" and (name is None or r["name"] == name)]

    def clear(self) -> None:
        with self._lock:
            self.records.clear()

    def export_jsonl(self, path: str | Path) -> int:
        """One record per line; returns the record count written."""
        with self._lock:
            recs = list(self.records)
        with open(path, "w") as f:
            for r in recs:
                f.write(json.dumps(r) + "\n")
        return len(recs)

    def export_chrome(self, path: str | Path) -> int:
        """Chrome trace-event JSON (chrome://tracing, ui.perfetto.dev)."""
        with self._lock:
            recs = list(self.records)
        events = []
        for r in recs:
            e = {"name": r["name"], "ph": r["ph"], "ts": r["ts"],
                 "pid": r["pid"], "tid": r["tid"], "args": dict(r["args"])}
            if r["ph"] == "X":
                e["dur"] = r["dur"]
            else:
                e["s"] = "t"  # thread-scoped instant
            events.append(e)
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
            f.write("\n")
        return len(events)


def load_jsonl(path: str | Path) -> list[dict]:
    """Parse a trace JSONL back into records (the export round-trip)."""
    recs = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{ln}: not valid JSON ({e})") from e
            for field in ("ph", "name", "ts"):
                if field not in rec:
                    raise ValueError(f"{path}:{ln}: missing field {field!r}")
            recs.append(rec)
    return recs


# ---------------------------------------------------------------------------
# Process-global enablement
# ---------------------------------------------------------------------------

_ACTIVE: Tracer | None = None
_EXIT_PATH: Path | None = None


def active() -> Tracer | None:
    """The installed tracer, or None when tracing is off."""
    return _ACTIVE


def span(name: str, **attrs):
    """A span against the active tracer; the shared no-op when tracing is
    off (the disabled path is one global read + one return)."""
    t = _ACTIVE
    if t is None:
        return _NULL_SPAN
    return t.span(name, **attrs)


def event(name: str, **attrs) -> None:
    """An instantaneous event against the active tracer; no-op when off."""
    t = _ACTIVE
    if t is not None:
        t.event(name, **attrs)


def install(tracer: Tracer | None) -> Tracer | None:
    """Install (or with None, remove) the process-global tracer; returns the
    previously installed one."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, tracer
    return prev


def enable(path: str | Path | None = None) -> Tracer:
    """Install a fresh process-global tracer; with `path`, also export the
    collected JSONL there at interpreter exit."""
    global _EXIT_PATH
    tr = Tracer()
    install(tr)
    if path is not None:
        _EXIT_PATH = Path(path)
    return tr


def disable() -> None:
    global _EXIT_PATH
    install(None)
    _EXIT_PATH = None


@atexit.register
def _export_at_exit() -> None:
    if _ACTIVE is not None and _EXIT_PATH is not None:
        try:
            _ACTIVE.export_jsonl(_EXIT_PATH)
        except OSError:
            pass


class tracing:
    """Scoped tracing for one call tree — the `decompose(..., trace=...)`
    switch.  `target` may be:

      * None / False — no-op (whatever tracer is active stays active);
      * True         — install a fresh Tracer for the scope;
      * str / Path   — fresh Tracer, exported as JSONL to that path on exit;
      * a Tracer     — install the caller's collector for the scope.

    The previously active tracer is restored on exit, so scoped traces nest
    under (and temporarily shadow) the REPRO_TRACE global tracer."""

    def __init__(self, target=None):
        self.target = target
        self.tracer: Tracer | None = None
        self._path: Path | None = None
        self._prev: Tracer | None = None
        self._installed = False

    def __enter__(self):
        t = self.target
        if t is None or t is False:
            self.tracer = _ACTIVE
            return self.tracer
        if isinstance(t, Tracer):
            self.tracer = t
        else:
            self.tracer = Tracer()
            if t is not True:
                self._path = Path(t)
        self._prev = install(self.tracer)
        self._installed = True
        return self.tracer

    def __exit__(self, *exc):
        if self._installed:
            install(self._prev)
            if self._path is not None:
                self.tracer.export_jsonl(self._path)
        return False


def configure_from_env() -> Tracer | None:
    """Apply the ``REPRO_TRACE`` switch: truthy values ("1"/"true"/"yes"/
    "on") enable collection; any other non-empty value enables collection
    AND exports JSONL to that path at exit; empty/unset leaves tracing off.
    Called once at import; call again after mutating the environment."""
    raw = os.environ.get("REPRO_TRACE", "").strip()
    if not raw:
        return None
    if raw.lower() in ("1", "true", "yes", "on"):
        return enable()
    return enable(raw)


configure_from_env()
