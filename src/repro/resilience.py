"""repro.resilience — the failure story of the planned decomposition engine.

The ROADMAP's decomposition-as-a-service frontier admits (tensor, algo, rank)
jobs against an HBM budget; this module collects everything that keeps such an
engine available when a job misbehaves:

  * **Numerical guards** (`GuardConfig`, policies "raise" / "restart" /
    "fallback") consumed by `PlannedWorkspace.drive`: non-finite fit detection
    is free (the fit scalar is already the one device->host sync per
    iteration), sustained fit regression fires after `divergence_patience`
    iterations, and factor finiteness is checked on an opt-in cadence.  On
    detection the drive loop raises a diagnostic `DecompositionDiverged`,
    restarts from jittered re-init (bounded by `max_restarts`), or degrades
    the Pallas sweep to the format's reference sweep mid-run, reusing the
    same padded factors.
  * **Plan integrity validation** (`validate_plan` / `PlanValidationError`,
    from `core.remap`): every BlockPlan invariant, opt-in on the hot paths
    via `REPRO_VALIDATE_PLANS=1` — at build time and on plan-cache hits.
  * **HBM admission control** (`admission_bytes` / `admit` /
    `plan_with_budget` / `AdmissionError`): a workspace's resident footprint
    is `plan_bytes()` (the per-mode remapped copies — the paper's Sec. 3
    space/time trade) + the padded device-resident factors + the PMS VMEM
    working set.  `plan_with_budget` is the graceful-degradation ladder
    behind `decompose(..., hbm_budget=...)`: halve the DMA block size (less
    group padding -> smaller layouts) down to a floor, then drop to the
    reference path, and only then raise `AdmissionError`.
  * **Checkpoint/resume** rides on `drive(checkpoint_every=, checkpoint_path=)`
    (see `kernels.workspace`), persisting padded factors + fit history via
    `train.checkpoint.CheckpointManager`.

The fault-injection harness proving each guard fires lives in
`repro.testing.faults`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from .core.loop import (  # noqa: F401  (re-exports: the guard surface)
    DecompositionDiverged,
    GuardConfig,
    GuardState,
)
from .core.memctrl import MemoryControllerConfig
from .core.remap import (  # noqa: F401  (re-exports: the validation surface)
    PlanValidationError,
    plans_validated,
    validate_plan,
)
from .obs import metrics as _metrics
from .obs import trace as _trace

__all__ = [
    "GuardConfig",
    "GuardState",
    "DecompositionDiverged",
    "PlanValidationError",
    "validate_plan",
    "plans_validated",
    "AdmissionError",
    "admission_bytes",
    "admit",
    "reference_footprint_bytes",
    "plan_with_budget",
]

#: The admission ladder never shrinks the DMA block size below this: Pallas
#: blocks narrower than one VPU sublane group stop resembling the modeled
#: hardware (and the group-padding savings have flattened out long before).
FLOOR_BLK = 8


class AdmissionError(RuntimeError):
    """No rung of the degradation ladder fits the HBM budget — not even the
    reference path's raw stream + true factors.  Carries the ladder of
    attempted configurations for the tenant's error report."""

    def __init__(self, budget_bytes: int, ladder: list[dict],
                 reference_bytes: int):
        self.budget_bytes = budget_bytes
        self.ladder = list(ladder)
        self.reference_bytes = reference_bytes
        tried = ", ".join(
            f"blk={a['blk']}: {a['total_bytes']:,}B" for a in ladder
        ) or "none"
        super().__init__(
            f"no configuration fits hbm_budget={budget_bytes:,}B — planned "
            f"rungs tried [{tried}]; reference path needs "
            f"{reference_bytes:,}B"
        )


def admission_bytes(ws: Any) -> dict:
    """Resident-footprint report of a planned workspace: the remapped layouts
    (`plan_bytes()`), the padded device-resident factors, and the PMS VMEM
    working-set model for the workspace's kernel family."""
    plan = int(ws.plan_bytes())
    fac = int(sum(
        rows * rp * 4 for rows, rp in zip(ws.padded_rows, ws.rank_pads)
    ))
    vmem = int(ws.vmem_model_bytes())
    return {
        "plan_bytes": plan,
        "factor_bytes": fac,
        "vmem_bytes": vmem,
        "total_bytes": plan + fac + vmem,
    }


def admit(ws: Any, budget_bytes: int) -> dict:
    """Admission check for a single workspace: return the
    `admission_bytes` report when it fits `budget_bytes`, raise
    `AdmissionError` otherwise.  Use `plan_with_budget` when a rebuild at a
    smaller configuration is an option."""
    report = admission_bytes(ws)
    if report["total_bytes"] > budget_bytes:
        _metrics.counter("admission.rejected").inc()
        _trace.event(
            "admission_rejected",
            total_bytes=report["total_bytes"],
            budget_bytes=int(budget_bytes),
        )
        raise AdmissionError(
            budget_bytes,
            [{"blk": None, **report}],
            report["total_bytes"],
        )
    _metrics.counter("admission.admitted", outcome="pallas").inc()
    return report


def reference_footprint_bytes(st: Any, lane_ranks) -> int:
    """HBM the reference (non-planned) path holds resident: the raw COO
    stream (one int32 coordinate per mode + one f32 value per non-zero) plus
    the true-shape f32 factors — the ladder's final rung."""
    stream = st.nnz * (st.nmodes + 1) * 4
    facs = sum(s * int(r) * 4 for s, r in zip(st.shape, lane_ranks))
    return int(stream + facs)


def plan_with_budget(
    build: Callable[[MemoryControllerConfig], Any],
    budget_bytes: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    floor_blk: int = FLOOR_BLK,
    reference_bytes: int = 0,
) -> tuple[Any, dict]:
    """The graceful-degradation ladder of `decompose(..., hbm_budget=...)`.

    Calls `build(cfg)` to construct a planned workspace and checks its
    `admission_bytes` total against the budget; while over budget, halves
    `cfg.dma.blk` (smaller blocks -> less per-group padding -> smaller
    remapped layouts) down to `floor_blk` and rebuilds.  When no planned
    rung fits, degrades to the reference path if `reference_bytes` fits,
    else raises `AdmissionError`.

    Returns `(workspace, decision)`: `workspace` is None when the caller
    should take the reference path; `decision` records the admitted rung and
    the full ladder of attempts.
    """
    cfg = cfg if cfg is not None else MemoryControllerConfig()
    attempts: list[dict] = []
    with _trace.span("admission_ladder", budget_bytes=int(budget_bytes)):
        while True:
            ws = build(cfg)
            report = admission_bytes(ws)
            attempts.append({"blk": cfg.dma.blk, **report})
            if report["total_bytes"] <= budget_bytes:
                _metrics.counter("admission.admitted", outcome="pallas").inc()
                _metrics.histogram("admission.ladder_rungs").observe(
                    len(attempts)
                )
                _trace.event(
                    "admission_rung", outcome="pallas", blk=cfg.dma.blk,
                    total_bytes=report["total_bytes"], rung=len(attempts),
                )
                return ws, {
                    "admitted": "pallas",
                    "blk": cfg.dma.blk,
                    "report": report,
                    "ladder": attempts,
                }
            _trace.event(
                "admission_rung", outcome="over_budget", blk=cfg.dma.blk,
                total_bytes=report["total_bytes"], rung=len(attempts),
            )
            if cfg.dma.blk // 2 >= floor_blk:
                cfg = dataclasses.replace(
                    cfg, dma=dataclasses.replace(cfg.dma, blk=cfg.dma.blk // 2)
                )
                continue
            break
        if reference_bytes <= budget_bytes:
            _metrics.counter("admission.admitted", outcome="reference").inc()
            _metrics.histogram("admission.ladder_rungs").observe(
                len(attempts) + 1
            )
            _trace.event(
                "admission_rung", outcome="reference",
                total_bytes=int(reference_bytes), rung=len(attempts) + 1,
            )
            return None, {
                "admitted": "reference",
                "report": {"total_bytes": int(reference_bytes)},
                "ladder": attempts,
            }
        _metrics.counter("admission.rejected").inc()
        _trace.event(
            "admission_rejected", budget_bytes=int(budget_bytes),
            rungs=len(attempts),
        )
    raise AdmissionError(budget_bytes, attempts, int(reference_bytes))
