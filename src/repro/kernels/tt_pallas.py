"""Blocked sorted-COO TT-core-update Pallas kernel — tensor-train ALS on the
same programmable memory controller as MTTKRP and TTMc.

The TT-ALS loop needs, per output mode m, the right-hand side of the core's
normal equations restricted to X's non-zeros: every nnz z contributes

    value_z * kron(l_z, r_z)           (rl_m * rr_m columns)

to output row i_m, where l_z is the LEFT interface chain
G_0[:, i_0, :] ... G_{m-1}[:, i_{m-1}, :]  (a row vector of width rl_m) and
r_z is the RIGHT interface chain G_{m+1}[:, i_{m+1}, :] ... G_{N-1} (a column
vector of width rr_m, applied to a vector of ones from the right).  That is
TTMc with the full Kronecker chain collapsed to a Kronecker of TWO chained
interfaces — the irregular memory access pattern is IDENTICAL, so the kernel
consumes the exact BlockPlan layout the Tensor Remapper builds for MTTKRP /
TTMc.  Engine mapping is unchanged (see kernels/mttkrp_pallas.py):

  * DMA Engine    — (nblocks, blk) BlockSpec stream tiles, double-buffered;
  * Cache Engine  — one (tile_n x rank_padded(rl_n*rr_n)) core-interface tile
                    per input mode, selected by scalar-prefetched tile ids;
  * Approach 1    — blocks sorted by output tile: the (tile_i x Pp)
                    accumulator is resident across its run, flushed once;
  * MXU           — segment accumulation as a one-hot matmul
                    (tile_i x blk) @ (blk x Pp).

Differences from the TTMc kernel: each input factor is a core's interface
matrix W_k = transpose(G_k, (1,0,2)).reshape(I_k, rl_k*rr_k) (row-major —
rl slow, rr fast), lane-padded to rank_padded(rl_k*rr_k); gathered rows fold
into the left chain (inputs left of the output mode, ascending) or the right
chain (inputs right of it, descending) as (blk, rl, rr) matrix-vector
products on the VPU, and the output carries rl_m * rr_m true columns
(lane-padded to rank_padded(rl_m*rr_m)).  `plan.in_modes` is ascending, so
n_left — the number of left-chain inputs — equals the output mode.

Validated in interpret=True mode against kernels/ref.py (CPU container; TPU
is the target).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .mttkrp_pallas import rank_padded

__all__ = ["ttcore_pallas_call", "tt_out_pair", "tt_out_cols"]


def tt_out_pair(
    in_rank_pairs: Sequence[tuple[int, int]], n_left: int
) -> tuple[int, int]:
    """The output core's interface pair (rl_m, rr_m), recovered from the
    input pairs: rl_m is the last left-chain factor's right bond (1 when the
    output is the first core), rr_m the first right-chain factor's left bond
    (1 when it is the last)."""
    n_in = len(in_rank_pairs)
    rl = in_rank_pairs[n_left - 1][1] if n_left > 0 else 1
    rr = in_rank_pairs[n_left][0] if n_left < n_in else 1
    return (rl, rr)


def tt_out_cols(in_rank_pairs: Sequence[tuple[int, int]], n_left: int) -> int:
    """Number of true output columns: rl_m * rr_m."""
    rl, rr = tt_out_pair(in_rank_pairs, n_left)
    return rl * rr


def _kernel(
    tile_i: int,
    n_in: int,
    in_rank_pairs: tuple[tuple[int, int], ...],
    n_left: int,
    *refs,
):
    """Template-unrolled kernel body for n_in core-interface tiles.

    refs layout is identical to the MTTKRP kernel (the plan layout is shared):
      [0]                    it_ref           scalar-prefetch: output tile ids
      [1 : 1+n_in]           input tile ids   (scalar-prefetch, unused in body)
      [1+n_in]               vals_ref         (1, blk)
      [2+n_in]               iloc_ref         (1, blk)
      [3+n_in : 3+2*n_in]    input local idx  (1, blk) each
      [3+2*n_in : 3+3*n_in]  interface tiles  (tile_n, rank_padded(rl*rr)) each
      [3+3*n_in]             out_ref          (tile_i, Pp)
    """
    it_ref = refs[0]
    vals_ref = refs[1 + n_in]
    iloc_ref = refs[2 + n_in]
    loc_refs = refs[3 + n_in : 3 + 2 * n_in]
    fac_refs = refs[3 + 2 * n_in : 3 + 3 * n_in]
    out_ref = refs[3 + 3 * n_in]

    b = pl.program_id(0)
    # Approach-1 accumulator management: zero on the first block of each
    # output tile's contiguous run (Tensor Remapper guarantees contiguity).
    prev = jnp.maximum(b - 1, 0)
    first_visit = jnp.logical_or(b == 0, it_ref[b] != it_ref[prev])

    @pl.when(first_visit)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[0, :]  # (blk,)
    il = iloc_ref[0, :]
    blk = vals.shape[0]

    def gathered(n):
        """One input's interface rows as (blk, rl, rr), lane padding sliced
        off before the chain so it never enters the product."""
        rl, rr = in_rank_pairs[n]
        rows = jnp.take(fac_refs[n][...], loc_refs[n][0, :], axis=0)
        return rows[:, : rl * rr].astype(jnp.float32).reshape(blk, rl, rr)

    # Left interface chain: row-vector times core matrix, ascending over the
    # inputs left of the output mode — (blk, rl) -> (blk, rr) per step.
    left = jnp.ones((blk, 1), jnp.float32)
    for n in range(n_left):
        left = jnp.sum(left[:, :, None] * gathered(n), axis=1)
    # Right interface chain: core matrix times column-vector, descending over
    # the inputs right of the output mode — (blk, rr) -> (blk, rl) per step.
    right = jnp.ones((blk, 1), jnp.float32)
    for n in range(n_in - 1, n_left - 1, -1):
        right = jnp.sum(gathered(n) * right[:, None, :], axis=2)

    # Kronecker of the two interfaces (rl_m slow, rr_m fast — the core-matrix
    # column convention), scaled by the stream values.
    contrib = vals[:, None].astype(jnp.float32) * (
        left[:, :, None] * right[:, None, :]
    ).reshape(blk, -1)

    # Zero-pad the true rl_m*rr_m columns up to the output tile's lane width.
    pp = out_ref.shape[1]
    if contrib.shape[1] < pp:
        contrib = jnp.concatenate(
            [contrib, jnp.zeros((blk, pp - contrib.shape[1]), jnp.float32)], axis=1
        )

    # MXU segment accumulation: one-hot (tile_i, blk) @ contrib (blk, Pp).
    rows_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_i, blk), 0)
    onehot = (rows_iota == il[None, :]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(onehot, contrib, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tile_i", "in_tiles", "in_rank_pairs", "n_left", "blk", "out_rows",
        "interpret",
    ),
)
def ttcore_pallas_call(
    block_it: jax.Array,  # (nblocks,) int32
    block_in: Sequence[jax.Array],  # N-1 x (nblocks,) int32 input tile ids
    vals: jax.Array,  # (nblocks, blk)
    iloc: jax.Array,  # (nblocks, blk) int32
    in_locs: Sequence[jax.Array],  # N-1 x (nblocks, blk) int32
    factors_pad: Sequence[jax.Array],  # N-1 x (rows_n, rank_padded(rl*rr))
    *,
    tile_i: int,
    in_tiles: tuple[int, ...],  # N-1 input tile sizes
    in_rank_pairs: tuple[tuple[int, int], ...],  # N-1 (rl, rr) bond pairs
    n_left: int,  # inputs left of the output mode (== the output mode)
    blk: int,
    out_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns (out_rows, rank_padded(rl_m*rr_m)) float32: the mode-m TT-ALS
    right-hand side B_m with columns row-major over (rl_m, rr_m).  Input
    interface matrices in plan.in_modes order (ascending), each lane-padded
    to its own rank_padded(rl_n*rr_n)."""
    block_in = tuple(block_in)
    in_locs = tuple(in_locs)
    factors_pad = tuple(factors_pad)
    in_rank_pairs = tuple((int(a), int(b)) for a, b in in_rank_pairs)
    n_in = len(in_tiles)
    assert len(block_in) == len(in_locs) == len(factors_pad) == n_in
    assert len(in_rank_pairs) == n_in
    assert 0 <= n_left <= n_in
    nblocks = vals.shape[0]
    pp = rank_padded(tt_out_cols(in_rank_pairs, n_left))

    def stream_spec():
        return pl.BlockSpec((1, blk), lambda b, it, *ts: (b, 0))

    def factor_spec(n):
        return pl.BlockSpec(
            (in_tiles[n], factors_pad[n].shape[1]),
            lambda b, it, *ts, n=n: (ts[n][b], 0),
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1 + n_in,  # output tile ids + one stream per input
        grid=(nblocks,),
        in_specs=(
            [stream_spec()]  # vals (DMA stream)
            + [stream_spec()]  # iloc
            + [stream_spec() for _ in range(n_in)]  # input local indices
            + [factor_spec(n) for n in range(n_in)]  # interface tiles (cache)
        ),
        out_specs=pl.BlockSpec((tile_i, pp), lambda b, it, *ts: (it[b], 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile_i, n_in, in_rank_pairs, n_left),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, pp), jnp.float32),
        interpret=interpret,
    )(block_it, *block_in, vals, iloc, *in_locs, *factors_pad)
