"""The `PlannedWorkspace` protocol: one shared implementation of everything a
planned decomposition workspace does that is NOT format-specific.

The paper's thesis is that the memory controller is *programmable* — one
remapped-COO data path serving many tensor kernels.  CP (MTTKRP), Tucker
(TTMc) and tensor-train (TT core update) all drive the same per-output-mode
BlockPlan layouts; what differs per format is only the per-mode contraction
and the factor-update math.  This module owns the shared layer:

  * rank padding + device-resident factor management (`pad_factors` /
    `unpad_factors` / `padded_rows` / `rank_pads`), parameterized by the one
    format-specific quantity — `lane_ranks`, each mode's true lane width
    (CP: R for every mode; Tucker: R_m; TT: rl_m * rr_m);
  * plan-per-mode amortization bookkeeping (`plan_bytes`, layout-byte
    accounting for both the single-device and shard-stacked layouts);
  * the lazily-compiled sweep cache (`sweep` builds `_build_sweep()` once);
  * `drive` — the host loop shared by every jitted path: pad once, one sweep
    per iteration, host-side tol early-exit, unpad at materialization;
  * visited-row masking (`_apply_row_mask` / `_visited_row_mask`) and the
    device-side plan arrays every kernel family consumes.

Format classes (`PlannedCPALS`, `PlannedTucker`, `PlannedTT` and their
sharded variants) subclass `PlannedWorkspace` / `ShardedWorkspace` and
provide only `lane_ranks`, `_geoms()` and `_build_sweep()` — the
format-specific sweep body IS the format.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.loop import finish_iter
from ..core.remap import BlockPlan
from .mttkrp_pallas import pad_factor, rank_padded

__all__ = [
    "PlannedWorkspace",
    "ShardedWorkspace",
    "planned_layout_bytes",
    "sharded_layout_bytes",
]


def _apply_row_mask(out: jax.Array, mask: jax.Array) -> jax.Array:
    """Zero the masked-out rows with `where`, NOT multiplication: unvisited
    tiles hold NaN in interpret mode and 0 * NaN = NaN."""
    return jnp.where(mask[:, None] > 0, out, 0.0)


def _visited_row_mask(block_it: np.ndarray, tile_i: int, out_rows: int) -> np.ndarray:
    """1.0 for every output row whose tile some block visits, else 0.0.

    The Pallas kernels zero an output tile only on its *first visit*; a tile
    no block targets keeps whatever the output buffer held (NaN in interpret
    mode, undefined on hardware).  Such tiles exist whenever a tile_i range
    of the output coordinate owns no non-zeros — their MTTKRP/TTMc/TT-core
    rows are mathematically zero, so every planned call multiplies by this
    mask."""
    ntiles = out_rows // tile_i
    tile_mask = np.zeros((ntiles,), np.float32)
    tile_mask[np.unique(block_it)] = 1.0
    return np.repeat(tile_mask, tile_i)


def _plan_device_arrays(plan: BlockPlan) -> dict:
    """Move a BlockPlan's layout to device in the shape the kernels consume:
    (nblocks, blk) stream tiles + per-block tile-id streams + the
    visited-row mask zeroing tiles the plan never touches."""
    nb, blk = plan.nblocks, plan.blk
    return dict(
        block_it=jnp.asarray(plan.block_it),
        block_in=tuple(jnp.asarray(t) for t in plan.block_in),
        vals=jnp.asarray(plan.vals).reshape(nb, blk),
        iloc=jnp.asarray(plan.iloc).reshape(nb, blk),
        in_locs=tuple(jnp.asarray(l).reshape(nb, blk) for l in plan.in_locs),
        row_mask=jnp.asarray(
            _visited_row_mask(plan.block_it, plan.tile_i, plan.out_rows)
        ),
    )


def planned_layout_bytes(ops: dict[int, Any]) -> int:
    """HBM held by a per-mode plan family's remapped layouts (the 'copies'
    space/time trade, Sec. 3).  Element widths come from each mode's Remapper
    configuration; identical for every kernel family — the layout is shared."""
    total = 0
    for op in ops.values():
        p, r = op.plan, op.cfg.remapper
        slots = p.vals.shape[0]
        total += slots * (r.value_bytes + (1 + p.n_in) * r.index_bytes)
        total += p.nblocks * (1 + p.n_in) * r.index_bytes
    return total


def sharded_layout_bytes(stacks: dict[int, Any], cfgs: dict[int, Any]) -> int:
    """HBM held by a per-mode shard-stack family, summed over every device
    (the distributed 'copies' trade: N layouts per shard) — the sharded
    analogue of `planned_layout_bytes`.  Counts the padded stack width, i.e.
    what is actually resident."""
    total = 0
    for m, s in stacks.items():
        r = cfgs[m].remapper
        slots = s.nshards * s.nblocks * s.blk
        total += slots * (r.value_bytes + (1 + s.n_in) * r.index_bytes)
        total += s.nshards * s.nblocks * (1 + s.n_in) * r.index_bytes
    return total


def _padded_rows_from(geoms: dict[int, Any], nmodes: int) -> tuple[int, ...]:
    """Shared row-padding rule over any per-mode layout family exposing
    BlockPlan geometry (`out_rows` / `in_modes` / `in_rows`): single-device
    plans and sharded `_ShardStack`s use identical padding, so factors can
    move between the two paths without re-padding."""
    rows = []
    for m in range(nmodes):
        r = geoms[m].out_rows
        for g in geoms.values():
            for n, im in enumerate(g.in_modes):
                if im == m:
                    r = max(r, g.in_rows[n])
        rows.append(r)
    return tuple(rows)


class PlannedWorkspace:
    """Base protocol of every planned decomposition workspace.

    Subclass contract (the entire per-format surface):
      * a `shape` attribute — the true tensor shape;
      * `lane_ranks` — each mode's true lane width (the factor's column
        count: CP R, Tucker R_m, TT rl_m*rr_m);
      * `_geoms()` — the per-mode layout family (BlockPlans or _ShardStacks)
        for the shared row-padding rule;
      * `_layout_bytes()` — HBM held by the layouts;
      * `_build_sweep()` — compile the format's jitted sweep; its result must
        accept rank-padded factors first and return
        (new padded factors, aux, fit).

    The base provides the padded-space residency contract shared by every
    format: `pad_factors` pads each mode ONCE for the whole decomposition (to
    the maximum row padding any plan needs, lanes to `rank_padded`); sweeps
    update factors in padded space, keeping padding rows/lanes exactly zero
    so grams/fits in padded space match the true-shape computation bit for
    bit; `unpad_factors` slices back only at materialization.
    """

    _sweep_fn = None  # instance attribute on first `sweep` call

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    @property
    def lane_ranks(self) -> tuple[int, ...]:
        """Per-mode true lane width of each factor (format-specific)."""
        raise NotImplementedError

    @property
    def rank_pads(self) -> tuple[int, ...]:
        """Per-mode lane padding: each factor padded to its own width."""
        return tuple(rank_padded(r) for r in self.lane_ranks)

    @property
    def padded_rows(self) -> tuple[int, ...]:
        """Per-mode device-resident row padding (see `_padded_rows_from`)."""
        return _padded_rows_from(self._geoms(), self.nmodes)

    def _geoms(self) -> dict[int, Any]:
        raise NotImplementedError

    def _layout_bytes(self) -> int:
        raise NotImplementedError

    def _build_sweep(self):
        raise NotImplementedError

    def pad_factors(self, factors: Sequence[jax.Array]) -> tuple[jax.Array, ...]:
        """One pad per mode for the whole decomposition (not N x iters)."""
        return tuple(
            pad_factor(f, rows, rp)
            for f, rows, rp in zip(factors, self.padded_rows, self.rank_pads)
        )

    def unpad_factors(self, padded: Sequence[jax.Array]) -> list[jax.Array]:
        return [
            f[:s, :r] for f, s, r in zip(padded, self.shape, self.lane_ranks)
        ]

    def plan_bytes(self) -> int:
        """HBM held by the per-mode layouts (the 'copies' trade, Sec. 3)."""
        return self._layout_bytes()

    def sweep(self, facs, *args, **kwargs):
        """One jitted iteration in padded space.

        `facs` is the factor tuple in PADDED space — one (padded_rows[m],
        rank_pads[m]) array per mode, as produced by `pad_factors` or a
        previous `sweep` call.  Invariant: padding rows and lanes are exactly
        zero on entry and are kept exactly zero on exit.  Returns (new padded
        factors, aux, fit), all device-resident — feeding the returned
        factors straight into the next call incurs zero host transfers and
        zero re-padding.  The compiled sweep is built lazily on first use and
        cached for the workspace's lifetime."""
        if self._sweep_fn is None:
            self._sweep_fn = self._build_sweep()
        return self._sweep_fn(facs, *args, **kwargs)

    def _sweep_call(self, facs, *args, it: int):
        """`drive`'s per-iteration hook; formats whose sweep takes the
        iteration count (CP's `first` retrace) override this."""
        return self.sweep(facs, *args)

    def drive(self, factors, args=(), *, iters: int, tol=None,
              verbose: bool = False, label: str = "decompose"):
        """The shared host loop of every jitted planned path: pad once, one
        compiled sweep per iteration, host-side tol early-exit on the fit
        scalar (the only device->host sync), unpad at materialization.
        Returns (true-shape factors, aux from the last sweep, fit history)."""
        fits: list[float] = []
        facs = self.pad_factors(factors)
        aux = None
        for it in range(iters):
            facs, aux, fit = self._sweep_call(facs, *args, it=it)
            if finish_iter(fits, fit, it, tol, verbose, label):
                break
        return self.unpad_factors(facs), aux, fits


class ShardedWorkspace(PlannedWorkspace):
    """Base of the distributed workspaces (repro.dist.planned): the same
    protocol over per-mode `_ShardStack`s — shard d of mode m's stack holds
    the remapped, device-resident layout of shard d's slice of the stream —
    with the sweep running as one jitted shard_map.  Subclasses additionally
    carry `stacks` / `dist` / `cfgs`; `_stream_args()` supplies the
    shard-stacked fit stream for formats whose fit walks the non-zeros."""

    @property
    def nshards(self) -> int:
        return self.dist.dp_size()

    def _geoms(self) -> dict[int, Any]:
        return self.stacks

    def _layout_bytes(self) -> int:
        return sharded_layout_bytes(self.stacks, self.cfgs)

    def _stream_args(self) -> tuple:
        return ()

    def sweep(self, facs, *args, **kwargs):
        """One jitted distributed iteration in padded space — the
        `PlannedWorkspace.sweep` contract minus any stream arguments (each
        shard's slice already lives on its device)."""
        if self._sweep_fn is None:
            self._sweep_fn = self._build_sweep()
        arrs = {m: self.stacks[m].tree() for m in range(self.nmodes)}
        return self._sweep_fn(arrs, *self._stream_args(), facs, *args, **kwargs)
