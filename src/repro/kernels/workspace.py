"""The `PlannedWorkspace` protocol: one shared implementation of everything a
planned decomposition workspace does that is NOT format-specific.

The paper's thesis is that the memory controller is *programmable* — one
remapped-COO data path serving many tensor kernels.  CP (MTTKRP), Tucker
(TTMc) and tensor-train (TT core update) all drive the same per-output-mode
BlockPlan layouts; what differs per format is only the per-mode contraction
and the factor-update math.  This module owns the shared layer:

  * rank padding + device-resident factor management (`pad_factors` /
    `unpad_factors` / `padded_rows` / `rank_pads`), parameterized by the one
    format-specific quantity — `lane_ranks`, each mode's true lane width
    (CP: R for every mode; Tucker: R_m; TT: rl_m * rr_m);
  * plan-per-mode amortization bookkeeping (`plan_bytes`, layout-byte
    accounting for both the single-device and shard-stacked layouts);
  * the lazily-compiled sweep cache (`sweep` builds `_build_sweep()` once);
  * `drive` — the host loop shared by every jitted path: pad once, one sweep
    per iteration, host-side tol early-exit, unpad at materialization;
  * visited-row masking (`_apply_row_mask` / `_visited_row_mask`) and the
    device-side plan arrays every kernel family consumes.

Format classes (`PlannedCPALS`, `PlannedTucker`, `PlannedTT` and their
sharded variants) subclass `PlannedWorkspace` / `ShardedWorkspace` and
provide only `lane_ranks`, `_geoms()` and `_build_sweep()` — the
format-specific sweep body IS the format.
"""
from __future__ import annotations

import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.loop import DecompositionDiverged, GuardState, finish_iter
from ..core.remap import BlockPlan
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .mttkrp_pallas import pad_factor, rank_padded

__all__ = [
    "PlannedWorkspace",
    "ShardedWorkspace",
    "planned_layout_bytes",
    "sharded_layout_bytes",
    "plan_stream",
]


def plan_stream(plan: BlockPlan) -> tuple[np.ndarray, np.ndarray]:
    """Reconstruct a COO stream equivalent to a plan's remapped layout, for
    reference-sweep fallbacks whose drivers never kept the raw stream (Tucker's
    sweep takes no stream arguments).  Padding slots carry value 0.0 and
    in-bounds local coordinates, so they contribute nothing to any
    scatter/inner-product the reference kernels run."""
    blk = plan.blk
    cols: dict[int, np.ndarray] = {
        plan.mode: (
            np.repeat(plan.block_it.astype(np.int64), blk) * plan.tile_i
            + plan.iloc.astype(np.int64)
        )
    }
    for n, im in enumerate(plan.in_modes):
        cols[im] = (
            np.repeat(plan.block_in[n].astype(np.int64), blk) * plan.in_tiles[n]
            + plan.in_locs[n].astype(np.int64)
        )
    nmodes = 1 + plan.n_in
    idx = np.stack([cols[m] for m in range(nmodes)], axis=1).astype(np.int32)
    return idx, np.asarray(plan.vals)


@jax.jit
def _finite_flag(facs):
    return jnp.stack([jnp.isfinite(f).all() for f in facs]).all()


def _factors_finite(facs) -> bool:
    """One host sync for the whole factor tuple (guards' cadence check).
    The reduction is jitted: eager per-factor dispatch costs more than the
    check itself on the drive loop's hot path."""
    return bool(_finite_flag(tuple(facs)))


def _jitter_factors(factors, attempt: int):
    """Deterministic restart re-init: the original factors plus a small
    relative jitter (1e-4 of each factor's scale), keyed by the attempt
    number.  Staying near the original init keeps the restarted trajectory's
    final fit within the clean run's convergence basin — a fresh random seed
    would land on a different seed-dependent fit entirely."""
    key = jax.random.PRNGKey(0x5EED + attempt)
    out = []
    for i, f in enumerate(factors):
        k = jax.random.fold_in(key, i)
        scale = 1e-4 * (jnp.std(f) + 1e-12)
        out.append(f + scale * jax.random.normal(k, f.shape, f.dtype))
    return out


def _apply_row_mask(out: jax.Array, mask: jax.Array) -> jax.Array:
    """Zero the masked-out rows with `where`, NOT multiplication: unvisited
    tiles hold NaN in interpret mode and 0 * NaN = NaN."""
    return jnp.where(mask[:, None] > 0, out, 0.0)


def _visited_row_mask(block_it: np.ndarray, tile_i: int, out_rows: int) -> np.ndarray:
    """1.0 for every output row whose tile some block visits, else 0.0.

    The Pallas kernels zero an output tile only on its *first visit*; a tile
    no block targets keeps whatever the output buffer held (NaN in interpret
    mode, undefined on hardware).  Such tiles exist whenever a tile_i range
    of the output coordinate owns no non-zeros — their MTTKRP/TTMc/TT-core
    rows are mathematically zero, so every planned call multiplies by this
    mask."""
    ntiles = out_rows // tile_i
    tile_mask = np.zeros((ntiles,), np.float32)
    tile_mask[np.unique(block_it)] = 1.0
    return np.repeat(tile_mask, tile_i)


def _plan_device_arrays(plan: BlockPlan) -> dict:
    """Move a BlockPlan's layout to device in the shape the kernels consume:
    (nblocks, blk) stream tiles + per-block tile-id streams + the
    visited-row mask zeroing tiles the plan never touches."""
    nb, blk = plan.nblocks, plan.blk
    return dict(
        block_it=jnp.asarray(plan.block_it),
        block_in=tuple(jnp.asarray(t) for t in plan.block_in),
        vals=jnp.asarray(plan.vals).reshape(nb, blk),
        iloc=jnp.asarray(plan.iloc).reshape(nb, blk),
        in_locs=tuple(jnp.asarray(l).reshape(nb, blk) for l in plan.in_locs),
        row_mask=jnp.asarray(
            _visited_row_mask(plan.block_it, plan.tile_i, plan.out_rows)
        ),
    )


def planned_layout_bytes(ops: dict[int, Any]) -> int:
    """HBM held by a per-mode plan family's remapped layouts (the 'copies'
    space/time trade, Sec. 3).  Element widths come from each mode's Remapper
    configuration; identical for every kernel family — the layout is shared."""
    total = 0
    for op in ops.values():
        p, r = op.plan, op.cfg.remapper
        slots = p.vals.shape[0]
        total += slots * (r.value_bytes + (1 + p.n_in) * r.index_bytes)
        total += p.nblocks * (1 + p.n_in) * r.index_bytes
    return total


def sharded_layout_bytes(stacks: dict[int, Any], cfgs: dict[int, Any]) -> int:
    """HBM held by a per-mode shard-stack family, summed over every device
    (the distributed 'copies' trade: N layouts per shard) — the sharded
    analogue of `planned_layout_bytes`.  Counts the padded stack width, i.e.
    what is actually resident."""
    total = 0
    for m, s in stacks.items():
        r = cfgs[m].remapper
        slots = s.nshards * s.nblocks * s.blk
        total += slots * (r.value_bytes + (1 + s.n_in) * r.index_bytes)
        total += s.nshards * s.nblocks * (1 + s.n_in) * r.index_bytes
    return total


def _padded_rows_from(geoms: dict[int, Any], nmodes: int) -> tuple[int, ...]:
    """Shared row-padding rule over any per-mode layout family exposing
    BlockPlan geometry (`out_rows` / `in_modes` / `in_rows`): single-device
    plans and sharded `_ShardStack`s use identical padding, so factors can
    move between the two paths without re-padding."""
    rows = []
    for m in range(nmodes):
        r = geoms[m].out_rows
        for g in geoms.values():
            for n, im in enumerate(g.in_modes):
                if im == m:
                    r = max(r, g.in_rows[n])
        rows.append(r)
    return tuple(rows)


class PlannedWorkspace:
    """Base protocol of every planned decomposition workspace.

    Subclass contract (the entire per-format surface):
      * a `shape` attribute — the true tensor shape;
      * `lane_ranks` — each mode's true lane width (the factor's column
        count: CP R, Tucker R_m, TT rl_m*rr_m);
      * `_geoms()` — the per-mode layout family (BlockPlans or _ShardStacks)
        for the shared row-padding rule;
      * `_layout_bytes()` — HBM held by the layouts;
      * `_build_sweep()` — compile the format's jitted sweep; its result must
        accept rank-padded factors first and return
        (new padded factors, aux, fit).

    The base provides the padded-space residency contract shared by every
    format: `pad_factors` pads each mode ONCE for the whole decomposition (to
    the maximum row padding any plan needs, lanes to `rank_padded`); sweeps
    update factors in padded space, keeping padding rows/lanes exactly zero
    so grams/fits in padded space match the true-shape computation bit for
    bit; `unpad_factors` slices back only at materialization.
    """

    _sweep_fn = None  # instance attribute on first `sweep` call
    _fallback_fn = None  # instance attribute on first fallback degradation

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    @property
    def lane_ranks(self) -> tuple[int, ...]:
        """Per-mode true lane width of each factor (format-specific)."""
        raise NotImplementedError

    @property
    def rank_pads(self) -> tuple[int, ...]:
        """Per-mode lane padding: each factor padded to its own width."""
        return tuple(rank_padded(r) for r in self.lane_ranks)

    @property
    def padded_rows(self) -> tuple[int, ...]:
        """Per-mode device-resident row padding (see `_padded_rows_from`)."""
        return _padded_rows_from(self._geoms(), self.nmodes)

    def _geoms(self) -> dict[int, Any]:
        raise NotImplementedError

    def _layout_bytes(self) -> int:
        raise NotImplementedError

    def _build_sweep(self):
        raise NotImplementedError

    def pad_factors(self, factors: Sequence[jax.Array]) -> tuple[jax.Array, ...]:
        """One pad per mode for the whole decomposition (not N x iters)."""
        return tuple(
            pad_factor(f, rows, rp)
            for f, rows, rp in zip(factors, self.padded_rows, self.rank_pads)
        )

    def unpad_factors(self, padded: Sequence[jax.Array]) -> list[jax.Array]:
        return [
            f[:s, :r] for f, s, r in zip(padded, self.shape, self.lane_ranks)
        ]

    def plan_bytes(self) -> int:
        """HBM held by the per-mode layouts (the 'copies' trade, Sec. 3)."""
        return self._layout_bytes()

    def sweep(self, facs, *args, **kwargs):
        """One jitted iteration in padded space.

        `facs` is the factor tuple in PADDED space — one (padded_rows[m],
        rank_pads[m]) array per mode, as produced by `pad_factors` or a
        previous `sweep` call.  Invariant: padding rows and lanes are exactly
        zero on entry and are kept exactly zero on exit.  Returns (new padded
        factors, aux, fit), all device-resident — feeding the returned
        factors straight into the next call incurs zero host transfers and
        zero re-padding.  The compiled sweep is built lazily on first use and
        cached for the workspace's lifetime."""
        if self._sweep_fn is None:
            self._sweep_fn = self._build_sweep()
        return self._sweep_fn(facs, *args, **kwargs)

    def _sweep_call(self, facs, *args, it: int):
        """`drive`'s per-iteration hook; formats whose sweep takes the
        iteration count (CP's `first` retrace) override this."""
        return self.sweep(facs, *args)

    def _build_fallback_sweep(self):
        """Compile the format's REFERENCE sweep as a drive-compatible callable
        `(facs, *args, it=...) -> (facs, aux, fit)` operating on the same
        padded factors — the graceful-degradation target of the "fallback"
        guard policy (pallas -> reference mid-run without re-padding).  Return
        None if the workspace has no reference path (sharded workspaces)."""
        return None

    def _fallback_sweep(self):
        if self._fallback_fn is None:
            self._fallback_fn = self._build_fallback_sweep()
        return self._fallback_fn

    def vmem_model_bytes(self) -> int:
        """Peak VMEM working set the PMS model predicts for this workspace's
        kernel family — part of the admission total (`repro.resilience.admit`).
        Format classes supply the per-kind formula; the base contributes 0."""
        return 0

    def drive(self, factors, args=(), *, iters: int, tol=None,
              verbose: bool = False, label: str = "decompose",
              guards=None, reinit=None,
              checkpoint_every: int | None = None, checkpoint_path=None):
        """The shared host loop of every jitted planned path: pad once, one
        compiled sweep per iteration, host-side tol early-exit on the fit
        scalar (the only device->host sync), unpad at materialization.
        Returns (true-shape factors, aux from the last sweep, fit history).

        Resilience surface (repro.resilience):
          * guards — a `GuardConfig`; each iteration's fit scalar feeds the
            divergence tracker for free, plus an optional factor-finiteness
            check every `check_factors_every` iterations.  On detection the
            policy either raises `DecompositionDiverged`, restarts from
            jittered re-init (`reinit(attempt)` if given, else the original
            factors + deterministic 1e-4 jitter; at most `max_restarts`
            times), or degrades to the format's reference sweep reusing the
            last good padded factors.
          * checkpoint_every/checkpoint_path — persist (padded factors, fit
            history) every k iterations via `train.checkpoint`; when the
            directory already holds a checkpoint, `drive` resumes from it
            bit-for-bit instead of starting over.
        """
        gs = GuardState(guards) if guards is not None else None
        fits: list[float] = []
        facs = self.pad_factors(factors)
        aux = None
        sweep_call = self._sweep_call
        fb_active = False

        ckpt = None
        start = 0
        if checkpoint_path is not None:
            from ..train.checkpoint import CheckpointManager

            if checkpoint_every is None:
                checkpoint_every = 1
            elif checkpoint_every < 1:
                raise ValueError("checkpoint_every must be >= 1")
            ckpt = CheckpointManager(checkpoint_path, keep=2)
            step = ckpt.latest_step()
            if step is not None:
                step, tree = ckpt.restore(step)
                saved = tuple(tree["facs"])
                want = tuple(f.shape for f in facs)
                got = tuple(tuple(f.shape) for f in saved)
                # Padded shapes alone cannot distinguish ranks below the
                # lane width (both pad to the same lanes), so the true
                # lane_ranks ride along in the checkpoint.
                saved_lr = tuple(int(r) for r in np.asarray(
                    tree.get("lane_ranks", self.lane_ranks)).ravel())
                if got != want or saved_lr != tuple(self.lane_ranks):
                    raise ValueError(
                        f"checkpoint at {checkpoint_path!r} holds padded "
                        f"factors of shapes {got} (lane ranks {saved_lr}) "
                        f"but this workspace pads to {want} (lane ranks "
                        f"{tuple(self.lane_ranks)}); it was written by a "
                        f"different tensor/rank/workspace"
                    )
                facs = tuple(jnp.asarray(f) for f in saved)
                fits = [float(f) for f in np.asarray(tree["fits"]).ravel()]
                start = int(step) + 1
                _metrics.counter("resilience.resumes", label=label).inc()
                _trace.event("checkpoint_resume", label=label, step=int(step))
                if verbose:
                    print(f"[{label}] resumed from checkpoint step {step} "
                          f"({len(fits)} fits recorded)")
        elif checkpoint_every is not None:
            raise ValueError("checkpoint_every requires checkpoint_path")

        # Per-iteration observability (docs/observability.md): metric
        # handles are resolved once so the hot loop pays no registry lookup;
        # the per-sweep span carries the PMS-predicted sweep time when the
        # format exposes it, which is what `obs.calibrate.join_trace` joins
        # achieved_pct from.
        m_iter = _metrics.histogram("drive.iter_seconds", label=label)
        m_delta = _metrics.histogram("drive.fit_delta", label=label)
        m_count = _metrics.counter("drive.iterations", label=label)
        predicted_s = (
            self._predicted_sweep_s() if _trace.active() is not None else None
        )

        it = start
        prev_facs = None  # one-step history: the fallback rebase target
        with _trace.span("drive", label=label, iters=iters, start=start):
            while it < iters:
                t_sweep = time.perf_counter()
                with _trace.span("sweep", label=label, it=it,
                                 predicted_s=predicted_s):
                    new_facs, aux, fit = sweep_call(facs, *args, it=it)
                    fit = float(fit)
                m_iter.observe(time.perf_counter() - t_sweep)
                m_count.inc()
                if fits:
                    m_delta.observe(fit - fits[-1])
                reason = None
                if gs is not None:
                    reason = gs.observe_fit(fit)
                    if (reason is None and gs.cfg.check_factors_every > 0
                            and (it + 1) % gs.cfg.check_factors_every == 0
                            and not _factors_finite(new_facs)):
                        reason = "non-finite factor entries"
                if reason is not None:
                    policy = gs.cfg.policy
                    if policy == "restart" and gs.restarts < gs.cfg.max_restarts:
                        gs.restarts += 1
                        _metrics.counter("resilience.restarts", label=label).inc()
                        _trace.event("guard_restart", label=label, it=it,
                                     reason=reason, attempt=gs.restarts)
                        if verbose:
                            print(f"[{label}] iter {it:3d} {reason}; restart "
                                  f"{gs.restarts}/{gs.cfg.max_restarts} with "
                                  f"jittered re-init")
                        base = (reinit(gs.restarts) if reinit is not None
                                else _jitter_factors(factors, gs.restarts))
                        facs = self.pad_factors(base)
                        fits = []
                        gs.reset()
                        it = 0
                        continue
                    if policy == "fallback" and not fb_active:
                        fb = self._fallback_sweep()
                        if fb is not None:
                            fb_active = True
                            sweep_call = fb
                            gs.reset()
                            _metrics.counter(
                                "resilience.fallbacks", label=label).inc()
                            _trace.event("guard_fallback", label=label,
                                         it=it, reason=reason)
                            # The current iterate may itself be corrupted (its
                            # fit looked fine when it was accepted, e.g. a factor
                            # poisoned after the fit was computed): rebase onto
                            # the previous accepted iterate and redo the tainted
                            # iteration in place, so the run loses no sweeps.
                            if not _factors_finite(facs) and prev_facs is not None:
                                facs = prev_facs
                                if fits:
                                    fits.pop()
                                it -= 1
                            if verbose:
                                print(f"[{label}] iter {it:3d} {reason}; "
                                      f"degrading to the reference sweep on the "
                                      f"last good factors")
                            continue  # retry this iteration on the good iterate
                        reason += " (no reference fallback sweep for this workspace)"
                    elif policy == "fallback":
                        reason += " (already running the reference fallback)"
                    elif policy == "restart":
                        reason += (f" (restart budget of {gs.cfg.max_restarts} "
                                   f"exhausted)")
                    _metrics.counter("resilience.diverged", label=label).inc()
                    _trace.event("guard_diverged", label=label, it=it,
                                 reason=reason)
                    raise DecompositionDiverged(label, it, reason, fits + [fit])
                prev_facs, facs = facs, new_facs
                stop = finish_iter(fits, fit, it, tol, verbose, label)
                if ckpt is not None and (
                    stop or it + 1 == iters or (it + 1) % checkpoint_every == 0
                ):
                    with _trace.span("checkpoint_save", label=label, it=it):
                        ckpt.save(
                            it, {"facs": tuple(facs),
                                 "fits": np.asarray(fits, np.float64),
                                 "lane_ranks": np.asarray(self.lane_ranks, np.int64)}
                        )
                if stop:
                    break
                it += 1
        return self.unpad_factors(facs), aux, fits

    def _predicted_sweep_s(self) -> float | None:
        """PMS-predicted seconds for one full sweep when the format exposes
        `pms_estimates` (PlannedCPALS / PlannedTucker / PlannedTT); None
        otherwise.  Attached to traced sweep spans so a trace JSONL alone
        carries everything `obs.calibrate.join_trace` needs."""
        hook = getattr(self, "pms_estimates", None)
        if hook is None:
            return None
        return float(sum(e.t_total for e in hook().values()))


class ShardedWorkspace(PlannedWorkspace):
    """Base of the distributed workspaces (repro.dist.planned): the same
    protocol over per-mode `_ShardStack`s — shard d of mode m's stack holds
    the remapped, device-resident layout of shard d's slice of the stream —
    with the sweep running as one jitted shard_map.  Subclasses additionally
    carry `stacks` / `dist` / `cfgs`; `_stream_args()` supplies the
    shard-stacked fit stream for formats whose fit walks the non-zeros."""

    @property
    def nshards(self) -> int:
        return self.dist.dp_size()

    def _geoms(self) -> dict[int, Any]:
        return self.stacks

    def _layout_bytes(self) -> int:
        return sharded_layout_bytes(self.stacks, self.cfgs)

    def _stream_args(self) -> tuple:
        return ()

    def sweep(self, facs, *args, **kwargs):
        """One jitted distributed iteration in padded space — the
        `PlannedWorkspace.sweep` contract minus any stream arguments (each
        shard's slice already lives on its device)."""
        if self._sweep_fn is None:
            self._sweep_fn = self._build_sweep()
        arrs = {m: self.stacks[m].tree() for m in range(self.nmodes)}
        return self._sweep_fn(arrs, *self._stream_args(), facs, *args, **kwargs)
