"""Pallas kernel layer: the BlockPlan-driven memory controller for MTTKRP
(mttkrp_pallas) and the Tucker TTM-chain (ttm_pallas), plan construction +
dispatch (ops), and pure-jnp oracles (ref)."""
from .mttkrp_pallas import mttkrp_pallas_call, pad_factor, rank_padded
from .ttm_pallas import cols_padded, kron_cols, ttmc_pallas_call
from .ops import (
    PlannedCPALS,
    PlannedMTTKRP,
    PlannedTTMC,
    ShardedPlannedCPALS,
    ShardedPlannedMTTKRP,
    ShardedPlannedTucker,
    make_planned_cp_als,
    make_planned_mttkrp,
    make_planned_ttmc,
    make_sharded_planned_cp_als,
    make_sharded_planned_mttkrp,
    make_sharded_planned_tucker,
    mttkrp_auto,
    plan_cache_clear,
    plan_cache_stats,
    planned_padded_rows,
    tucker_auto,
)
from .ref import (
    mttkrp_plan_ref,
    mttkrp_ref,
    mttkrp_ref_dense,
    ttmc_plan_ref,
    ttmc_ref,
    ttmc_ref_dense,
)

__all__ = [
    "mttkrp_pallas_call",
    "pad_factor",
    "rank_padded",
    "ttmc_pallas_call",
    "cols_padded",
    "kron_cols",
    "PlannedCPALS",
    "PlannedMTTKRP",
    "PlannedTTMC",
    "ShardedPlannedCPALS",
    "ShardedPlannedMTTKRP",
    "ShardedPlannedTucker",
    "make_planned_cp_als",
    "make_planned_mttkrp",
    "make_planned_ttmc",
    "make_sharded_planned_cp_als",
    "make_sharded_planned_mttkrp",
    "make_sharded_planned_tucker",
    "mttkrp_auto",
    "tucker_auto",
    "plan_cache_clear",
    "plan_cache_stats",
    "planned_padded_rows",
    "mttkrp_ref",
    "mttkrp_ref_dense",
    "mttkrp_plan_ref",
    "ttmc_ref",
    "ttmc_ref_dense",
    "ttmc_plan_ref",
]
