"""Pallas MTTKRP kernel layer: the BlockPlan-driven memory controller
(mttkrp_pallas), plan construction + dispatch (ops), and pure-jnp oracles
(ref)."""
from .mttkrp_pallas import mttkrp_pallas_call, pad_factor, rank_padded
from .ops import (
    PlannedCPALS,
    PlannedMTTKRP,
    make_planned_cp_als,
    make_planned_mttkrp,
    mttkrp_auto,
    plan_cache_clear,
    plan_cache_stats,
)
from .ref import mttkrp_ref, mttkrp_ref_dense, mttkrp_plan_ref

__all__ = [
    "mttkrp_pallas_call",
    "pad_factor",
    "rank_padded",
    "PlannedCPALS",
    "PlannedMTTKRP",
    "make_planned_cp_als",
    "make_planned_mttkrp",
    "mttkrp_auto",
    "plan_cache_clear",
    "plan_cache_stats",
    "mttkrp_ref",
    "mttkrp_ref_dense",
    "mttkrp_plan_ref",
]
