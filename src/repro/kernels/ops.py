"""Jit'd wrappers for the MTTKRP kernels: plan construction + padding +
dispatch between the Pallas kernel, its interpret-mode validation path, and
the pure-JAX approaches.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.coo import SparseTensor
from ..core.memctrl import MemoryControllerConfig, TPUSpec
from ..core.pms import search as pms_search
from ..core.remap import BlockPlan, plan_blocks
from ..core.mttkrp import mttkrp as mttkrp_jax
from .mttkrp_pallas import mttkrp_pallas_call, pad_factor, rank_padded

__all__ = ["PlannedMTTKRP", "make_planned_mttkrp", "mttkrp_auto"]


@dataclasses.dataclass
class PlannedMTTKRP:
    """A compiled memory-controller instance for one (tensor, mode): the
    device-resident BlockPlan layout + a callable running the Pallas kernel."""

    plan: BlockPlan
    rank: int
    interpret: bool
    _dev: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        p = self.plan
        nb, blk = p.nblocks, p.blk
        self._dev = dict(
            block_it=jnp.asarray(p.block_it),
            block_jt=jnp.asarray(p.block_jt),
            block_kt=jnp.asarray(p.block_kt),
            vals=jnp.asarray(p.vals).reshape(nb, blk),
            iloc=jnp.asarray(p.iloc).reshape(nb, blk),
            jloc=jnp.asarray(p.jloc).reshape(nb, blk),
            kloc=jnp.asarray(p.kloc).reshape(nb, blk),
        )

    def __call__(self, factor_j: jax.Array, factor_k: jax.Array) -> jax.Array:
        """factors for the two *input* modes (plan.in_modes order).
        Returns (out_rows_unpadded, rank)."""
        p = self.plan
        rp = rank_padded(self.rank)
        b_pad = pad_factor(factor_j, p.rows_j, rp)
        c_pad = pad_factor(factor_k, p.rows_k, rp)
        out = mttkrp_pallas_call(
            self._dev["block_it"],
            self._dev["block_jt"],
            self._dev["block_kt"],
            self._dev["vals"],
            self._dev["iloc"],
            self._dev["jloc"],
            self._dev["kloc"],
            b_pad,
            c_pad,
            tile_i=p.tile_i,
            tile_j=p.tile_j,
            tile_k=p.tile_k,
            blk=p.blk,
            out_rows=p.out_rows,
            interpret=self.interpret,
        )
        return out[: p.out_rows, : self.rank]

    def output(self, factors: Sequence[jax.Array], true_rows: int) -> jax.Array:
        fj = factors[self.plan.in_modes[0]]
        fk = factors[self.plan.in_modes[1]]
        return self(fj, fk)[:true_rows]


def make_planned_mttkrp(
    st: SparseTensor,
    mode: int,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool = False,
    spec: TPUSpec = TPUSpec(),
    interpret: bool = True,
) -> PlannedMTTKRP:
    """Build the memory layout (Tensor Remapper) + kernel instance.  With
    auto_tune=True the PMS picks the controller parameters (Sec. 5.3)."""
    if auto_tune:
        best = pms_search(st, mode, rank, spec=spec, top_k=1)[0]
        cfg = best.cfg
    cfg = cfg or MemoryControllerConfig()
    plan = plan_blocks(
        st,
        mode,
        tile_i=cfg.cache.tile_i,
        tile_j=cfg.cache.tile_j,
        tile_k=cfg.cache.tile_k,
        blk=cfg.dma.blk,
    )
    return PlannedMTTKRP(plan=plan, rank=rank, interpret=interpret)


def mttkrp_auto(
    st: SparseTensor,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    method: str = "pallas",
    interpret: bool = True,
    cfg: MemoryControllerConfig | None = None,
) -> jax.Array:
    """One-shot dispatcher used by tests/benchmarks: 'pallas' | 'approach1' |
    'approach2'."""
    rank = int(factors[0].shape[1])
    if method == "pallas":
        op = make_planned_mttkrp(st, mode, rank, cfg=cfg, interpret=interpret)
        return op.output(factors, st.shape[mode])
    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    return mttkrp_jax(idx, val, factors, mode, st.shape[mode], method=method)
