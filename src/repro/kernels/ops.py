"""Jit'd wrappers for the MTTKRP kernels: plan construction + padding +
dispatch between the Pallas kernel, its interpret-mode validation path, and
the pure-JAX approaches.

`PlannedCPALS` is the workspace that makes the Pallas kernel the *production*
decomposition path (paper Alg. 1 + Alg. 5): one PMS-tunable BlockPlan +
device-resident layout per output mode, built once and cached across every
ALS iteration (the paper's layout="copies" posture — per-mode remapped
copies, a legitimate space/time trade on HBM).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from ..core.coo import SparseTensor
from ..core.cp_als import _update_mode, fit_value
from ..core.memctrl import MemoryControllerConfig, TPUSpec
from ..core.pms import search as pms_search
from ..core.remap import BlockPlan, plan_blocks
from ..core.mttkrp import mttkrp as mttkrp_jax
from .mttkrp_pallas import mttkrp_pallas_call, pad_factor, rank_padded

__all__ = [
    "PlannedMTTKRP",
    "make_planned_mttkrp",
    "PlannedCPALS",
    "make_planned_cp_als",
    "mttkrp_auto",
    "plan_cache_stats",
    "plan_cache_clear",
]


@dataclasses.dataclass
class PlannedMTTKRP:
    """A compiled memory-controller instance for one (tensor, mode): the
    device-resident BlockPlan layout + a callable running the Pallas kernel."""

    plan: BlockPlan
    rank: int
    interpret: bool
    cfg: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig
    )
    _dev: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        p = self.plan
        nb, blk = p.nblocks, p.blk
        self._dev = dict(
            block_it=jnp.asarray(p.block_it),
            block_in=tuple(jnp.asarray(t) for t in p.block_in),
            vals=jnp.asarray(p.vals).reshape(nb, blk),
            iloc=jnp.asarray(p.iloc).reshape(nb, blk),
            in_locs=tuple(jnp.asarray(l).reshape(nb, blk) for l in p.in_locs),
        )

    def __call__(self, *in_factors: jax.Array) -> jax.Array:
        """Factors for the N-1 *input* modes (plan.in_modes order).
        Returns (out_rows_unpadded, rank)."""
        p = self.plan
        assert len(in_factors) == p.n_in
        rp = rank_padded(self.rank)
        pads = tuple(
            pad_factor(f, rows, rp) for f, rows in zip(in_factors, p.in_rows)
        )
        out = mttkrp_pallas_call(
            self._dev["block_it"],
            self._dev["block_in"],
            self._dev["vals"],
            self._dev["iloc"],
            self._dev["in_locs"],
            pads,
            tile_i=p.tile_i,
            in_tiles=p.in_tiles,
            blk=p.blk,
            out_rows=p.out_rows,
            interpret=self.interpret,
        )
        return out[: p.out_rows, : self.rank]

    def output(self, factors: Sequence[jax.Array], true_rows: int) -> jax.Array:
        return self(*(factors[m] for m in self.plan.in_modes))[:true_rows]


def make_planned_mttkrp(
    st: SparseTensor,
    mode: int,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool = False,
    spec: TPUSpec = TPUSpec(),
    interpret: bool = True,
) -> PlannedMTTKRP:
    """Build the memory layout (Tensor Remapper) + kernel instance.  With
    auto_tune=True the PMS picks the controller parameters (Sec. 5.3)."""
    if auto_tune:
        best = pms_search(st, mode, rank, spec=spec, top_k=1)
        if not best:
            raise ValueError(
                f"PMS found no VMEM-feasible controller configuration for "
                f"mode {mode} at rank {rank} (spec budget "
                f"{spec.vmem_bytes * spec.vmem_usable_frac:.0f} bytes)"
            )
        cfg = best[0].cfg
    cfg = cfg or MemoryControllerConfig()
    n_in = st.nmodes - 1
    plan = plan_blocks(
        st,
        mode,
        tile_i=cfg.cache.tile_i,
        blk=cfg.dma.blk,
        in_tiles=cfg.cache.input_tiles(n_in),
    )
    return PlannedMTTKRP(plan=plan, rank=rank, interpret=interpret, cfg=cfg)


@dataclasses.dataclass
class PlannedCPALS:
    """Per-mode plan cache driving the whole CP-ALS loop on the memory
    controller (paper Alg. 1 on the Alg. 5 layout).

    One `PlannedMTTKRP` per output mode — each holds its own remapped,
    device-resident copy of the non-zero stream — constructed once and reused
    for every ALS iteration, so the plan/remap cost is amortized over the
    decomposition exactly as the paper amortizes the FPGA layout generation
    over the (many-iteration) ALS run.

    The steady-state iteration is `sweep`: one jitted function running a full
    ALS iteration (every mode's MTTKRP -> gram -> solve -> normalize, plus the
    on-device fit).  Factors stay rank-padded and device-resident across
    iterations — `pad_factors` pads each mode once up front (to the maximum
    row padding any plan needs, lanes to rank_padded) and the sweep updates
    them in padded space; `unpad_factors` slices back to true shape only when
    a `CPState` is materialized.
    """

    ops: dict[int, PlannedMTTKRP]
    shape: tuple[int, ...]
    rank: int
    _sweep_fn: Callable | None = dataclasses.field(default=None, repr=False)

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    @property
    def rank_pad(self) -> int:
        return rank_padded(self.rank)

    def plan_for(self, mode: int) -> BlockPlan:
        return self.ops[mode].plan

    @property
    def padded_rows(self) -> tuple[int, ...]:
        """Device-resident row padding per mode: the largest padding any plan
        requires of that factor (its own plan's out_rows, plus in_rows
        wherever it appears as an input mode).  Each plan's kernel slices the
        rows it needs — a static, zero-copy slice inside the sweep jit."""
        rows = []
        for m in range(self.nmodes):
            r = self.ops[m].plan.out_rows
            for op in self.ops.values():
                p = op.plan
                for n, im in enumerate(p.in_modes):
                    if im == m:
                        r = max(r, p.in_rows[n])
            rows.append(r)
        return tuple(rows)

    def pad_factors(self, factors: Sequence[jax.Array]) -> tuple[jax.Array, ...]:
        """One pad per mode for the whole decomposition (not N x iters)."""
        rp = self.rank_pad
        return tuple(
            pad_factor(f, rows, rp) for f, rows in zip(factors, self.padded_rows)
        )

    def unpad_factors(self, padded: Sequence[jax.Array]) -> list[jax.Array]:
        return [f[:s, : self.rank] for f, s in zip(padded, self.shape)]

    def _build_sweep(self) -> Callable:
        shape, rank, nmodes = self.shape, self.rank, self.nmodes
        rp, prows = self.rank_pad, self.padded_rows
        ops = self.ops

        def sweep(facs, idx, val, norm_x_sq, first):
            facs = list(facs)
            lam = None
            for m in range(nmodes):
                op, p = ops[m], ops[m].plan
                in_facs = tuple(
                    facs[im][: p.in_rows[n]] for n, im in enumerate(p.in_modes)
                )
                out = mttkrp_pallas_call(
                    op._dev["block_it"],
                    op._dev["block_in"],
                    op._dev["vals"],
                    op._dev["iloc"],
                    op._dev["in_locs"],
                    in_facs,
                    tile_i=p.tile_i,
                    in_tiles=p.in_tiles,
                    blk=p.blk,
                    out_rows=p.out_rows,
                    interpret=op.interpret,
                )
                mt = out[: shape[m], :rank]
                true = [f[:s, :rank] for f, s in zip(facs, shape)]
                true, lam = _update_mode(mt, true, m, first)
                # Re-pad in place of the old padded factor (padding rows and
                # lanes stay exactly zero, so grams/fit in padded space match
                # the true-shape computation bit for bit).
                f = true[m]
                facs[m] = jnp.zeros((prows[m], rp), f.dtype).at[: shape[m], :rank].set(f)
            true = [f[:s, :rank] for f, s in zip(facs, shape)]
            fit = fit_value(idx, val, true, lam, norm_x_sq)
            return tuple(facs), lam, fit

        return jax.jit(sweep, static_argnames=("first",))

    def sweep(self, facs, idx, val, norm_x_sq, *, first: bool = False):
        """One jitted ALS iteration in padded space.  Returns
        (new padded factors, lam, fit scalar on device)."""
        if self._sweep_fn is None:
            self._sweep_fn = self._build_sweep()
        return self._sweep_fn(facs, idx, val, norm_x_sq, first=first)

    def mttkrp_fn(self, indices, values, factors, mode, out_rows):
        """The `cp_als(mttkrp_fn=...)` seam: the stream args are ignored —
        each mode's remapped copy already lives on device in its plan."""
        return self.ops[mode].output(factors, out_rows)

    def plan_bytes(self) -> int:
        """HBM held by the per-mode layouts (the 'copies' trade, Sec. 3).
        Element widths come from each mode's Remapper configuration."""
        total = 0
        for op in self.ops.values():
            p, r = op.plan, op.cfg.remapper
            slots = p.vals.shape[0]
            total += slots * (r.value_bytes + (1 + p.n_in) * r.index_bytes)
            total += p.nblocks * (1 + p.n_in) * r.index_bytes
        return total


def make_planned_cp_als(
    st: SparseTensor,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool = False,
    spec: TPUSpec = TPUSpec(),
    interpret: bool = True,
) -> PlannedCPALS:
    """Build the full ALS workspace: one tuned plan per output mode.

    With auto_tune=True each mode gets its own PMS-selected controller
    configuration (modes have different shapes/locality, Sec. 5.3); otherwise
    `cfg` (or the default) is shared by every mode."""
    ops = {
        m: make_planned_mttkrp(
            st, m, rank, cfg=cfg, auto_tune=auto_tune, spec=spec, interpret=interpret
        )
        for m in range(st.nmodes)
    }
    return PlannedCPALS(ops=ops, shape=st.shape, rank=rank)


# ---------------------------------------------------------------------------
# Keyed plan cache for the one-shot dispatcher
# ---------------------------------------------------------------------------

_PLAN_CACHE: OrderedDict[tuple, PlannedMTTKRP] = OrderedDict()
_PLAN_CACHE_CAP = 32  # LRU bound: each entry pins a device-resident layout
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0}


def plan_cache_stats() -> dict[str, int]:
    """Hit/miss counters of the `mttkrp_auto` plan cache (bench_e2e reports
    them: a hit means a call skipped the whole remap/layout build)."""
    return dict(_PLAN_CACHE_STATS)


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    _PLAN_CACHE_STATS["hits"] = 0
    _PLAN_CACHE_STATS["misses"] = 0


def _planned_mttkrp_cached(
    st: SparseTensor,
    mode: int,
    rank: int,
    cfg: MemoryControllerConfig | None,
    interpret: bool,
) -> PlannedMTTKRP:
    """LRU-cached plan lookup keyed by (tensor content fingerprint, mode,
    rank, controller config, interpret) — repeated test/benchmark calls stop
    repaying the Tensor Remapper on every invocation."""
    key = (
        st.fingerprint(),
        mode,
        rank,
        cfg or MemoryControllerConfig(),
        bool(interpret),
    )
    op = _PLAN_CACHE.get(key)
    if op is not None:
        _PLAN_CACHE_STATS["hits"] += 1
        _PLAN_CACHE.move_to_end(key)
        return op
    _PLAN_CACHE_STATS["misses"] += 1
    op = make_planned_mttkrp(st, mode, rank, cfg=cfg, interpret=interpret)
    _PLAN_CACHE[key] = op
    while len(_PLAN_CACHE) > _PLAN_CACHE_CAP:
        _PLAN_CACHE.popitem(last=False)
    return op


def mttkrp_auto(
    st: SparseTensor,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    method: str = "pallas",
    interpret: bool = True,
    cfg: MemoryControllerConfig | None = None,
    sorted_by_mode: bool | None = None,
) -> jax.Array:
    """One-shot dispatcher used by tests/benchmarks: 'pallas' | 'approach1' |
    'approach2'.  The pallas path caches its BlockPlan keyed on the tensor's
    content fingerprint (see `plan_cache_stats`).

    `sorted_by_mode` defaults to what the stream actually satisfies
    (`st.is_sorted_by(mode)`): `indices_are_sorted` is a correctness promise
    to XLA, not a hint, so it is never asserted for an unsorted stream."""
    rank = int(factors[0].shape[1])
    if method == "pallas":
        op = _planned_mttkrp_cached(st, mode, rank, cfg, interpret)
        return op.output(factors, st.shape[mode])
    if sorted_by_mode is None:
        sorted_by_mode = st.is_sorted_by(mode)
    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    return mttkrp_jax(
        idx, val, factors, mode, st.shape[mode],
        method=method, sorted_by_mode=sorted_by_mode,
    )
