"""Jit'd wrappers for the decomposition kernels: plan construction + padding +
dispatch between the Pallas kernels, their interpret-mode validation paths,
and the pure-JAX references.

Three kernel families share the BlockPlan substrate (the memory controller is
*programmable*, not MTTKRP-specific):
  * MTTKRP  — `PlannedMTTKRP` / `mttkrp_auto` / `PlannedCPALS` (CP-ALS,
              paper Alg. 1 + Alg. 5);
  * TTMc    — `PlannedTTMC` / `tucker_auto` (sparse Tucker HOOI; see
              repro.tucker).  Same remapped layout, Kronecker-chain compute.
  * TT-core — `PlannedTTCore` / `tt_auto` (tensor-train ALS; see repro.tt).
              Same remapped layout, Kronecker-of-two-interfaces compute.

`PlannedCPALS` is the workspace that makes the Pallas kernel the *production*
decomposition path (paper Alg. 1 + Alg. 5): one PMS-tunable BlockPlan +
device-resident layout per output mode, built once and cached across every
ALS iteration (the paper's layout="copies" posture — per-mode remapped
copies, a legitimate space/time trade on HBM).  `PlannedTucker`
(repro.tucker.hooi) and `PlannedTT` (repro.tt.als) mirror it for the HOOI
and TT-ALS loops.  Everything the workspaces share — padding, residency,
plan-byte accounting, the lazily-built sweep, the drive loop — lives in
`repro.kernels.workspace.PlannedWorkspace`; the classes here supply only
their format's sweep body.

The one-shot dispatchers share a keyed LRU plan cache.  The key leads with a
kernel-kind discriminator ("mttkrp" / "ttmc" / "tt"): two kernels sharing a
tensor fingerprint + mode + rank must never silently reuse each other's
plans (the layouts coincide today, but the cached objects carry
kernel-specific state).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from collections import OrderedDict
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.coo import SparseTensor
from ..core.cp_als import _update_mode, fit_value, inner_with_model, model_norm_sq
from ..core.memctrl import MemoryControllerConfig, TPUSpec
from ..core.pms import (
    predict_from_plan,
    resolve_spec as pms_resolve_spec,
    search as pms_search,
)
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from ..core.remap import BlockPlan, plan_blocks, plans_validated, validate_plan
from ..core.mttkrp import mttkrp as mttkrp_jax
from .mttkrp_pallas import mttkrp_pallas_call, pad_factor, rank_padded
from .ref import ttcore_ref, ttmc_ref
from .tt_pallas import tt_out_cols, tt_out_pair, ttcore_pallas_call
from .ttm_pallas import kron_cols, ttmc_pallas_call
from .workspace import (
    PlannedWorkspace,
    ShardedWorkspace,
    _apply_row_mask,
    _padded_rows_from,
    _plan_device_arrays,
    _visited_row_mask,
    planned_layout_bytes,
    sharded_layout_bytes,
)

__all__ = [
    "PlannedMTTKRP",
    "make_planned_mttkrp",
    "PlannedCPALS",
    "make_planned_cp_als",
    "PlannedTTMC",
    "make_planned_ttmc",
    "PlannedTTCore",
    "make_planned_ttcore",
    "mttkrp_auto",
    "tucker_auto",
    "tt_auto",
    "plan_cache_stats",
    "plan_cache_clear",
    "planned_padded_rows",
    "planned_layout_bytes",
    "ShardedPlannedMTTKRP",
    "ShardedPlannedCPALS",
    "ShardedPlannedTucker",
    "ShardedPlannedTT",
    "make_sharded_planned_mttkrp",
    "make_sharded_planned_cp_als",
    "make_sharded_planned_tucker",
    "make_sharded_planned_tt",
]


def planned_padded_rows(ops: dict[int, "PlannedMTTKRP | PlannedTTMC"], nmodes: int) -> tuple[int, ...]:
    """Device-resident row padding per mode for a per-mode plan family: the
    largest padding any plan requires of that factor (its own plan's
    out_rows, plus in_rows wherever it appears as an input mode).  Each
    plan's kernel slices the rows it needs — a static, zero-copy slice
    inside a sweep jit."""
    return _padded_rows_from({m: op.plan for m, op in ops.items()}, nmodes)


@dataclasses.dataclass
class PlannedMTTKRP:
    """A compiled memory-controller instance for one (tensor, mode): the
    device-resident BlockPlan layout + a callable running the Pallas kernel."""

    plan: BlockPlan
    rank: int
    interpret: bool
    cfg: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig
    )
    _dev: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self._dev = _plan_device_arrays(self.plan)

    def __call__(self, *in_factors: jax.Array) -> jax.Array:
        """Factors for the N-1 *input* modes (plan.in_modes order).
        Returns (out_rows_unpadded, rank)."""
        p = self.plan
        assert len(in_factors) == p.n_in
        rp = rank_padded(self.rank)
        pads = tuple(
            pad_factor(f, rows, rp) for f, rows in zip(in_factors, p.in_rows)
        )
        out = mttkrp_pallas_call(
            self._dev["block_it"],
            self._dev["block_in"],
            self._dev["vals"],
            self._dev["iloc"],
            self._dev["in_locs"],
            pads,
            tile_i=p.tile_i,
            in_tiles=p.in_tiles,
            blk=p.blk,
            out_rows=p.out_rows,
            interpret=self.interpret,
        )
        out = _apply_row_mask(out, self._dev["row_mask"])  # zero unvisited tiles
        return out[: p.out_rows, : self.rank]

    def output(self, factors: Sequence[jax.Array], true_rows: int) -> jax.Array:
        return self(*(factors[m] for m in self.plan.in_modes))[:true_rows]


def _resolve_tune(auto_tune, spec):
    """Normalize the (auto_tune, spec) pair every planned builder accepts:
    `auto_tune` must be False / True / "cached" ("cached" = True semantics
    with the winning configuration persisted in `repro.tune.cache`, so a
    warm cache skips the PMS sweep entirely); `spec` may be a TPUSpec,
    "default", or "measured" (this backend's calibrated spec)."""
    if auto_tune not in (False, True, "cached"):
        raise ValueError(
            f"auto_tune must be False, True or 'cached', got {auto_tune!r}"
        )
    return auto_tune, pms_resolve_spec(spec)


def _searched_cfg(
    auto_tune, kind: str, st: SparseTensor, mode: int, rank_key, spec, search,
    *, nshards: int | None = None,
) -> MemoryControllerConfig:
    """Run (or skip) the PMS sweep per the auto_tune policy: True runs
    `search()` every call; "cached" serves the persisted winner for this
    (kind, tensor, mode, rank payload, backend, spec, shards) key and only
    searches — then writes back — on a miss."""
    if auto_tune == "cached":
        from ..tune.cache import cached_config  # deferred: tune -> ops

        return cached_config(
            kind, st.fingerprint(), mode, rank_key, spec, search, nshards=nshards
        )
    return search()


def make_planned_mttkrp(
    st: SparseTensor,
    mode: int,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> PlannedMTTKRP:
    """Build the memory layout (Tensor Remapper) + kernel instance.  With
    auto_tune=True the PMS picks the controller parameters (Sec. 5.3);
    auto_tune="cached" additionally persists/reuses the winner on disk."""
    auto_tune, spec = _resolve_tune(auto_tune, spec)
    if auto_tune:
        def _search():
            best = pms_search(st, mode, rank, spec=spec, top_k=1)
            if not best:
                raise ValueError(
                    f"PMS found no VMEM-feasible controller configuration for "
                    f"mode {mode} at rank {rank} (spec budget "
                    f"{spec.vmem_bytes * spec.vmem_usable_frac:.0f} bytes)"
                )
            return best[0].cfg

        cfg = _searched_cfg(auto_tune, "mttkrp", st, mode, rank, spec, _search)
    cfg = cfg or MemoryControllerConfig()
    n_in = st.nmodes - 1
    plan = plan_blocks(
        st,
        mode,
        tile_i=cfg.cache.tile_i,
        blk=cfg.dma.blk,
        in_tiles=cfg.cache.input_tiles(n_in),
    )
    return PlannedMTTKRP(plan=plan, rank=rank, interpret=interpret, cfg=cfg)


@dataclasses.dataclass
class PlannedTTMC:
    """A compiled memory-controller instance of the TTM-chain kernel for one
    (tensor, output mode): the same device-resident BlockPlan layout as
    MTTKRP, driving the Kronecker-chain Pallas kernel (repro.tucker HOOI's
    per-mode contraction).  `in_ranks` are the input-factor ranks in
    plan.in_modes order; the output has prod(in_ranks) true columns."""

    plan: BlockPlan
    in_ranks: tuple[int, ...]
    interpret: bool
    cfg: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig
    )
    _dev: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.in_ranks = tuple(int(r) for r in self.in_ranks)
        self._dev = _plan_device_arrays(self.plan)

    @property
    def out_cols(self) -> int:
        return kron_cols(self.in_ranks)

    def __call__(self, *in_factors: jax.Array) -> jax.Array:
        """Factors for the N-1 *input* modes (plan.in_modes order), true
        shapes.  Returns (out_rows_unpadded, prod(in_ranks))."""
        p = self.plan
        assert len(in_factors) == p.n_in
        pads = tuple(
            pad_factor(f, rows, rank_padded(r))
            for f, rows, r in zip(in_factors, p.in_rows, self.in_ranks)
        )
        out = self.call_padded(pads)
        return out[: p.out_rows, : self.out_cols]

    def call_padded(self, in_factors_pad: Sequence[jax.Array]) -> jax.Array:
        """Run the kernel on already row/lane-padded input factors (the
        PlannedTucker sweep path).  Returns the padded (out_rows, Pp) tile
        with unvisited output tiles zeroed."""
        p = self.plan
        out = ttmc_pallas_call(
            self._dev["block_it"],
            self._dev["block_in"],
            self._dev["vals"],
            self._dev["iloc"],
            self._dev["in_locs"],
            tuple(in_factors_pad),
            tile_i=p.tile_i,
            in_tiles=p.in_tiles,
            in_ranks=self.in_ranks,
            blk=p.blk,
            out_rows=p.out_rows,
            interpret=self.interpret,
        )
        return _apply_row_mask(out, self._dev["row_mask"])

    def output(self, factors: Sequence[jax.Array], true_rows: int) -> jax.Array:
        return self(*(factors[m] for m in self.plan.in_modes))[:true_rows]


def make_planned_ttmc(
    st: SparseTensor,
    mode: int,
    core_ranks: Sequence[int],
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> PlannedTTMC:
    """Build the memory layout + TTMc kernel instance for one output mode.

    Args:
      st: host-side COO tensor (>= 3 modes).
      mode: the output mode n — the kernel computes the unfolding
        Y_(n) = X_(n) (kron of the other factors).
      core_ranks: the FULL N-tuple of Tucker core ranks (not the N-1 input
        ranks); the instance's `in_ranks` are taken from it in
        plan.in_modes order.  Each input factor is lane-padded to its own
        `rank_padded(R_m)`; the output carries `prod(in_ranks)` true
        columns, lane-padded to `cols_padded(prod R_m)`.
      cfg / auto_tune / spec: controller configuration, or let the PMS tune
        it for the TTMc kernel specifically (the core-tensor output tile
        changes both the VMEM constraint and the roofline).
      interpret: run the Pallas kernel in interpret mode.

    Returns:
      A `PlannedTTMC` holding the device-resident BlockPlan layout — the
      SAME layout `make_planned_mttkrp` would build for this (tensor, mode,
      cfg); only the kernel differs.  Invariant: `op(*in_factors)` expects
      true-shape factors for plan.in_modes in order and returns
      (I_mode, prod(in_ranks))."""
    core_ranks = tuple(int(r) for r in core_ranks)
    if len(core_ranks) != st.nmodes:
        raise ValueError(
            f"core_ranks has {len(core_ranks)} entries for a "
            f"{st.nmodes}-mode tensor (pass the full N-tuple)"
        )
    auto_tune, spec = _resolve_tune(auto_tune, spec)
    if auto_tune:
        def _search():
            best = pms_search(
                st, mode, max(core_ranks), spec=spec, top_k=1,
                kernel="ttmc", core_ranks=core_ranks,
            )
            if not best:
                raise ValueError(
                    f"PMS found no VMEM-feasible controller configuration for "
                    f"TTMc mode {mode} at core ranks {core_ranks} (spec budget "
                    f"{spec.vmem_bytes * spec.vmem_usable_frac:.0f} bytes)"
                )
            return best[0].cfg

        cfg = _searched_cfg(auto_tune, "ttmc", st, mode, core_ranks, spec, _search)
    cfg = cfg or MemoryControllerConfig()
    n_in = st.nmodes - 1
    plan = plan_blocks(
        st,
        mode,
        tile_i=cfg.cache.tile_i,
        blk=cfg.dma.blk,
        in_tiles=cfg.cache.input_tiles(n_in),
    )
    in_ranks = tuple(core_ranks[m] for m in plan.in_modes)
    return PlannedTTMC(plan=plan, in_ranks=in_ranks, interpret=interpret, cfg=cfg)


def _tt_bond_pairs(tt_ranks: Sequence[int], nmodes: int) -> tuple[tuple[int, int], ...]:
    """Per-core (rl_k, rr_k) bond pairs from the N-1 interior TT ranks
    (boundary bonds are 1 by definition)."""
    tt_ranks = tuple(int(r) for r in tt_ranks)
    if len(tt_ranks) != nmodes - 1:
        raise ValueError(
            f"tt_ranks has {len(tt_ranks)} entries for a {nmodes}-mode "
            f"tensor (pass the N-1 interior TT ranks)"
        )
    bounds = (1,) + tt_ranks + (1,)
    return tuple((bounds[k], bounds[k + 1]) for k in range(nmodes))


@dataclasses.dataclass
class PlannedTTCore:
    """A compiled memory-controller instance of the TT-core-update kernel for
    one (tensor, output mode): the same device-resident BlockPlan layout as
    MTTKRP/TTMc, driving the Kronecker-of-two-interfaces Pallas kernel
    (repro.tt TT-ALS's per-mode contraction).  `in_rank_pairs` are the input
    cores' (rl, rr) bond pairs in plan.in_modes order (ascending, so the
    first `plan.mode` of them chain from the left); the output has
    rl_m * rr_m true columns."""

    plan: BlockPlan
    in_rank_pairs: tuple[tuple[int, int], ...]
    interpret: bool
    cfg: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig
    )
    _dev: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.in_rank_pairs = tuple(
            (int(a), int(b)) for a, b in self.in_rank_pairs
        )
        self._dev = _plan_device_arrays(self.plan)

    @property
    def n_left(self) -> int:
        """Inputs left of the output mode: plan.in_modes is ascending, so
        exactly `plan.mode` of them precede it."""
        return self.plan.mode

    @property
    def out_pair(self) -> tuple[int, int]:
        return tt_out_pair(self.in_rank_pairs, self.n_left)

    @property
    def out_cols(self) -> int:
        return tt_out_cols(self.in_rank_pairs, self.n_left)

    def __call__(self, *in_mats: jax.Array) -> jax.Array:
        """Core interface matrices W_k = transpose(G_k,(1,0,2)).reshape(I_k,
        rl_k*rr_k) for the N-1 *input* modes (plan.in_modes order), true
        shapes.  Returns (out_rows_unpadded, rl_m*rr_m)."""
        p = self.plan
        assert len(in_mats) == p.n_in
        pads = tuple(
            pad_factor(f, rows, rank_padded(a * b))
            for f, rows, (a, b) in zip(in_mats, p.in_rows, self.in_rank_pairs)
        )
        out = self.call_padded(pads)
        return out[: p.out_rows, : self.out_cols]

    def call_padded(self, in_mats_pad: Sequence[jax.Array]) -> jax.Array:
        """Run the kernel on already row/lane-padded interface matrices (the
        PlannedTT sweep path).  Returns the padded (out_rows, Pp) tile with
        unvisited output tiles zeroed."""
        p = self.plan
        out = ttcore_pallas_call(
            self._dev["block_it"],
            self._dev["block_in"],
            self._dev["vals"],
            self._dev["iloc"],
            self._dev["in_locs"],
            tuple(in_mats_pad),
            tile_i=p.tile_i,
            in_tiles=p.in_tiles,
            in_rank_pairs=self.in_rank_pairs,
            n_left=self.n_left,
            blk=p.blk,
            out_rows=p.out_rows,
            interpret=self.interpret,
        )
        return _apply_row_mask(out, self._dev["row_mask"])

    def output(self, mats: Sequence[jax.Array], true_rows: int) -> jax.Array:
        return self(*(mats[m] for m in self.plan.in_modes))[:true_rows]


def make_planned_ttcore(
    st: SparseTensor,
    mode: int,
    tt_ranks: Sequence[int],
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> PlannedTTCore:
    """Build the memory layout + TT-core kernel instance for one output mode.

    Args:
      st: host-side COO tensor (>= 3 modes).
      mode: the output mode m — the kernel computes the TT-ALS right-hand
        side B_m (nnz-restricted Kronecker of the left/right interface
        chains).
      tt_ranks: the N-1 INTERIOR TT bond ranks (boundary bonds are 1); the
        instance's `in_rank_pairs` are the per-core (rl, rr) pairs in
        plan.in_modes order.  Each interface matrix is lane-padded to its
        own `rank_padded(rl_k*rr_k)`; the output carries rl_m*rr_m true
        columns, lane-padded to `rank_padded(rl_m*rr_m)`.
      cfg / auto_tune / spec: controller configuration, or let the PMS tune
        it for the TT kernel specifically (two interface scratch chains
        change the VMEM constraint and the roofline).
      interpret: run the Pallas kernel in interpret mode.

    Returns:
      A `PlannedTTCore` holding the device-resident BlockPlan layout — the
      SAME layout `make_planned_mttkrp` would build for this (tensor, mode,
      cfg); only the kernel differs."""
    pairs = _tt_bond_pairs(tt_ranks, st.nmodes)
    auto_tune, spec = _resolve_tune(auto_tune, spec)
    if auto_tune:
        def _search():
            best = pms_search(
                st, mode, max(max(p) for p in pairs), spec=spec, top_k=1,
                kernel="tt", core_ranks=tuple(int(r) for r in tt_ranks),
            )
            if not best:
                raise ValueError(
                    f"PMS found no VMEM-feasible controller configuration for "
                    f"TT mode {mode} at TT ranks {tuple(tt_ranks)} (spec budget "
                    f"{spec.vmem_bytes * spec.vmem_usable_frac:.0f} bytes)"
                )
            return best[0].cfg

        cfg = _searched_cfg(
            auto_tune, "tt", st, mode, tuple(int(r) for r in tt_ranks), spec, _search
        )
    cfg = cfg or MemoryControllerConfig()
    n_in = st.nmodes - 1
    plan = plan_blocks(
        st,
        mode,
        tile_i=cfg.cache.tile_i,
        blk=cfg.dma.blk,
        in_tiles=cfg.cache.input_tiles(n_in),
    )
    in_rank_pairs = tuple(pairs[m] for m in plan.in_modes)
    return PlannedTTCore(
        plan=plan, in_rank_pairs=in_rank_pairs, interpret=interpret, cfg=cfg
    )


@dataclasses.dataclass
class PlannedCPALS(PlannedWorkspace):
    """Per-mode plan cache driving the whole CP-ALS loop on the memory
    controller (paper Alg. 1 on the Alg. 5 layout).

    One `PlannedMTTKRP` per output mode — each holds its own remapped,
    device-resident copy of the non-zero stream — constructed once and reused
    for every ALS iteration, so the plan/remap cost is amortized over the
    decomposition exactly as the paper amortizes the FPGA layout generation
    over the (many-iteration) ALS run.

    The steady-state iteration is `sweep`: one jitted function running a full
    ALS iteration (every mode's MTTKRP -> gram -> solve -> normalize, plus the
    on-device fit).  Factor padding/residency and the host drive loop come
    from `PlannedWorkspace` — this class supplies only the CP sweep body.
    """

    ops: dict[int, PlannedMTTKRP]
    shape: tuple[int, ...]
    rank: int

    @property
    def lane_ranks(self) -> tuple[int, ...]:
        return (self.rank,) * self.nmodes

    @property
    def rank_pad(self) -> int:
        """CP's single lane padding (every mode shares rank R)."""
        return rank_padded(self.rank)

    def plan_for(self, mode: int) -> BlockPlan:
        return self.ops[mode].plan

    def _geoms(self) -> dict[int, BlockPlan]:
        return {m: op.plan for m, op in self.ops.items()}

    def _layout_bytes(self) -> int:
        return planned_layout_bytes(self.ops)

    def _build_sweep(self) -> Callable:
        shape, rank, nmodes = self.shape, self.rank, self.nmodes
        rp, prows = self.rank_pad, self.padded_rows
        ops = self.ops

        def sweep(facs, idx, val, norm_x_sq, first):
            facs = list(facs)
            lam = None
            for m in range(nmodes):
                op, p = ops[m], ops[m].plan
                in_facs = tuple(
                    facs[im][: p.in_rows[n]] for n, im in enumerate(p.in_modes)
                )
                out = mttkrp_pallas_call(
                    op._dev["block_it"],
                    op._dev["block_in"],
                    op._dev["vals"],
                    op._dev["iloc"],
                    op._dev["in_locs"],
                    in_facs,
                    tile_i=p.tile_i,
                    in_tiles=p.in_tiles,
                    blk=p.blk,
                    out_rows=p.out_rows,
                    interpret=op.interpret,
                )
                out = _apply_row_mask(out, op._dev["row_mask"])  # zero unvisited tiles
                mt = out[: shape[m], :rank]
                true = [f[:s, :rank] for f, s in zip(facs, shape)]
                true, lam = _update_mode(mt, true, m, first)
                # Re-pad in place of the old padded factor (padding rows and
                # lanes stay exactly zero, so grams/fit in padded space match
                # the true-shape computation bit for bit).
                f = true[m]
                facs[m] = jnp.zeros((prows[m], rp), f.dtype).at[: shape[m], :rank].set(f)
            true = [f[:s, :rank] for f, s in zip(facs, shape)]
            fit = fit_value(idx, val, true, lam, norm_x_sq)
            return tuple(facs), lam, fit

        return jax.jit(sweep, static_argnames=("first",))

    def sweep(self, facs, idx, val, norm_x_sq, *, first: bool = False):
        """One jitted ALS iteration in padded space (the
        `PlannedWorkspace.sweep` contract).

        Args: `facs` — the rank-padded factor tuple; `idx`, `val` — the raw
        COO stream (any order — only the fit's inner product reads it; the
        per-mode remapped copies live inside the plans); `norm_x_sq` —
        ||X||_F^2 as a device scalar; `first` — first-ALS-iteration
        normalization convention (max(norm, 1)); static — one retrace when
        it flips to False.  Returns (new padded factors, lam, fit)."""
        return super().sweep(facs, idx, val, norm_x_sq, first=first)

    def _sweep_call(self, facs, *args, it: int):
        return self.sweep(facs, *args, first=(it == 0))

    def mttkrp_fn(self, indices, values, factors, mode, out_rows):
        """The `cp_als(mttkrp_fn=...)` seam: the stream args are ignored —
        each mode's remapped copy already lives on device in its plan."""
        return self.ops[mode].output(factors, out_rows)

    def vmem_model_bytes(self) -> int:
        rp = self.rank_pad
        return max(
            op.cfg.vmem_bytes(rp, n_in=op.plan.n_in) for op in self.ops.values()
        )

    def pms_estimates(self, spec: TPUSpec = TPUSpec()) -> dict[int, Any]:
        """Exact per-mode PMS estimates from the built plans — the predicted
        side of `obs.calibrate`'s achieved_pct join (measured fills and
        padding, not the analytic occupancy model)."""
        return {
            m: predict_from_plan(op.plan, self.rank, op.cfg, spec)
            for m, op in self.ops.items()
        }

    def _build_fallback_sweep(self) -> Callable:
        """Reference degradation target of the "fallback" guard policy: the
        same ALS iteration as `_build_sweep` with the per-mode Pallas calls
        replaced by the pure-JAX Approach-1 MTTKRP on the raw stream (drive's
        args already carry it for the fit).  Operates on the SAME padded
        factors, so the switch reuses the last good iterate unchanged."""
        shape, rank, nmodes = self.shape, self.rank, self.nmodes
        rp, prows = self.rank_pad, self.padded_rows

        def sweep(facs, idx, val, norm_x_sq, first):
            facs = list(facs)
            lam = None
            for m in range(nmodes):
                true = [f[:s, :rank] for f, s in zip(facs, shape)]
                mt = mttkrp_jax(
                    idx, val, true, m, shape[m],
                    method="approach1", sorted_by_mode=False,
                )
                true, lam = _update_mode(mt, true, m, first)
                f = true[m]
                facs[m] = jnp.zeros((prows[m], rp), f.dtype).at[: shape[m], :rank].set(f)
            true = [f[:s, :rank] for f, s in zip(facs, shape)]
            fit = fit_value(idx, val, true, lam, norm_x_sq)
            return tuple(facs), lam, fit

        jitted = jax.jit(sweep, static_argnames=("first",))
        return lambda facs, *args, it: jitted(facs, *args, first=(it == 0))


def make_planned_cp_als(
    st: SparseTensor,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> PlannedCPALS:
    """Build the full ALS workspace: one tuned plan per output mode.

    Args:
      st: host-side COO tensor (>= 3 modes).  The Tensor Remapper runs once
        per mode here — this call is the whole layout-generation cost the
        paper amortizes over the ALS run.
      rank: CP rank R.  Kernels compute at `rank_padded(R)` lanes (>= 128,
        128-multiple); results are sliced back to R.
      cfg: controller configuration shared by every mode (default config if
        None).  Ignored when auto_tune=True.
      auto_tune: run the PMS per output mode (modes have different shapes /
        locality, Sec. 5.3) and take each mode's best configuration.
      spec: target-hardware constants for the PMS search.
      interpret: run the Pallas kernels in interpret mode (CPU containers).

    Returns:
      A `PlannedCPALS` whose per-mode remapped layouts are device-resident
      for the workspace's lifetime (`plan_bytes()` reports the HBM spend —
      the per-mode-copies trade).  Reuse it across `cp_als(planned=ws)`
      calls to skip the remap entirely."""
    ops = {
        m: make_planned_mttkrp(
            st, m, rank, cfg=cfg, auto_tune=auto_tune, spec=spec, interpret=interpret
        )
        for m in range(st.nmodes)
    }
    return PlannedCPALS(ops=ops, shape=st.shape, rank=rank)


# ---------------------------------------------------------------------------
# Keyed plan cache for the one-shot dispatchers (mttkrp_auto / tucker_auto)
# ---------------------------------------------------------------------------

_PLAN_CACHE: OrderedDict[tuple, "PlannedMTTKRP | PlannedTTMC"] = OrderedDict()
# LRU bound: each entry pins a device-resident layout, so an unbounded cache
# lets a tenant churning tensor fingerprints grow resident HBM without limit.
# Env-overridable at import (REPRO_PLAN_CACHE_MAX) and at runtime
# (plan_cache_config).
_PLAN_CACHE_CAP = max(1, int(os.environ.get("REPRO_PLAN_CACHE_MAX", "32")))
_PLAN_CACHE_KINDS = ("mttkrp", "ttmc", "tt")
_PLAN_CACHE_STATS = {k: {"hits": 0, "misses": 0} for k in _PLAN_CACHE_KINDS}
_PLAN_CACHE_EVICTIONS = {"count": 0}


def plan_cache_config(maxsize: int | None = None) -> int:
    """Get (and optionally set) the plan cache's LRU bound.

    With `maxsize=None` returns the current bound.  With an integer, sets the
    bound (>= 1), immediately evicting least-recently-used entries down to it
    (counted in `plan_cache_stats()["evictions"]`), and returns the new
    bound.  The initial bound comes from `REPRO_PLAN_CACHE_MAX` (default
    32)."""
    global _PLAN_CACHE_CAP
    if maxsize is not None:
        if maxsize < 1:
            raise ValueError(f"plan cache maxsize must be >= 1, got {maxsize}")
        _PLAN_CACHE_CAP = int(maxsize)
        _evict_to_cap()
    return _PLAN_CACHE_CAP


def _evict_to_cap() -> None:
    while len(_PLAN_CACHE) > _PLAN_CACHE_CAP:
        key, _ = _PLAN_CACHE.popitem(last=False)
        _PLAN_CACHE_EVICTIONS["count"] += 1
        _metrics.counter("plan_cache.evictions").inc()
        _trace.event("plan_cache_evict", kind=str(key[0]), mode=int(key[2]))


def plan_cache_stats() -> dict:
    """Hit/miss/eviction counters of the shared plan cache.

    Returns:
      ``{"hits": int, "misses": int, "evictions": int, "size": int,
      "maxsize": int, "by_kind": {"mttkrp": {...}, "ttmc": {...},
      "tt": {...}}}`` — totals at the top level plus per-kernel-kind
      hit/miss counters.  A hit means a dispatcher call skipped the whole
      remap/layout build (bench_e2e reports first-vs-cached call times); an
      eviction means the LRU bound (`plan_cache_config`) dropped a resident
      layout.

    Invariants: the kinds are tracked separately precisely because the
    cache key carries a kind discriminator — no cross-kind collisions by
    construction; per-shard BlockPlans of the distributed path count under
    their kernel's kind (their keys additionally carry a shard field).
    Counters reset on `plan_cache_clear()`."""
    by_kind = {k: dict(v) for k, v in _PLAN_CACHE_STATS.items()}
    return {
        "hits": sum(v["hits"] for v in by_kind.values()),
        "misses": sum(v["misses"] for v in by_kind.values()),
        "evictions": _PLAN_CACHE_EVICTIONS["count"],
        "size": len(_PLAN_CACHE),
        "maxsize": _PLAN_CACHE_CAP,
        "by_kind": by_kind,
    }


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    for v in _PLAN_CACHE_STATS.values():
        v["hits"] = 0
        v["misses"] = 0
    _PLAN_CACHE_EVICTIONS["count"] = 0


def _planned_cached(
    kind: str,
    st: SparseTensor,
    mode: int,
    rank_key,
    cfg: MemoryControllerConfig | None,
    interpret: bool,
    build: Callable,
    *,
    shard: tuple | None = None,
):
    """LRU-cached plan lookup keyed by (kernel kind, tensor content
    fingerprint, mode, rank key, controller config, interpret, shard) —
    repeated test/benchmark calls stop repaying the Tensor Remapper on every
    invocation.  The leading `kind` field keeps MTTKRP and TTMc plans for
    the same tensor/mode/rank from silently aliasing each other: the cached
    kernel instances carry kernel-specific state.  `shard` entries (a
    `(shard_index, nshards)` pair, None for the single-device dispatchers)
    are different: they cache raw, kernel-agnostic `BlockPlan`s, so their
    keys use a shared "layout" kind — CP and Tucker sharded workspaces for
    the same (tensor, cfg) reuse each other's shard layouts instead of
    repaying the remap — while hit/miss STATS stay attributed to the
    calling kernel's kind."""
    key = (
        "layout" if shard is not None else kind,
        st.fingerprint(),
        mode,
        rank_key,
        cfg or MemoryControllerConfig(),
        bool(interpret),
        shard,
    )
    stats = _PLAN_CACHE_STATS[kind]
    t0 = time.perf_counter()
    op = _PLAN_CACHE.get(key)
    if op is not None:
        stats["hits"] += 1
        _PLAN_CACHE.move_to_end(key)
        if plans_validated():
            # REPRO_VALIDATE_PLANS: re-validate cached layouts on every hit —
            # a corrupted resident plan must not outlive detection just
            # because it skipped the build path.  Shard entries cache raw
            # BlockPlans; kind entries cache kernel ops carrying `.plan`.
            validate_plan(op if isinstance(op, BlockPlan) else op.plan)
        _metrics.counter("plan_cache.hits", kind=kind).inc()
        _metrics.histogram("plan_cache.hit_seconds", kind=kind).observe(
            time.perf_counter() - t0
        )
        _trace.event("plan_cache_hit", kind=kind, mode=mode)
        return op
    stats["misses"] += 1
    with _trace.span("plan_cache_build", kind=kind, mode=mode):
        op = build()
    _PLAN_CACHE[key] = op
    _evict_to_cap()
    _metrics.counter("plan_cache.misses", kind=kind).inc()
    _metrics.histogram("plan_cache.miss_build_seconds", kind=kind).observe(
        time.perf_counter() - t0
    )
    return op


def mttkrp_auto(
    st: SparseTensor,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    method: str = "pallas",
    interpret: bool = True,
    cfg: MemoryControllerConfig | None = None,
    sorted_by_mode: bool | None = None,
) -> jax.Array:
    """One-shot dispatcher used by tests/benchmarks: 'pallas' | 'approach1' |
    'approach2'.  The pallas path caches its BlockPlan keyed on the tensor's
    content fingerprint (see `plan_cache_stats`).

    `sorted_by_mode` defaults to what the stream actually satisfies
    (`st.is_sorted_by(mode)`): `indices_are_sorted` is a correctness promise
    to XLA, not a hint, so it is never asserted for an unsorted stream."""
    rank = int(factors[0].shape[1])
    if method == "pallas":
        op = _planned_cached(
            "mttkrp", st, mode, rank, cfg, interpret,
            lambda: make_planned_mttkrp(st, mode, rank, cfg=cfg, interpret=interpret),
        )
        return op.output(factors, st.shape[mode])
    if sorted_by_mode is None:
        sorted_by_mode = st.is_sorted_by(mode)
    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    return mttkrp_jax(
        idx, val, factors, mode, st.shape[mode],
        method=method, sorted_by_mode=sorted_by_mode,
    )


def tucker_auto(
    st: SparseTensor,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    method: str = "pallas",
    interpret: bool = True,
    cfg: MemoryControllerConfig | None = None,
) -> jax.Array:
    """One-shot sparse TTM-chain dispatcher (the Tucker-side analogue of
    `mttkrp_auto`): contract every factor but `mode` into X.

    Args:
      st: host-side COO tensor.
      factors: ALL N factor matrices, true shapes (I_m, R_m); the mode-th is
        not contracted (and its rank is not part of the cache key).  Input
        ranks are read off the factor shapes.
      mode: output mode of the unfolding.
      method: 'pallas' — the planned memory-controller kernel, its BlockPlan
        cached in the shared kind-keyed LRU (see
        `plan_cache_stats()["by_kind"]["ttmc"]`); 'reference' — the pure-jnp
        gather/Kronecker/segment_sum oracle.
      interpret / cfg: pallas-path knobs (both are part of the cache key).

    Returns:
      The unfolding Y_(mode), shape (I_mode, prod of input ranks), float32,
      column order row-major over ascending input mode.  Rank-padding
      invariant: the kernel pads each input factor to `rank_padded(R_m)`
      lanes internally and slices the true Kronecker width back out —
      callers never see padded shapes."""
    core_ranks = tuple(int(f.shape[1]) for f in factors)
    if method == "pallas":
        in_ranks = tuple(r for m, r in enumerate(core_ranks) if m != mode)
        op = _planned_cached(
            "ttmc", st, mode, in_ranks, cfg, interpret,
            lambda: make_planned_ttmc(st, mode, core_ranks, cfg=cfg, interpret=interpret),
        )
        return op.output(factors, st.shape[mode])
    if method != "reference":
        raise ValueError(f"unknown method {method!r}: expected 'pallas' or 'reference'")
    return ttmc_ref(
        jnp.asarray(st.indices), jnp.asarray(st.values), factors, mode, st.shape[mode]
    )


def tt_auto(
    st: SparseTensor,
    cores: Sequence[jax.Array],
    mode: int,
    *,
    method: str = "pallas",
    interpret: bool = True,
    cfg: MemoryControllerConfig | None = None,
) -> jax.Array:
    """One-shot sparse TT-core dispatcher (the tensor-train analogue of
    `mttkrp_auto` / `tucker_auto`): the TT-ALS right-hand side B_mode from
    the left/right interface chains of the other cores.

    Args:
      st: host-side COO tensor.
      cores: ALL N TT cores, shapes (rl_k, I_k, rr_k) with boundary bonds 1;
        the mode-th is not contracted (its bonds still set the output
        width).  Bond ranks are read off the core shapes.
      mode: output mode of the update.
      method: 'pallas' — the planned memory-controller kernel, its BlockPlan
        cached in the shared kind-keyed LRU (see
        `plan_cache_stats()["by_kind"]["tt"]`); 'reference' — the pure-jnp
        gather/chain/segment_sum oracle.
      interpret / cfg: pallas-path knobs (both are part of the cache key).

    Returns:
      B_mode, shape (I_mode, rl_mode * rr_mode), float32, columns row-major
      over (rl, rr).  Rank-padding invariant: the kernel pads each interface
      matrix to `rank_padded(rl_k*rr_k)` lanes internally and slices the
      true width back out — callers never see padded shapes."""
    pairs = tuple((int(c.shape[0]), int(c.shape[2])) for c in cores)
    if method == "pallas":
        in_pairs = tuple(p for m, p in enumerate(pairs) if m != mode)
        tt_ranks = tuple(pairs[k][1] for k in range(len(cores) - 1))
        op = _planned_cached(
            "tt", st, mode, in_pairs, cfg, interpret,
            lambda: make_planned_ttcore(st, mode, tt_ranks, cfg=cfg, interpret=interpret),
        )
        mats = [jnp.transpose(c, (1, 0, 2)).reshape(c.shape[1], -1) for c in cores]
        return op.output(mats, st.shape[mode])
    if method != "reference":
        raise ValueError(f"unknown method {method!r}: expected 'pallas' or 'reference'")
    return ttcore_ref(
        jnp.asarray(st.indices), jnp.asarray(st.values), cores, mode, st.shape[mode]
    )


# ---------------------------------------------------------------------------
# Sharded planned decomposition (the repro.dist.planned substrate)
# ---------------------------------------------------------------------------
#
# The distributed composition of the whole repo: the COO stream is partitioned
# into balanced output-mode tile ranges (dist/sharding.partition_stream — the
# paper's "each DMA engine serves one slice of the remapped stream" posture),
# one BlockPlan is built per (shard, mode) so every shard's remapped layout is
# local to its device, and the existing Pallas kernels run unchanged under
# shard_map with ONE psum of partial factor rows per mode.  Because shard
# boundaries are tile_i-aligned, each device's kernel writes a disjoint set of
# output tiles and the psum is a pure reassembly (plus float reassociation).


@dataclasses.dataclass
class _ShardStack:
    """Stacked (shard-major) BlockPlan layouts for one output mode: shard d's
    layout occupies row d of every array, padded to the widest shard's block
    count.  Padding blocks carry zero values and *repeat the last real
    block's tile ids*, so they re-zero no accumulator, trigger no extra tile
    fills, and contribute exactly nothing.  Geometry fields mirror BlockPlan
    (identical across shards: same controller config, same global shape)."""

    block_it: jax.Array  # (D, NB) int32 — global output tile ids
    block_in: tuple  # n_in x (D, NB) int32
    vals: jax.Array  # (D, NB, blk) f32
    iloc: jax.Array  # (D, NB, blk) int32
    in_locs: tuple  # n_in x (D, NB, blk) int32
    row_mask: jax.Array  # (D, out_rows) f32 — 1.0 on each shard's visited tiles
    tile_i: int
    in_tiles: tuple[int, ...]
    blk: int
    out_rows: int  # padded global I_out (multiple of tile_i)
    in_rows: tuple[int, ...]
    mode: int
    in_modes: tuple[int, ...]
    shard_nblocks: tuple[int, ...]  # true per-shard block counts (pre-pad)
    shard_nnz: tuple[int, ...]
    tile_bounds: tuple[int, ...]  # partition cut points, in tile_i units

    @property
    def nshards(self) -> int:
        return int(self.block_it.shape[0])

    @property
    def nblocks(self) -> int:
        """Padded per-shard block count (the stack width)."""
        return int(self.block_it.shape[1])

    @property
    def n_in(self) -> int:
        return len(self.in_modes)

    def tree(self) -> dict:
        """The pytree handed through shard_map (leading dim = shard axis)."""
        return {
            "block_it": self.block_it,
            "block_in": self.block_in,
            "vals": self.vals,
            "iloc": self.iloc,
            "in_locs": self.in_locs,
            "row_mask": self.row_mask,
        }

    def tree_specs(self, axes) -> dict:
        """PartitionSpecs matching `tree()`: leading dim over the data axes."""
        row, cube = P(axes, None), P(axes, None, None)
        return {
            "block_it": row,
            "block_in": tuple(row for _ in self.block_in),
            "vals": cube,
            "iloc": cube,
            "in_locs": tuple(cube for _ in self.in_locs),
            "row_mask": row,
        }


def _empty_shard_plan(shape: tuple[int, ...], mode: int, cfg: MemoryControllerConfig) -> BlockPlan:
    """An all-padding layout for a shard that owns no non-zeros (possible
    when nnz or the output tile count is smaller than the shard count): one
    zero-value block targeting tile 0, which accumulates exactly zero."""
    nmodes = len(shape)
    in_modes = tuple(m for m in range(nmodes) if m != mode)
    n_in = len(in_modes)
    in_tiles = cfg.cache.input_tiles(n_in)
    blk, tile_i = cfg.dma.blk, cfg.cache.tile_i
    ceil_to = lambda x, t: ((x + t - 1) // t) * t
    return BlockPlan(
        vals=np.zeros((blk,), np.float32),
        iloc=np.zeros((blk,), np.int32),
        in_locs=tuple(np.zeros((blk,), np.int32) for _ in range(n_in)),
        block_it=np.zeros((1,), np.int32),
        block_in=tuple(np.zeros((1,), np.int32) for _ in range(n_in)),
        tile_i=tile_i,
        in_tiles=in_tiles,
        blk=blk,
        out_rows=ceil_to(shape[mode], tile_i),
        in_rows=tuple(ceil_to(shape[m], t) for m, t in zip(in_modes, in_tiles)),
        mode=mode,
        in_modes=in_modes,
        nnz=0,
    )


def _stack_shard_plans(plans: Sequence[BlockPlan], part, dist) -> _ShardStack:
    """Pad per-shard BlockPlans to a common block count and stack them
    shard-major, then device_put every array with its NamedSharding so each
    shard's layout is resident on its own device (never gathered)."""
    p0 = plans[0]
    for p in plans[1:]:
        assert (
            p.tile_i, p.in_tiles, p.blk, p.out_rows, p.in_rows, p.in_modes
        ) == (
            p0.tile_i, p0.in_tiles, p0.blk, p0.out_rows, p0.in_rows, p0.in_modes
        ), "shard plans must share controller geometry"
    nd = len(plans)
    nb = max(p.nblocks for p in plans)
    n_in, blk = p0.n_in, p0.blk
    row_mask = np.stack(
        [_visited_row_mask(p.block_it, p.tile_i, p.out_rows) for p in plans]
    )
    block_it = np.zeros((nd, nb), np.int32)
    block_in = [np.zeros((nd, nb), np.int32) for _ in range(n_in)]
    vals = np.zeros((nd, nb, blk), np.float32)
    iloc = np.zeros((nd, nb, blk), np.int32)
    in_locs = [np.zeros((nd, nb, blk), np.int32) for _ in range(n_in)]
    for d, p in enumerate(plans):
        k = p.nblocks
        block_it[d, :k] = p.block_it
        block_it[d, k:] = p.block_it[-1]
        for n in range(n_in):
            block_in[n][d, :k] = p.block_in[n]
            block_in[n][d, k:] = p.block_in[n][-1]
        vals[d, :k] = p.vals.reshape(k, blk)
        iloc[d, :k] = p.iloc.reshape(k, blk)
        for n in range(n_in):
            in_locs[n][d, :k] = p.in_locs[n].reshape(k, blk)
    mesh, axes = dist.mesh, dist.data_axes()
    sh_row = NamedSharding(mesh, P(axes, None))
    sh_cube = NamedSharding(mesh, P(axes, None, None))
    return _ShardStack(
        block_it=jax.device_put(block_it, sh_row),
        block_in=tuple(jax.device_put(b, sh_row) for b in block_in),
        vals=jax.device_put(vals, sh_cube),
        iloc=jax.device_put(iloc, sh_cube),
        in_locs=tuple(jax.device_put(l, sh_cube) for l in in_locs),
        row_mask=jax.device_put(row_mask, sh_row),
        tile_i=p0.tile_i,
        in_tiles=p0.in_tiles,
        blk=blk,
        out_rows=p0.out_rows,
        in_rows=p0.in_rows,
        mode=p0.mode,
        in_modes=p0.in_modes,
        shard_nblocks=tuple(p.nblocks for p in plans),
        shard_nnz=tuple(p.nnz for p in plans),
        tile_bounds=part.tile_bounds,
    )


def _sharded_mode_stack(
    st: SparseTensor,
    mode: int,
    cfg: MemoryControllerConfig,
    dist,
    kind: str,
):
    """Partition the stream for one output mode and build its shard-stacked
    layout.  Per-shard BlockPlans go through the shared LRU with shard-aware
    keys (`_planned_cached(shard=(d, nshards))`), so rebuilding a workspace
    for the same tensor skips the per-shard Tensor Remapper.  The cached
    objects are raw BlockPlans, which depend only on (stream, mode, cfg) —
    the rank key is a constant sentinel and interpret is pinned False, so
    rebuilding the same tensor at a different rank or interpret flag still
    hits.  Returns (partition, stack)."""
    from ..dist.sharding import partition_stream

    nshards = dist.dp_size()
    with _trace.span("shard_stack", kind=kind, mode=mode, nshards=nshards):
        part = partition_stream(st, mode, nshards, tile=cfg.cache.tile_i)
        n_in = st.nmodes - 1
        plans = []
        for d, shard in enumerate(part.shards):
            if shard.nnz == 0:
                plans.append(_empty_shard_plan(st.shape, mode, cfg))
                continue
            plans.append(
                _planned_cached(
                    kind, shard, mode, "layout", cfg, False,
                    lambda shard=shard: plan_blocks(
                        shard,
                        mode,
                        tile_i=cfg.cache.tile_i,
                        blk=cfg.dma.blk,
                        in_tiles=cfg.cache.input_tiles(n_in),
                    ),
                    shard=(d, nshards),
                )
            )
        stack = _stack_shard_plans(plans, part, dist)
    # The stacked sweep runs every shard for the widest shard's block count,
    # so max/mean block imbalance is the direct makespan-inflation factor.
    nblocks = [max(1, p.nblocks) for p in plans]
    _metrics.histogram("sharded.block_imbalance", kind=kind).observe(
        max(nblocks) * len(nblocks) / sum(nblocks)
    )
    return part, stack


def _stack_fit_stream(part, shape: tuple[int, ...], dist):
    """Shard-stacked raw COO stream for on-device fit terms: each shard's
    slice zero-padded to the widest shard (padding values are 0, so partial
    inner products are unchanged).  Returns (idx, val) with leading shard
    dim, device_put with their NamedShardings."""
    nd = part.nshards
    nnz_max = max(1, max(part.shard_nnz))
    idx = np.zeros((nd, nnz_max, len(shape)), np.int32)
    val = np.zeros((nd, nnz_max), np.float32)
    for d, sh in enumerate(part.shards):
        idx[d, : sh.nnz] = sh.indices
        val[d, : sh.nnz] = sh.values
    mesh, axes = dist.mesh, dist.data_axes()
    return (
        jax.device_put(idx, NamedSharding(mesh, P(axes, None, None))),
        jax.device_put(val, NamedSharding(mesh, P(axes, None))),
    )


def _stack_mttkrp_call(stack: _ShardStack, arrs: dict, in_facs, interpret: bool) -> jax.Array:
    """One shard's MTTKRP kernel over its row of the stack (inside shard_map
    every stacked array arrives with a leading local dim of 1).

    The result is multiplied by the shard's visited-row mask: the kernel's
    output buffer is only *written* for tiles its blocks visit; every other
    tile — outside the shard's partition range OR inside it but owning no
    non-zeros — keeps whatever the buffer held (NaNs in interpret mode,
    undefined on hardware).  Masking to the visited tiles zeroes both kinds
    and makes the psum a pure reassembly of disjoint contributions."""
    out = mttkrp_pallas_call(
        arrs["block_it"][0],
        tuple(t[0] for t in arrs["block_in"]),
        arrs["vals"][0],
        arrs["iloc"][0],
        tuple(l[0] for l in arrs["in_locs"]),
        in_facs,
        tile_i=stack.tile_i,
        in_tiles=stack.in_tiles,
        blk=stack.blk,
        out_rows=stack.out_rows,
        interpret=interpret,
    )
    return _apply_row_mask(out, arrs["row_mask"][0])


def _stack_ttmc_call(
    stack: _ShardStack, arrs: dict, in_facs, in_ranks: tuple[int, ...], interpret: bool
) -> jax.Array:
    """One shard's TTM-chain kernel over its row of the stack (visited-row
    masked — see `_stack_mttkrp_call`)."""
    out = ttmc_pallas_call(
        arrs["block_it"][0],
        tuple(t[0] for t in arrs["block_in"]),
        arrs["vals"][0],
        arrs["iloc"][0],
        tuple(l[0] for l in arrs["in_locs"]),
        in_facs,
        tile_i=stack.tile_i,
        in_tiles=stack.in_tiles,
        in_ranks=in_ranks,
        blk=stack.blk,
        out_rows=stack.out_rows,
        interpret=interpret,
    )
    return _apply_row_mask(out, arrs["row_mask"][0])


def _stack_ttcore_call(
    stack: _ShardStack,
    arrs: dict,
    in_mats,
    in_rank_pairs: tuple[tuple[int, int], ...],
    n_left: int,
    interpret: bool,
) -> jax.Array:
    """One shard's TT-core kernel over its row of the stack (visited-row
    masked — see `_stack_mttkrp_call`)."""
    out = ttcore_pallas_call(
        arrs["block_it"][0],
        tuple(t[0] for t in arrs["block_in"]),
        arrs["vals"][0],
        arrs["iloc"][0],
        tuple(l[0] for l in arrs["in_locs"]),
        in_mats,
        tile_i=stack.tile_i,
        in_tiles=stack.in_tiles,
        in_rank_pairs=in_rank_pairs,
        n_left=n_left,
        blk=stack.blk,
        out_rows=stack.out_rows,
        interpret=interpret,
    )
    return _apply_row_mask(out, arrs["row_mask"][0])


def _tuned_cfg(
    st: SparseTensor,
    mode: int,
    rank: int,
    nshards: int,
    cfg: MemoryControllerConfig | None,
    auto_tune: bool | str,
    spec: TPUSpec | str,
    kernel: str = "mttkrp",
    core_ranks: Sequence[int] | None = None,
) -> MemoryControllerConfig:
    """Resolve one mode's controller configuration for the sharded path:
    the sharded PMS's worst-shard-makespan winner when auto_tune is set
    (persisted/reused on disk for auto_tune="cached", keyed with the shard
    count — a 2-shard winner is not a 4-shard winner), else the explicit
    cfg, else the default."""
    auto_tune, spec = _resolve_tune(auto_tune, spec)
    if auto_tune:
        def _search():
            from ..core.pms import search_sharded

            best = search_sharded(
                st, mode, rank, nshards, spec=spec, top_k=1,
                kernel=kernel, core_ranks=core_ranks,
            )
            if not best:
                raise ValueError(
                    f"sharded PMS found no VMEM-feasible {kernel} configuration "
                    f"for mode {mode} over {nshards} shards (spec budget "
                    f"{spec.vmem_bytes * spec.vmem_usable_frac:.0f} bytes)"
                )
            return best[0].cfg

        rank_key = rank if core_ranks is None else tuple(int(r) for r in core_ranks)
        return _searched_cfg(
            auto_tune, kernel, st, mode, rank_key, spec, _search, nshards=nshards
        )
    return cfg or MemoryControllerConfig()


def _resolve_dist(dist, devices: int | None):
    """Default ShardingPlan for the sharded planned path: an explicit plan
    wins; otherwise a 1-D `shard` mesh over the first `devices` (or all)
    local devices (dist/planned.shard_plan)."""
    if dist is None:
        from ..dist.planned import shard_plan

        dist = shard_plan(devices)
    elif devices is not None and dist.dp_size() != devices:
        raise ValueError(
            f"both dist (dp_size={dist.dp_size()}) and devices={devices} "
            f"were passed and they disagree"
        )
    if dist.mesh is None or not dist.data_axes():
        raise ValueError(
            "the sharded planned path needs a ShardingPlan with a mesh and "
            "at least one data axis (see repro.dist.planned.shard_plan)"
        )
    return dist


@dataclasses.dataclass
class ShardedPlannedMTTKRP:
    """One (tensor, mode) MTTKRP distributed over a ShardingPlan's data axes.

    The stream is partitioned into balanced, tile_i-aligned output ranges;
    each shard's remapped BlockPlan layout lives on its own device
    (`_ShardStack` row) and a call runs the unchanged Pallas kernel under
    shard_map, psum-reducing the partial factor rows — `mttkrp_sharded`'s
    Table-1 `I_out*R` collective, now fed by the planned kernel instead of
    the pure-JAX approaches."""

    stack: _ShardStack
    dist: Any  # ShardingPlan with mesh + data axes
    rank: int
    interpret: bool
    cfg: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig
    )
    _call_fn: Callable | None = dataclasses.field(default=None, repr=False)

    def _build_call(self) -> Callable:
        stack, interpret = self.stack, self.interpret
        mesh, axes = self.dist.mesh, self.dist.data_axes()
        fac_specs = tuple(P(None, None) for _ in range(stack.n_in))

        def local_fn(arrs, pads):
            out = _stack_mttkrp_call(stack, arrs, pads, interpret)
            return jax.lax.psum(out, axes)

        def call(arrs, pads):
            return shard_map(
                local_fn,
                mesh=mesh,
                in_specs=(stack.tree_specs(axes), fac_specs),
                out_specs=P(None, None),
                check_rep=False,
            )(arrs, pads)

        return jax.jit(call)

    def __call__(self, *in_factors: jax.Array) -> jax.Array:
        """Factors for the N-1 *input* modes (stack.in_modes order), true
        shapes.  Returns (out_rows_padded, rank) sliced to true columns."""
        s = self.stack
        assert len(in_factors) == s.n_in
        rp = rank_padded(self.rank)
        pads = tuple(
            pad_factor(f, rows, rp) for f, rows in zip(in_factors, s.in_rows)
        )
        if self._call_fn is None:
            self._call_fn = self._build_call()
        out = self._call_fn(s.tree(), pads)
        return out[: s.out_rows, : self.rank]

    def output(self, factors: Sequence[jax.Array], true_rows: int) -> jax.Array:
        return self(*(factors[m] for m in self.stack.in_modes))[:true_rows]


def make_sharded_planned_mttkrp(
    st: SparseTensor,
    mode: int,
    rank: int,
    *,
    dist=None,
    devices: int | None = None,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> ShardedPlannedMTTKRP:
    """Build the distributed memory layout + kernel instance for one output
    mode.  With auto_tune=True the PMS scores configurations by their *worst
    shard* (`pms.search_sharded` makespan) before the layouts are built."""
    dist = _resolve_dist(dist, devices)
    cfg = _tuned_cfg(st, mode, rank, dist.dp_size(), cfg, auto_tune, spec)
    _, stack = _sharded_mode_stack(st, mode, cfg, dist, "mttkrp")
    return ShardedPlannedMTTKRP(
        stack=stack, dist=dist, rank=rank, interpret=interpret, cfg=cfg
    )


@dataclasses.dataclass
class ShardedPlannedCPALS(ShardedWorkspace):
    """Distributed `PlannedCPALS`: the whole CP-ALS loop on shard-local
    memory-controller layouts.

    One `_ShardStack` per output mode — shard d of mode m's stack holds the
    remapped, device-resident layout of shard d's slice of the stream,
    partitioned by mode-m output tiles (`partition_stream`).  `sweep` runs a
    full ALS iteration as ONE jitted shard_map: per mode, every device runs
    the Pallas kernel on its local layout and a single `psum` reassembles the
    factor rows (shards own disjoint tile ranges, so the sum merges rather
    than accumulates); gram/solve/normalize then run replicated.  The fit is
    computed from psum'd scalars — each shard contributes the inner product
    over its own stream slice.  Padding/residency and the drive loop come
    from `ShardedWorkspace` — this class supplies only the CP sweep body."""

    stacks: dict[int, _ShardStack]
    dist: Any  # ShardingPlan with mesh + data axes
    shape: tuple[int, ...]
    rank: int
    interpret: bool
    cfgs: dict[int, MemoryControllerConfig]
    idx_sh: jax.Array  # (D, max shard nnz, N) fit stream, zero-padded
    val_sh: jax.Array  # (D, max shard nnz)

    @property
    def lane_ranks(self) -> tuple[int, ...]:
        return (self.rank,) * self.nmodes

    @property
    def rank_pad(self) -> int:
        """CP's single lane padding (every mode shares rank R)."""
        return rank_padded(self.rank)

    def _stream_args(self) -> tuple:
        return (self.idx_sh, self.val_sh)

    def _build_sweep(self) -> Callable:
        shape, rank, nmodes = self.shape, self.rank, self.nmodes
        rp, prows = self.rank_pad, self.padded_rows
        stacks, interpret = self.stacks, self.interpret
        mesh, axes = self.dist.mesh, self.dist.data_axes()
        arr_specs = {m: stacks[m].tree_specs(axes) for m in range(nmodes)}
        fac_specs = tuple(P(None, None) for _ in range(nmodes))

        def local_sweep(arrs, idx, val, facs, norm_x_sq, first):
            facs = list(facs)
            lam = None
            for m in range(nmodes):
                s = stacks[m]
                in_facs = tuple(
                    facs[im][: s.in_rows[n]] for n, im in enumerate(s.in_modes)
                )
                out = _stack_mttkrp_call(s, arrs[m], in_facs, interpret)
                # The single collective per mode: partial factor rows from
                # disjoint tile ranges -> the full MTTKRP output.
                mt = jax.lax.psum(out, axes)[: shape[m], :rank]
                true = [f[:sz, :rank] for f, sz in zip(facs, shape)]
                true, lam = _update_mode(mt, true, m, first)
                f = true[m]
                facs[m] = (
                    jnp.zeros((prows[m], rp), f.dtype).at[: shape[m], :rank].set(f)
                )
            true = [f[:sz, :rank] for f, sz in zip(facs, shape)]
            # Fit from psum'd scalars: each shard's slice of <X, model>
            # (padding entries carry value 0), reduced once.
            inner = jax.lax.psum(inner_with_model(idx[0], val[0], true, lam), axes)
            resid_sq = jnp.maximum(
                norm_x_sq + model_norm_sq(true, lam) - 2.0 * inner, 0.0
            )
            fit = 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)
            return tuple(facs), lam, fit

        def sweep(arrs, idx_sh, val_sh, facs, norm_x_sq, first):
            fn = functools.partial(local_sweep, first=first)
            return shard_map(
                fn,
                mesh=mesh,
                in_specs=(
                    arr_specs,
                    P(axes, None, None),
                    P(axes, None),
                    fac_specs,
                    P(),
                ),
                out_specs=(fac_specs, P(None), P()),
                check_rep=False,
            )(arrs, idx_sh, val_sh, facs, norm_x_sq)

        return jax.jit(sweep, static_argnames=("first",))

    def sweep(self, facs, norm_x_sq, *, first: bool = False):
        """One jitted distributed ALS iteration in padded space.

        Args: `facs` — the rank-padded factor tuple from `pad_factors`
        (replicated); `norm_x_sq` — ||X||^2 scalar.  Returns (new padded
        factors, lam, fit scalar on device) — the same contract as
        `PlannedCPALS.sweep` minus the stream arguments (each shard's slice
        already lives on its device)."""
        return super().sweep(facs, norm_x_sq, first=first)

    def _sweep_call(self, facs, *args, it: int):
        return self.sweep(facs, *args, first=(it == 0))


def make_sharded_planned_cp_als(
    st: SparseTensor,
    rank: int,
    *,
    dist=None,
    devices: int | None = None,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> ShardedPlannedCPALS:
    """Build the distributed ALS workspace: one partition + shard-stacked
    layout per output mode (each mode partitions by ITS OWN output
    coordinate, exactly as each mode gets its own remap in Alg. 5).

    dist/devices: a ShardingPlan with >= 1 data axis, or a device count for
    the default 1-D `shard` mesh (None = all local devices).  With
    auto_tune=True each mode's controller configuration is chosen by the
    sharded PMS (worst-shard makespan, `pms.search_sharded`)."""
    dist = _resolve_dist(dist, devices)
    nshards = dist.dp_size()
    stacks: dict[int, _ShardStack] = {}
    cfgs: dict[int, MemoryControllerConfig] = {}
    part0 = None
    for m in range(st.nmodes):
        mcfg = _tuned_cfg(st, m, rank, nshards, cfg, auto_tune, spec)
        cfgs[m] = mcfg
        part, stacks[m] = _sharded_mode_stack(st, m, mcfg, dist, "mttkrp")
        if m == 0:
            part0 = part
    idx_sh, val_sh = _stack_fit_stream(part0, st.shape, dist)
    return ShardedPlannedCPALS(
        stacks=stacks,
        dist=dist,
        shape=st.shape,
        rank=rank,
        interpret=interpret,
        cfgs=cfgs,
        idx_sh=idx_sh,
        val_sh=val_sh,
    )


@dataclasses.dataclass
class ShardedPlannedTucker(ShardedWorkspace):
    """Distributed `PlannedTucker`: the whole HOOI loop on shard-local
    memory-controller layouts — the TTM-chain mirror of
    `ShardedPlannedCPALS` (same partitions, same stacks, Kronecker-chain
    kernel, per-mode `rank_padded(R_m)` lane contracts).  The fit needs no
    stream at all: the core comes from the last mode's psum'd unfolding and
    ||X||^2 - ||G||^2 gives the residual (orthonormal factors)."""

    stacks: dict[int, _ShardStack]
    dist: Any
    shape: tuple[int, ...]
    core_ranks: tuple[int, ...]
    interpret: bool
    cfgs: dict[int, MemoryControllerConfig]

    @property
    def lane_ranks(self) -> tuple[int, ...]:
        return self.core_ranks

    def in_ranks(self, mode: int) -> tuple[int, ...]:
        return tuple(self.core_ranks[im] for im in self.stacks[mode].in_modes)

    def _build_sweep(self) -> Callable:
        # Lazy: repro.tucker imports this module at load time.
        from ..tucker.hooi import (
            _core_from_unfolding,
            _factor_from_unfolding,
            core_fit_value,
        )

        shape, core_ranks, nmodes = self.shape, self.core_ranks, self.nmodes
        rps, prows = self.rank_pads, self.padded_rows
        stacks, interpret = self.stacks, self.interpret
        mesh, axes = self.dist.mesh, self.dist.data_axes()
        in_ranks = {m: self.in_ranks(m) for m in range(nmodes)}
        out_cols = {m: kron_cols(in_ranks[m]) for m in range(nmodes)}
        arr_specs = {m: stacks[m].tree_specs(axes) for m in range(nmodes)}
        fac_specs = tuple(P(None, None) for _ in range(nmodes))

        def local_sweep(arrs, facs, norm_x_sq):
            facs = list(facs)
            y = None
            for m in range(nmodes):
                s = stacks[m]
                in_facs = tuple(
                    facs[im][: s.in_rows[n]] for n, im in enumerate(s.in_modes)
                )
                out = _stack_ttmc_call(s, arrs[m], in_facs, in_ranks[m], interpret)
                y = jax.lax.psum(out, axes)[: shape[m], : out_cols[m]]
                u = _factor_from_unfolding(y, core_ranks[m])
                facs[m] = (
                    jnp.zeros((prows[m], rps[m]), u.dtype)
                    .at[: shape[m], : core_ranks[m]]
                    .set(u)
                )
            last = nmodes - 1
            u_last = facs[last][: shape[last], : core_ranks[last]]
            core = _core_from_unfolding(y, u_last, last, core_ranks)
            return tuple(facs), core, core_fit_value(core, norm_x_sq)

        def sweep(arrs, facs, norm_x_sq):
            return shard_map(
                local_sweep,
                mesh=mesh,
                in_specs=(arr_specs, fac_specs, P()),
                out_specs=(fac_specs, P(*([None] * nmodes)), P()),
                check_rep=False,
            )(arrs, facs, norm_x_sq)

        return jax.jit(sweep)

    def sweep(self, facs, norm_x_sq):
        """One jitted distributed HOOI iteration in padded space.  Returns
        (new padded factors, core, fit scalar on device) — the
        `PlannedTucker.sweep` contract."""
        return super().sweep(facs, norm_x_sq)


def make_sharded_planned_tucker(
    st: SparseTensor,
    core_ranks: Sequence[int],
    *,
    dist=None,
    devices: int | None = None,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> ShardedPlannedTucker:
    """Build the distributed HOOI workspace: one partition + shard-stacked
    TTMc layout per output mode.  Mirrors `make_sharded_planned_cp_als`;
    with auto_tune=True the sharded PMS scores the TTMc roofline per mode
    (`search_sharded(kernel="ttmc", core_ranks=...)`)."""
    from ..tucker.hooi import _validated_core_ranks

    cr = _validated_core_ranks(st, core_ranks)
    dist = _resolve_dist(dist, devices)
    nshards = dist.dp_size()
    stacks: dict[int, _ShardStack] = {}
    cfgs: dict[int, MemoryControllerConfig] = {}
    for m in range(st.nmodes):
        mcfg = _tuned_cfg(
            st, m, max(cr), nshards, cfg, auto_tune, spec,
            kernel="ttmc", core_ranks=cr,
        )
        cfgs[m] = mcfg
        _, stacks[m] = _sharded_mode_stack(st, m, mcfg, dist, "ttmc")
    return ShardedPlannedTucker(
        stacks=stacks,
        dist=dist,
        shape=st.shape,
        core_ranks=cr,
        interpret=interpret,
        cfgs=cfgs,
    )


@dataclasses.dataclass
class ShardedPlannedTT(ShardedWorkspace):
    """Distributed `PlannedTT`: the whole TT-ALS loop on shard-local
    memory-controller layouts — the TT-core mirror of `ShardedPlannedCPALS`
    (same partitions, same stacks, Kronecker-of-two-interfaces kernel,
    per-mode `rank_padded(rl_m*rr_m)` lane contracts).  Per mode, every
    device runs the TT-core kernel on its local layout, ONE psum reassembles
    the right-hand side B_m, and the normal-equations solve runs replicated;
    the fit's per-nnz TT inner product is psum'd over each shard's stream
    slice, like CP's."""

    stacks: dict[int, _ShardStack]
    dist: Any
    shape: tuple[int, ...]
    tt_ranks: tuple[int, ...]  # N-1 interior bond ranks
    interpret: bool
    cfgs: dict[int, MemoryControllerConfig]
    idx_sh: jax.Array  # (D, max shard nnz, N) fit stream, zero-padded
    val_sh: jax.Array  # (D, max shard nnz)

    @property
    def bond_pairs(self) -> tuple[tuple[int, int], ...]:
        return _tt_bond_pairs(self.tt_ranks, self.nmodes)

    @property
    def lane_ranks(self) -> tuple[int, ...]:
        return tuple(a * b for a, b in self.bond_pairs)

    def in_rank_pairs(self, mode: int) -> tuple[tuple[int, int], ...]:
        pairs = self.bond_pairs
        return tuple(pairs[im] for im in self.stacks[mode].in_modes)

    def _stream_args(self) -> tuple:
        return (self.idx_sh, self.val_sh)

    def _build_sweep(self) -> Callable:
        # Lazy: repro.tt imports this module at load time.
        from ..tt.als import _p_next, _q_suffix, _solve_core, matrix_to_core, tt_inner

        shape, nmodes = self.shape, self.nmodes
        pairs, lr = self.bond_pairs, self.lane_ranks
        rps, prows = self.rank_pads, self.padded_rows
        stacks, interpret = self.stacks, self.interpret
        mesh, axes = self.dist.mesh, self.dist.data_axes()
        in_pairs = {m: self.in_rank_pairs(m) for m in range(nmodes)}
        arr_specs = {m: stacks[m].tree_specs(axes) for m in range(nmodes)}
        fac_specs = tuple(P(None, None) for _ in range(nmodes))

        def local_sweep(arrs, idx, val, facs, norm_x_sq):
            facs = list(facs)
            cores = [
                matrix_to_core(facs[m][: shape[m], : lr[m]], *pairs[m])
                for m in range(nmodes)
            ]
            # Right interfaces from the incoming cores (cores > m are
            # untouched until the left-to-right sweep reaches them), the
            # running left interface from each freshly solved core.
            qs = _q_suffix(cores)
            p = jnp.ones((1, 1), jnp.float32)
            for m in range(nmodes):
                s = stacks[m]
                in_mats = tuple(
                    facs[im][: s.in_rows[n]] for n, im in enumerate(s.in_modes)
                )
                out = _stack_ttcore_call(s, arrs[m], in_mats, in_pairs[m], m, interpret)
                # The single collective per mode: partial right-hand-side
                # rows from disjoint tile ranges -> the full B_m.
                b = jax.lax.psum(out, axes)[: shape[m], : lr[m]]
                w = _solve_core(jnp.kron(p, qs[m]), b)
                cores[m] = matrix_to_core(w, *pairs[m])
                facs[m] = (
                    jnp.zeros((prows[m], rps[m]), w.dtype)
                    .at[: shape[m], : lr[m]]
                    .set(w)
                )
                p = _p_next(p, cores[m])
            # Fit from psum'd scalars: each shard's slice of <X, TT>
            # (padding entries carry value 0); ||TT||^2 is the completed
            # left-interface chain, a replicated scalar.
            inner = jax.lax.psum(tt_inner(idx[0], val[0], cores), axes)
            resid_sq = jnp.maximum(norm_x_sq + p[0, 0] - 2.0 * inner, 0.0)
            fit = 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)
            return tuple(facs), fit

        def sweep(arrs, idx_sh, val_sh, facs, norm_x_sq):
            facs, fit = shard_map(
                local_sweep,
                mesh=mesh,
                in_specs=(
                    arr_specs,
                    P(axes, None, None),
                    P(axes, None),
                    fac_specs,
                    P(),
                ),
                out_specs=(fac_specs, P()),
                check_rep=False,
            )(arrs, idx_sh, val_sh, facs, norm_x_sq)
            return facs, None, fit

        return jax.jit(sweep)

    def sweep(self, facs, norm_x_sq):
        """One jitted distributed TT-ALS iteration in padded space.  Returns
        (new padded interface matrices, None, fit scalar on device) — the
        `PlannedTT.sweep` contract."""
        return super().sweep(facs, norm_x_sq)


def make_sharded_planned_tt(
    st: SparseTensor,
    tt_ranks: Sequence[int],
    *,
    dist=None,
    devices: int | None = None,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> ShardedPlannedTT:
    """Build the distributed TT-ALS workspace: one partition + shard-stacked
    TT-core layout per output mode.  Mirrors `make_sharded_planned_cp_als`;
    with auto_tune=True the sharded PMS scores the TT roofline per mode
    (`search_sharded(kernel="tt", core_ranks=...)`)."""
    from ..tt.als import _validated_tt_ranks

    tr = _validated_tt_ranks(st, tt_ranks)
    dist = _resolve_dist(dist, devices)
    nshards = dist.dp_size()
    stacks: dict[int, _ShardStack] = {}
    cfgs: dict[int, MemoryControllerConfig] = {}
    part0 = None
    for m in range(st.nmodes):
        mcfg = _tuned_cfg(
            st, m, max(tr), nshards, cfg, auto_tune, spec,
            kernel="tt", core_ranks=tr,
        )
        cfgs[m] = mcfg
        part, stacks[m] = _sharded_mode_stack(st, m, mcfg, dist, "tt")
        if m == 0:
            part0 = part
    idx_sh, val_sh = _stack_fit_stream(part0, st.shape, dist)
    return ShardedPlannedTT(
        stacks=stacks,
        dist=dist,
        shape=st.shape,
        tt_ranks=tr,
        interpret=interpret,
        cfgs=cfgs,
        idx_sh=idx_sh,
        val_sh=val_sh,
    )
