"""Jit'd wrappers for the MTTKRP kernels: plan construction + padding +
dispatch between the Pallas kernel, its interpret-mode validation path, and
the pure-JAX approaches.

`PlannedCPALS` is the workspace that makes the Pallas kernel the *production*
decomposition path (paper Alg. 1 + Alg. 5): one PMS-tunable BlockPlan +
device-resident layout per output mode, built once and cached across every
ALS iteration (the paper's layout="copies" posture — per-mode remapped
copies, a legitimate space/time trade on HBM).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.coo import SparseTensor
from ..core.memctrl import MemoryControllerConfig, TPUSpec
from ..core.pms import search as pms_search
from ..core.remap import BlockPlan, plan_blocks
from ..core.mttkrp import mttkrp as mttkrp_jax
from .mttkrp_pallas import mttkrp_pallas_call, pad_factor, rank_padded

__all__ = [
    "PlannedMTTKRP",
    "make_planned_mttkrp",
    "PlannedCPALS",
    "make_planned_cp_als",
    "mttkrp_auto",
]


@dataclasses.dataclass
class PlannedMTTKRP:
    """A compiled memory-controller instance for one (tensor, mode): the
    device-resident BlockPlan layout + a callable running the Pallas kernel."""

    plan: BlockPlan
    rank: int
    interpret: bool
    cfg: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig
    )
    _dev: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        p = self.plan
        nb, blk = p.nblocks, p.blk
        self._dev = dict(
            block_it=jnp.asarray(p.block_it),
            block_in=tuple(jnp.asarray(t) for t in p.block_in),
            vals=jnp.asarray(p.vals).reshape(nb, blk),
            iloc=jnp.asarray(p.iloc).reshape(nb, blk),
            in_locs=tuple(jnp.asarray(l).reshape(nb, blk) for l in p.in_locs),
        )

    def __call__(self, *in_factors: jax.Array) -> jax.Array:
        """Factors for the N-1 *input* modes (plan.in_modes order).
        Returns (out_rows_unpadded, rank)."""
        p = self.plan
        assert len(in_factors) == p.n_in
        rp = rank_padded(self.rank)
        pads = tuple(
            pad_factor(f, rows, rp) for f, rows in zip(in_factors, p.in_rows)
        )
        out = mttkrp_pallas_call(
            self._dev["block_it"],
            self._dev["block_in"],
            self._dev["vals"],
            self._dev["iloc"],
            self._dev["in_locs"],
            pads,
            tile_i=p.tile_i,
            in_tiles=p.in_tiles,
            blk=p.blk,
            out_rows=p.out_rows,
            interpret=self.interpret,
        )
        return out[: p.out_rows, : self.rank]

    def output(self, factors: Sequence[jax.Array], true_rows: int) -> jax.Array:
        return self(*(factors[m] for m in self.plan.in_modes))[:true_rows]


def make_planned_mttkrp(
    st: SparseTensor,
    mode: int,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool = False,
    spec: TPUSpec = TPUSpec(),
    interpret: bool = True,
) -> PlannedMTTKRP:
    """Build the memory layout (Tensor Remapper) + kernel instance.  With
    auto_tune=True the PMS picks the controller parameters (Sec. 5.3)."""
    if auto_tune:
        best = pms_search(st, mode, rank, spec=spec, top_k=1)
        if not best:
            raise ValueError(
                f"PMS found no VMEM-feasible controller configuration for "
                f"mode {mode} at rank {rank} (spec budget "
                f"{spec.vmem_bytes * spec.vmem_usable_frac:.0f} bytes)"
            )
        cfg = best[0].cfg
    cfg = cfg or MemoryControllerConfig()
    n_in = st.nmodes - 1
    plan = plan_blocks(
        st,
        mode,
        tile_i=cfg.cache.tile_i,
        blk=cfg.dma.blk,
        in_tiles=cfg.cache.input_tiles(n_in),
    )
    return PlannedMTTKRP(plan=plan, rank=rank, interpret=interpret, cfg=cfg)


@dataclasses.dataclass
class PlannedCPALS:
    """Per-mode plan cache driving the whole CP-ALS loop on the memory
    controller (paper Alg. 1 on the Alg. 5 layout).

    One `PlannedMTTKRP` per output mode — each holds its own remapped,
    device-resident copy of the non-zero stream — constructed once and reused
    for every ALS iteration, so the plan/remap cost is amortized over the
    decomposition exactly as the paper amortizes the FPGA layout generation
    over the (many-iteration) ALS run.
    """

    ops: dict[int, PlannedMTTKRP]
    shape: tuple[int, ...]
    rank: int

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    def plan_for(self, mode: int) -> BlockPlan:
        return self.ops[mode].plan

    def mttkrp_fn(self, indices, values, factors, mode, out_rows):
        """The `cp_als(mttkrp_fn=...)` seam: the stream args are ignored —
        each mode's remapped copy already lives on device in its plan."""
        return self.ops[mode].output(factors, out_rows)

    def plan_bytes(self) -> int:
        """HBM held by the per-mode layouts (the 'copies' trade, Sec. 3).
        Element widths come from each mode's Remapper configuration."""
        total = 0
        for op in self.ops.values():
            p, r = op.plan, op.cfg.remapper
            slots = p.vals.shape[0]
            total += slots * (r.value_bytes + (1 + p.n_in) * r.index_bytes)
            total += p.nblocks * (1 + p.n_in) * r.index_bytes
        return total


def make_planned_cp_als(
    st: SparseTensor,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool = False,
    spec: TPUSpec = TPUSpec(),
    interpret: bool = True,
) -> PlannedCPALS:
    """Build the full ALS workspace: one tuned plan per output mode.

    With auto_tune=True each mode gets its own PMS-selected controller
    configuration (modes have different shapes/locality, Sec. 5.3); otherwise
    `cfg` (or the default) is shared by every mode."""
    ops = {
        m: make_planned_mttkrp(
            st, m, rank, cfg=cfg, auto_tune=auto_tune, spec=spec, interpret=interpret
        )
        for m in range(st.nmodes)
    }
    return PlannedCPALS(ops=ops, shape=st.shape, rank=rank)


def mttkrp_auto(
    st: SparseTensor,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    method: str = "pallas",
    interpret: bool = True,
    cfg: MemoryControllerConfig | None = None,
    sorted_by_mode: bool | None = None,
) -> jax.Array:
    """One-shot dispatcher used by tests/benchmarks: 'pallas' | 'approach1' |
    'approach2'.

    `sorted_by_mode` defaults to what the stream actually satisfies
    (`st.is_sorted_by(mode)`): `indices_are_sorted` is a correctness promise
    to XLA, not a hint, so it is never asserted for an unsorted stream."""
    rank = int(factors[0].shape[1])
    if method == "pallas":
        op = make_planned_mttkrp(st, mode, rank, cfg=cfg, interpret=interpret)
        return op.output(factors, st.shape[mode])
    if sorted_by_mode is None:
        sorted_by_mode = st.is_sorted_by(mode)
    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    return mttkrp_jax(
        idx, val, factors, mode, st.shape[mode],
        method=method, sorted_by_mode=sorted_by_mode,
    )
