"""Jit'd wrappers for the decomposition kernels: plan construction + padding +
dispatch between the Pallas kernels, their interpret-mode validation paths,
and the pure-JAX references.

Two kernel families share the BlockPlan substrate (the memory controller is
*programmable*, not MTTKRP-specific):
  * MTTKRP  — `PlannedMTTKRP` / `mttkrp_auto` / `PlannedCPALS` (CP-ALS,
              paper Alg. 1 + Alg. 5);
  * TTMc    — `PlannedTTMC` / `tucker_auto` (sparse Tucker HOOI; see
              repro.tucker).  Same remapped layout, Kronecker-chain compute.

`PlannedCPALS` is the workspace that makes the Pallas kernel the *production*
decomposition path (paper Alg. 1 + Alg. 5): one PMS-tunable BlockPlan +
device-resident layout per output mode, built once and cached across every
ALS iteration (the paper's layout="copies" posture — per-mode remapped
copies, a legitimate space/time trade on HBM).  `PlannedTucker`
(repro.tucker.hooi) mirrors it for the HOOI loop.

The one-shot dispatchers share a keyed LRU plan cache.  The key leads with a
kernel-kind discriminator ("mttkrp" / "ttmc"): two kernels sharing a tensor
fingerprint + mode + rank must never silently reuse each other's plans (the
layouts coincide today, but the cached objects carry kernel-specific state).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from ..core.coo import SparseTensor
from ..core.cp_als import _update_mode, fit_value
from ..core.memctrl import MemoryControllerConfig, TPUSpec
from ..core.pms import search as pms_search
from ..core.remap import BlockPlan, plan_blocks
from ..core.mttkrp import mttkrp as mttkrp_jax
from .mttkrp_pallas import mttkrp_pallas_call, pad_factor, rank_padded
from .ref import ttmc_ref
from .ttm_pallas import kron_cols, ttmc_pallas_call

__all__ = [
    "PlannedMTTKRP",
    "make_planned_mttkrp",
    "PlannedCPALS",
    "make_planned_cp_als",
    "PlannedTTMC",
    "make_planned_ttmc",
    "mttkrp_auto",
    "tucker_auto",
    "plan_cache_stats",
    "plan_cache_clear",
    "planned_padded_rows",
    "planned_layout_bytes",
]


def _plan_device_arrays(plan: BlockPlan) -> dict:
    """Move a BlockPlan's layout to device in the shape the kernels consume:
    (nblocks, blk) stream tiles + per-block tile-id streams."""
    nb, blk = plan.nblocks, plan.blk
    return dict(
        block_it=jnp.asarray(plan.block_it),
        block_in=tuple(jnp.asarray(t) for t in plan.block_in),
        vals=jnp.asarray(plan.vals).reshape(nb, blk),
        iloc=jnp.asarray(plan.iloc).reshape(nb, blk),
        in_locs=tuple(jnp.asarray(l).reshape(nb, blk) for l in plan.in_locs),
    )


def planned_layout_bytes(ops: dict[int, "PlannedMTTKRP | PlannedTTMC"]) -> int:
    """HBM held by a per-mode plan family's remapped layouts (the 'copies'
    space/time trade, Sec. 3).  Element widths come from each mode's Remapper
    configuration; identical for MTTKRP and TTMc — the layout is shared."""
    total = 0
    for op in ops.values():
        p, r = op.plan, op.cfg.remapper
        slots = p.vals.shape[0]
        total += slots * (r.value_bytes + (1 + p.n_in) * r.index_bytes)
        total += p.nblocks * (1 + p.n_in) * r.index_bytes
    return total


def planned_padded_rows(ops: dict[int, "PlannedMTTKRP | PlannedTTMC"], nmodes: int) -> tuple[int, ...]:
    """Device-resident row padding per mode for a per-mode plan family: the
    largest padding any plan requires of that factor (its own plan's
    out_rows, plus in_rows wherever it appears as an input mode).  Each
    plan's kernel slices the rows it needs — a static, zero-copy slice
    inside a sweep jit."""
    rows = []
    for m in range(nmodes):
        r = ops[m].plan.out_rows
        for op in ops.values():
            p = op.plan
            for n, im in enumerate(p.in_modes):
                if im == m:
                    r = max(r, p.in_rows[n])
        rows.append(r)
    return tuple(rows)


@dataclasses.dataclass
class PlannedMTTKRP:
    """A compiled memory-controller instance for one (tensor, mode): the
    device-resident BlockPlan layout + a callable running the Pallas kernel."""

    plan: BlockPlan
    rank: int
    interpret: bool
    cfg: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig
    )
    _dev: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self._dev = _plan_device_arrays(self.plan)

    def __call__(self, *in_factors: jax.Array) -> jax.Array:
        """Factors for the N-1 *input* modes (plan.in_modes order).
        Returns (out_rows_unpadded, rank)."""
        p = self.plan
        assert len(in_factors) == p.n_in
        rp = rank_padded(self.rank)
        pads = tuple(
            pad_factor(f, rows, rp) for f, rows in zip(in_factors, p.in_rows)
        )
        out = mttkrp_pallas_call(
            self._dev["block_it"],
            self._dev["block_in"],
            self._dev["vals"],
            self._dev["iloc"],
            self._dev["in_locs"],
            pads,
            tile_i=p.tile_i,
            in_tiles=p.in_tiles,
            blk=p.blk,
            out_rows=p.out_rows,
            interpret=self.interpret,
        )
        return out[: p.out_rows, : self.rank]

    def output(self, factors: Sequence[jax.Array], true_rows: int) -> jax.Array:
        return self(*(factors[m] for m in self.plan.in_modes))[:true_rows]


def make_planned_mttkrp(
    st: SparseTensor,
    mode: int,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool = False,
    spec: TPUSpec = TPUSpec(),
    interpret: bool = True,
) -> PlannedMTTKRP:
    """Build the memory layout (Tensor Remapper) + kernel instance.  With
    auto_tune=True the PMS picks the controller parameters (Sec. 5.3)."""
    if auto_tune:
        best = pms_search(st, mode, rank, spec=spec, top_k=1)
        if not best:
            raise ValueError(
                f"PMS found no VMEM-feasible controller configuration for "
                f"mode {mode} at rank {rank} (spec budget "
                f"{spec.vmem_bytes * spec.vmem_usable_frac:.0f} bytes)"
            )
        cfg = best[0].cfg
    cfg = cfg or MemoryControllerConfig()
    n_in = st.nmodes - 1
    plan = plan_blocks(
        st,
        mode,
        tile_i=cfg.cache.tile_i,
        blk=cfg.dma.blk,
        in_tiles=cfg.cache.input_tiles(n_in),
    )
    return PlannedMTTKRP(plan=plan, rank=rank, interpret=interpret, cfg=cfg)


@dataclasses.dataclass
class PlannedTTMC:
    """A compiled memory-controller instance of the TTM-chain kernel for one
    (tensor, output mode): the same device-resident BlockPlan layout as
    MTTKRP, driving the Kronecker-chain Pallas kernel (repro.tucker HOOI's
    per-mode contraction).  `in_ranks` are the input-factor ranks in
    plan.in_modes order; the output has prod(in_ranks) true columns."""

    plan: BlockPlan
    in_ranks: tuple[int, ...]
    interpret: bool
    cfg: MemoryControllerConfig = dataclasses.field(
        default_factory=MemoryControllerConfig
    )
    _dev: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.in_ranks = tuple(int(r) for r in self.in_ranks)
        self._dev = _plan_device_arrays(self.plan)

    @property
    def out_cols(self) -> int:
        return kron_cols(self.in_ranks)

    def __call__(self, *in_factors: jax.Array) -> jax.Array:
        """Factors for the N-1 *input* modes (plan.in_modes order), true
        shapes.  Returns (out_rows_unpadded, prod(in_ranks))."""
        p = self.plan
        assert len(in_factors) == p.n_in
        pads = tuple(
            pad_factor(f, rows, rank_padded(r))
            for f, rows, r in zip(in_factors, p.in_rows, self.in_ranks)
        )
        out = self.call_padded(pads)
        return out[: p.out_rows, : self.out_cols]

    def call_padded(self, in_factors_pad: Sequence[jax.Array]) -> jax.Array:
        """Run the kernel on already row/lane-padded input factors (the
        PlannedTucker sweep path).  Returns the padded (out_rows, Pp) tile."""
        p = self.plan
        return ttmc_pallas_call(
            self._dev["block_it"],
            self._dev["block_in"],
            self._dev["vals"],
            self._dev["iloc"],
            self._dev["in_locs"],
            tuple(in_factors_pad),
            tile_i=p.tile_i,
            in_tiles=p.in_tiles,
            in_ranks=self.in_ranks,
            blk=p.blk,
            out_rows=p.out_rows,
            interpret=self.interpret,
        )

    def output(self, factors: Sequence[jax.Array], true_rows: int) -> jax.Array:
        return self(*(factors[m] for m in self.plan.in_modes))[:true_rows]


def make_planned_ttmc(
    st: SparseTensor,
    mode: int,
    core_ranks: Sequence[int],
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool = False,
    spec: TPUSpec = TPUSpec(),
    interpret: bool = True,
) -> PlannedTTMC:
    """Build the memory layout + TTMc kernel instance for one output mode.
    `core_ranks` is the full N-tuple of Tucker core ranks; the N-1 input
    ranks are taken from it.  With auto_tune=True the PMS tunes the
    controller for the TTMc kernel (core-tensor output tile in the VMEM
    model)."""
    core_ranks = tuple(int(r) for r in core_ranks)
    if len(core_ranks) != st.nmodes:
        raise ValueError(
            f"core_ranks has {len(core_ranks)} entries for a "
            f"{st.nmodes}-mode tensor (pass the full N-tuple)"
        )
    if auto_tune:
        best = pms_search(
            st, mode, max(core_ranks), spec=spec, top_k=1,
            kernel="ttmc", core_ranks=core_ranks,
        )
        if not best:
            raise ValueError(
                f"PMS found no VMEM-feasible controller configuration for "
                f"TTMc mode {mode} at core ranks {core_ranks} (spec budget "
                f"{spec.vmem_bytes * spec.vmem_usable_frac:.0f} bytes)"
            )
        cfg = best[0].cfg
    cfg = cfg or MemoryControllerConfig()
    n_in = st.nmodes - 1
    plan = plan_blocks(
        st,
        mode,
        tile_i=cfg.cache.tile_i,
        blk=cfg.dma.blk,
        in_tiles=cfg.cache.input_tiles(n_in),
    )
    in_ranks = tuple(core_ranks[m] for m in plan.in_modes)
    return PlannedTTMC(plan=plan, in_ranks=in_ranks, interpret=interpret, cfg=cfg)


@dataclasses.dataclass
class PlannedCPALS:
    """Per-mode plan cache driving the whole CP-ALS loop on the memory
    controller (paper Alg. 1 on the Alg. 5 layout).

    One `PlannedMTTKRP` per output mode — each holds its own remapped,
    device-resident copy of the non-zero stream — constructed once and reused
    for every ALS iteration, so the plan/remap cost is amortized over the
    decomposition exactly as the paper amortizes the FPGA layout generation
    over the (many-iteration) ALS run.

    The steady-state iteration is `sweep`: one jitted function running a full
    ALS iteration (every mode's MTTKRP -> gram -> solve -> normalize, plus the
    on-device fit).  Factors stay rank-padded and device-resident across
    iterations — `pad_factors` pads each mode once up front (to the maximum
    row padding any plan needs, lanes to rank_padded) and the sweep updates
    them in padded space; `unpad_factors` slices back to true shape only when
    a `CPState` is materialized.
    """

    ops: dict[int, PlannedMTTKRP]
    shape: tuple[int, ...]
    rank: int
    _sweep_fn: Callable | None = dataclasses.field(default=None, repr=False)

    @property
    def nmodes(self) -> int:
        return len(self.shape)

    @property
    def rank_pad(self) -> int:
        return rank_padded(self.rank)

    def plan_for(self, mode: int) -> BlockPlan:
        return self.ops[mode].plan

    @property
    def padded_rows(self) -> tuple[int, ...]:
        """Per-mode device-resident row padding (see `planned_padded_rows`)."""
        return planned_padded_rows(self.ops, self.nmodes)

    def pad_factors(self, factors: Sequence[jax.Array]) -> tuple[jax.Array, ...]:
        """One pad per mode for the whole decomposition (not N x iters)."""
        rp = self.rank_pad
        return tuple(
            pad_factor(f, rows, rp) for f, rows in zip(factors, self.padded_rows)
        )

    def unpad_factors(self, padded: Sequence[jax.Array]) -> list[jax.Array]:
        return [f[:s, : self.rank] for f, s in zip(padded, self.shape)]

    def _build_sweep(self) -> Callable:
        shape, rank, nmodes = self.shape, self.rank, self.nmodes
        rp, prows = self.rank_pad, self.padded_rows
        ops = self.ops

        def sweep(facs, idx, val, norm_x_sq, first):
            facs = list(facs)
            lam = None
            for m in range(nmodes):
                op, p = ops[m], ops[m].plan
                in_facs = tuple(
                    facs[im][: p.in_rows[n]] for n, im in enumerate(p.in_modes)
                )
                out = mttkrp_pallas_call(
                    op._dev["block_it"],
                    op._dev["block_in"],
                    op._dev["vals"],
                    op._dev["iloc"],
                    op._dev["in_locs"],
                    in_facs,
                    tile_i=p.tile_i,
                    in_tiles=p.in_tiles,
                    blk=p.blk,
                    out_rows=p.out_rows,
                    interpret=op.interpret,
                )
                mt = out[: shape[m], :rank]
                true = [f[:s, :rank] for f, s in zip(facs, shape)]
                true, lam = _update_mode(mt, true, m, first)
                # Re-pad in place of the old padded factor (padding rows and
                # lanes stay exactly zero, so grams/fit in padded space match
                # the true-shape computation bit for bit).
                f = true[m]
                facs[m] = jnp.zeros((prows[m], rp), f.dtype).at[: shape[m], :rank].set(f)
            true = [f[:s, :rank] for f, s in zip(facs, shape)]
            fit = fit_value(idx, val, true, lam, norm_x_sq)
            return tuple(facs), lam, fit

        return jax.jit(sweep, static_argnames=("first",))

    def sweep(self, facs, idx, val, norm_x_sq, *, first: bool = False):
        """One jitted ALS iteration in padded space.  Returns
        (new padded factors, lam, fit scalar on device)."""
        if self._sweep_fn is None:
            self._sweep_fn = self._build_sweep()
        return self._sweep_fn(facs, idx, val, norm_x_sq, first=first)

    def mttkrp_fn(self, indices, values, factors, mode, out_rows):
        """The `cp_als(mttkrp_fn=...)` seam: the stream args are ignored —
        each mode's remapped copy already lives on device in its plan."""
        return self.ops[mode].output(factors, out_rows)

    def plan_bytes(self) -> int:
        """HBM held by the per-mode layouts (the 'copies' trade, Sec. 3)."""
        return planned_layout_bytes(self.ops)


def make_planned_cp_als(
    st: SparseTensor,
    rank: int,
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool = False,
    spec: TPUSpec = TPUSpec(),
    interpret: bool = True,
) -> PlannedCPALS:
    """Build the full ALS workspace: one tuned plan per output mode.

    With auto_tune=True each mode gets its own PMS-selected controller
    configuration (modes have different shapes/locality, Sec. 5.3); otherwise
    `cfg` (or the default) is shared by every mode."""
    ops = {
        m: make_planned_mttkrp(
            st, m, rank, cfg=cfg, auto_tune=auto_tune, spec=spec, interpret=interpret
        )
        for m in range(st.nmodes)
    }
    return PlannedCPALS(ops=ops, shape=st.shape, rank=rank)


# ---------------------------------------------------------------------------
# Keyed plan cache for the one-shot dispatchers (mttkrp_auto / tucker_auto)
# ---------------------------------------------------------------------------

_PLAN_CACHE: OrderedDict[tuple, "PlannedMTTKRP | PlannedTTMC"] = OrderedDict()
_PLAN_CACHE_CAP = 32  # LRU bound: each entry pins a device-resident layout
_PLAN_CACHE_KINDS = ("mttkrp", "ttmc")
_PLAN_CACHE_STATS = {k: {"hits": 0, "misses": 0} for k in _PLAN_CACHE_KINDS}


def plan_cache_stats() -> dict:
    """Hit/miss counters of the shared plan cache (bench_e2e reports them: a
    hit means a call skipped the whole remap/layout build).  Totals at the
    top level plus per-kernel-kind counters under "by_kind" — the kinds are
    tracked separately precisely because the cache key carries a kind
    discriminator (no cross-kind collisions by construction)."""
    by_kind = {k: dict(v) for k, v in _PLAN_CACHE_STATS.items()}
    return {
        "hits": sum(v["hits"] for v in by_kind.values()),
        "misses": sum(v["misses"] for v in by_kind.values()),
        "by_kind": by_kind,
    }


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    for v in _PLAN_CACHE_STATS.values():
        v["hits"] = 0
        v["misses"] = 0


def _planned_cached(
    kind: str,
    st: SparseTensor,
    mode: int,
    rank_key,
    cfg: MemoryControllerConfig | None,
    interpret: bool,
    build: Callable,
):
    """LRU-cached plan lookup keyed by (kernel kind, tensor content
    fingerprint, mode, rank key, controller config, interpret) — repeated
    test/benchmark calls stop repaying the Tensor Remapper on every
    invocation.  The leading `kind` field keeps MTTKRP and TTMc plans for
    the same tensor/mode/rank from silently aliasing each other."""
    key = (
        kind,
        st.fingerprint(),
        mode,
        rank_key,
        cfg or MemoryControllerConfig(),
        bool(interpret),
    )
    stats = _PLAN_CACHE_STATS[kind]
    op = _PLAN_CACHE.get(key)
    if op is not None:
        stats["hits"] += 1
        _PLAN_CACHE.move_to_end(key)
        return op
    stats["misses"] += 1
    op = build()
    _PLAN_CACHE[key] = op
    while len(_PLAN_CACHE) > _PLAN_CACHE_CAP:
        _PLAN_CACHE.popitem(last=False)
    return op


def mttkrp_auto(
    st: SparseTensor,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    method: str = "pallas",
    interpret: bool = True,
    cfg: MemoryControllerConfig | None = None,
    sorted_by_mode: bool | None = None,
) -> jax.Array:
    """One-shot dispatcher used by tests/benchmarks: 'pallas' | 'approach1' |
    'approach2'.  The pallas path caches its BlockPlan keyed on the tensor's
    content fingerprint (see `plan_cache_stats`).

    `sorted_by_mode` defaults to what the stream actually satisfies
    (`st.is_sorted_by(mode)`): `indices_are_sorted` is a correctness promise
    to XLA, not a hint, so it is never asserted for an unsorted stream."""
    rank = int(factors[0].shape[1])
    if method == "pallas":
        op = _planned_cached(
            "mttkrp", st, mode, rank, cfg, interpret,
            lambda: make_planned_mttkrp(st, mode, rank, cfg=cfg, interpret=interpret),
        )
        return op.output(factors, st.shape[mode])
    if sorted_by_mode is None:
        sorted_by_mode = st.is_sorted_by(mode)
    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    return mttkrp_jax(
        idx, val, factors, mode, st.shape[mode],
        method=method, sorted_by_mode=sorted_by_mode,
    )


def tucker_auto(
    st: SparseTensor,
    factors: Sequence[jax.Array],
    mode: int,
    *,
    method: str = "pallas",
    interpret: bool = True,
    cfg: MemoryControllerConfig | None = None,
) -> jax.Array:
    """One-shot sparse TTM-chain dispatcher (the Tucker-side analogue of
    `mttkrp_auto`): contract every factor but `mode` into X, returning the
    unfolding Y_(mode) of shape (I_mode, prod of input ranks).

    method: 'pallas' — the planned memory-controller kernel, with its
    BlockPlan cached in the shared kind-keyed LRU (`plan_cache_stats()["by_kind"]
    ["ttmc"]`); 'reference' — the pure-jnp gather/Kronecker/segment_sum
    oracle.  `factors` holds all N factor matrices; the mode-th is not
    contracted (and its rank is not part of the cache key)."""
    core_ranks = tuple(int(f.shape[1]) for f in factors)
    if method == "pallas":
        in_ranks = tuple(r for m, r in enumerate(core_ranks) if m != mode)
        op = _planned_cached(
            "ttmc", st, mode, in_ranks, cfg, interpret,
            lambda: make_planned_ttmc(st, mode, core_ranks, cfg=cfg, interpret=interpret),
        )
        return op.output(factors, st.shape[mode])
    if method != "reference":
        raise ValueError(f"unknown method {method!r}: expected 'pallas' or 'reference'")
    return ttmc_ref(
        jnp.asarray(st.indices), jnp.asarray(st.values), factors, mode, st.shape[mode]
    )
