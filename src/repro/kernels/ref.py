"""Pure-jnp oracles for the MTTKRP kernels.

Two independent references:
  * `mttkrp_ref`        — gather -> Hadamard -> segment_sum (mirrors Alg. 2).
  * `mttkrp_ref_dense`  — densify + einsum; O(I*J*K*R), tiny shapes only, used
                          to cross-check the sparse reference itself.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["mttkrp_ref", "mttkrp_ref_dense", "mttkrp_plan_ref"]


def mttkrp_ref(
    indices: jax.Array,
    values: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    out_rows: int,
) -> jax.Array:
    prod = None
    for n, f in enumerate(factors):
        if n == mode:
            continue
        rows = f[indices[:, n]]
        prod = rows if prod is None else prod * rows
    contrib = prod * values[:, None].astype(prod.dtype)
    return jax.ops.segment_sum(contrib, indices[:, mode], num_segments=out_rows)


def mttkrp_ref_dense(
    indices: np.ndarray,
    values: np.ndarray,
    factors: Sequence[np.ndarray],
    mode: int,
    out_rows: int,
) -> np.ndarray:
    """Densify-and-einsum cross-check (3-mode, duplicate-accumulating)."""
    assert len(factors) == 3
    shape = tuple(int(f.shape[0]) for f in factors)
    dense = np.zeros(shape, np.float64)
    np.add.at(dense, tuple(indices[:, m] for m in range(3)), values.astype(np.float64))
    ins = [n for n in range(3) if n != mode]
    letters = "ijk"
    spec = f"ijk,{letters[ins[0]]}r,{letters[ins[1]]}r->{letters[mode]}r"
    out = np.einsum(spec, dense, factors[ins[0]].astype(np.float64), factors[ins[1]].astype(np.float64))
    return out[:out_rows].astype(np.float32)


def mttkrp_plan_ref(plan, factors_padded: Sequence[jax.Array], rank_padded: int) -> jax.Array:
    """Oracle operating on the *kernel's* input layout (BlockPlan): computes
    exactly what the Pallas kernel should produce, including padded rows.
    N-mode: one padded factor per input mode, in plan.in_modes order.
    Returns (out_rows_padded, rank_padded)."""
    blk = plan.blk
    vals = jnp.asarray(plan.vals)
    gi = jnp.repeat(jnp.asarray(plan.block_it), blk) * plan.tile_i + jnp.asarray(plan.iloc)
    contrib = vals[:, None]
    for f_pad, tids, loc, tile in zip(
        factors_padded, plan.block_in, plan.in_locs, plan.in_tiles
    ):
        g = jnp.repeat(jnp.asarray(tids), blk) * tile + jnp.asarray(loc)
        contrib = contrib * f_pad[g]
    return jax.ops.segment_sum(contrib, gi, num_segments=plan.out_rows)
