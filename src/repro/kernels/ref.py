"""Pure-jnp oracles for the MTTKRP, TTM-chain (TTMc) and TT-core kernels.

Independent references per kernel family:
  * `mttkrp_ref`        — gather -> Hadamard -> segment_sum (mirrors Alg. 2).
  * `mttkrp_ref_dense`  — densify + einsum; O(I*J*K*R), tiny shapes only, used
                          to cross-check the sparse reference itself.
  * `ttmc_ref`          — gather -> Kronecker chain -> segment_sum: the sparse
                          TTMc unfolding Y_(n) = X_(n) (kron of input factors)
                          that drives the Tucker HOOI loop.
  * `ttmc_ref_dense`    — densify + einsum cross-check, any order >= 3.
  * `ttcore_ref`        — gather -> left/right interface chains -> Kronecker
                          of two -> segment_sum: the TT-ALS right-hand side
                          B_m that drives the tensor-train loop.
  * `ttcore_ref_dense`  — densify + einsum cross-check, any order >= 3.
Each family also has a `*_plan_ref` oracle operating on the kernel's own
BlockPlan layout (including padded rows).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "mttkrp_ref",
    "mttkrp_ref_dense",
    "mttkrp_plan_ref",
    "ttmc_ref",
    "ttmc_ref_dense",
    "ttmc_plan_ref",
    "ttcore_ref",
    "ttcore_ref_dense",
    "ttcore_plan_ref",
]


def mttkrp_ref(
    indices: jax.Array,
    values: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    out_rows: int,
) -> jax.Array:
    prod = None
    for n, f in enumerate(factors):
        if n == mode:
            continue
        rows = f[indices[:, n]]
        prod = rows if prod is None else prod * rows
    contrib = prod * values[:, None].astype(prod.dtype)
    return jax.ops.segment_sum(contrib, indices[:, mode], num_segments=out_rows)


def mttkrp_ref_dense(
    indices: np.ndarray,
    values: np.ndarray,
    factors: Sequence[np.ndarray],
    mode: int,
    out_rows: int,
) -> np.ndarray:
    """Densify-and-einsum cross-check (3-mode, duplicate-accumulating)."""
    assert len(factors) == 3
    shape = tuple(int(f.shape[0]) for f in factors)
    dense = np.zeros(shape, np.float64)
    np.add.at(dense, tuple(indices[:, m] for m in range(3)), values.astype(np.float64))
    ins = [n for n in range(3) if n != mode]
    letters = "ijk"
    spec = f"ijk,{letters[ins[0]]}r,{letters[ins[1]]}r->{letters[mode]}r"
    out = np.einsum(spec, dense, factors[ins[0]].astype(np.float64), factors[ins[1]].astype(np.float64))
    return out[:out_rows].astype(np.float32)


def ttmc_ref(
    indices: jax.Array,
    values: jax.Array,
    factors: Sequence[jax.Array],
    mode: int,
    out_rows: int,
) -> jax.Array:
    """Sparse TTM-chain: Y[i_n, :] += v * kron(rows of every factor != mode),
    columns in row-major order over ascending input-mode index.  `factors`
    holds all N factor matrices; the mode-th is ignored.  Returns
    (out_rows, prod of input ranks)."""
    nnz = values.shape[0]
    contrib = values[:, None].astype(jnp.float32)
    for n, f in enumerate(factors):
        if n == mode:
            continue
        rows = f[indices[:, n]].astype(jnp.float32)  # (nnz, R_n)
        contrib = (contrib[:, :, None] * rows[:, None, :]).reshape(nnz, -1)
    return jax.ops.segment_sum(contrib, indices[:, mode], num_segments=out_rows)


def ttmc_ref_dense(
    indices: np.ndarray,
    values: np.ndarray,
    factors: Sequence[np.ndarray],
    mode: int,
    out_rows: int,
) -> np.ndarray:
    """Densify-and-einsum cross-check for any order >= 3 (duplicate-
    accumulating, float64 internally): contracts every mode but `mode` with
    its factor and flattens the rank axes row-major."""
    nmodes = len(factors)
    assert nmodes <= 5, "dense oracle is for tiny cross-check shapes only"
    shape = tuple(int(f.shape[0]) for f in factors)
    dense = np.zeros(shape, np.float64)
    np.add.at(dense, tuple(indices[:, m] for m in range(nmodes)), values.astype(np.float64))
    ins = [n for n in range(nmodes) if n != mode]
    letters, ranks = "abcde"[:nmodes], "vwxyz"
    spec = (
        letters
        + ","
        + ",".join(letters[n] + ranks[k] for k, n in enumerate(ins))
        + "->"
        + letters[mode]
        + ranks[: len(ins)]
    )
    out = np.einsum(spec, dense, *[factors[n].astype(np.float64) for n in ins])
    return out.reshape(shape[mode], -1)[:out_rows].astype(np.float32)


def ttmc_plan_ref(
    plan, factors_padded: Sequence[jax.Array], in_ranks: Sequence[int]
) -> jax.Array:
    """Oracle on the kernel's BlockPlan layout: exactly what the Pallas TTMc
    kernel should produce, including padded rows (true columns only — the
    caller compares against out[:, :prod(in_ranks)]).  One lane-padded factor
    per input mode, in plan.in_modes order."""
    blk = plan.blk
    vals = jnp.asarray(plan.vals)
    gi = jnp.repeat(jnp.asarray(plan.block_it), blk) * plan.tile_i + jnp.asarray(plan.iloc)
    contrib = vals[:, None]
    for f_pad, tids, loc, tile, r in zip(
        factors_padded, plan.block_in, plan.in_locs, plan.in_tiles, in_ranks
    ):
        g = jnp.repeat(jnp.asarray(tids), blk) * tile + jnp.asarray(loc)
        rows = f_pad[g][:, :r]
        contrib = (contrib[:, :, None] * rows[:, None, :]).reshape(vals.shape[0], -1)
    return jax.ops.segment_sum(contrib, gi, num_segments=plan.out_rows)


def ttcore_ref(
    indices: jax.Array,
    values: jax.Array,
    cores: Sequence[jax.Array],
    mode: int,
    out_rows: int,
) -> jax.Array:
    """Sparse TT-ALS right-hand side: B[i_m, :] += v * kron(l, r), where l is
    the left interface chain over cores < mode and r the right chain over
    cores > mode, columns row-major over (rl_m, rr_m).  `cores` holds all N
    TT cores, shape (rl_k, I_k, rr_k); the mode-th is ignored.  Returns
    (out_rows, rl_m * rr_m)."""
    nnz = values.shape[0]
    left = jnp.ones((nnz, 1), jnp.float32)
    for k in range(mode):
        rows = jnp.transpose(cores[k], (1, 0, 2))[indices[:, k]]  # (nnz, rl, rr)
        left = jnp.einsum("za,zab->zb", left, rows.astype(jnp.float32))
    right = jnp.ones((nnz, 1), jnp.float32)
    for k in range(len(cores) - 1, mode, -1):
        rows = jnp.transpose(cores[k], (1, 0, 2))[indices[:, k]]
        right = jnp.einsum("zab,zb->za", rows.astype(jnp.float32), right)
    contrib = values[:, None].astype(jnp.float32) * (
        left[:, :, None] * right[:, None, :]
    ).reshape(nnz, -1)
    return jax.ops.segment_sum(contrib, indices[:, mode], num_segments=out_rows)


def ttcore_ref_dense(
    indices: np.ndarray,
    values: np.ndarray,
    cores: Sequence[np.ndarray],
    mode: int,
    out_rows: int,
) -> np.ndarray:
    """Densify-and-einsum cross-check for any order >= 3 (duplicate-
    accumulating, float64 internally): contracts the dense tensor with the
    left interface (modes < mode folded into an rl_m-wide matrix) and the
    right interface (modes > mode into rr_m wide), flattening (rl, rr)
    row-major."""
    nmodes = len(cores)
    assert nmodes <= 5, "dense oracle is for tiny cross-check shapes only"
    shape = tuple(int(c.shape[1]) for c in cores)
    dense = np.zeros(shape, np.float64)
    np.add.at(dense, tuple(indices[:, m] for m in range(nmodes)), values.astype(np.float64))
    # Left interface: rows of kron-chained left cores, (prod(shape[:mode]), rl_m).
    left = np.ones((1, 1), np.float64)
    for k in range(mode):
        left = np.einsum("pa,aib->pib", left, cores[k].astype(np.float64))
        left = left.reshape(-1, cores[k].shape[2])
    # Right interface: columns of kron-chained right cores, (rr_m, prod(shape[mode+1:])).
    right = np.ones((1, 1), np.float64)
    for k in range(nmodes - 1, mode, -1):
        right = np.einsum("aib,bq->aiq", cores[k].astype(np.float64), right)
        right = right.reshape(cores[k].shape[0], -1)
    d3 = dense.reshape(left.shape[0], shape[mode], right.shape[1])
    out = np.einsum("piq,pa,bq->iab", d3, left, right)
    return out.reshape(shape[mode], -1)[:out_rows].astype(np.float32)


def ttcore_plan_ref(
    plan,
    factors_padded: Sequence[jax.Array],
    in_rank_pairs: Sequence[tuple[int, int]],
    n_left: int,
) -> jax.Array:
    """Oracle on the kernel's BlockPlan layout: exactly what the Pallas
    TT-core kernel should produce, including padded rows (true columns only —
    the caller compares against out[:, :rl_m*rr_m]).  One lane-padded
    interface matrix per input mode, in plan.in_modes order."""
    blk = plan.blk
    vals = jnp.asarray(plan.vals)
    nnz = vals.shape[0]
    gi = jnp.repeat(jnp.asarray(plan.block_it), blk) * plan.tile_i + jnp.asarray(plan.iloc)
    rows3 = []
    for f_pad, tids, loc, tile, (rl, rr) in zip(
        factors_padded, plan.block_in, plan.in_locs, plan.in_tiles, in_rank_pairs
    ):
        g = jnp.repeat(jnp.asarray(tids), blk) * tile + jnp.asarray(loc)
        rows3.append(f_pad[g][:, : rl * rr].reshape(nnz, rl, rr))
    left = jnp.ones((nnz, 1), jnp.float32)
    for n in range(n_left):
        left = jnp.einsum("za,zab->zb", left, rows3[n])
    right = jnp.ones((nnz, 1), jnp.float32)
    for n in range(len(rows3) - 1, n_left - 1, -1):
        right = jnp.einsum("zab,zb->za", rows3[n], right)
    contrib = vals[:, None] * (left[:, :, None] * right[:, None, :]).reshape(nnz, -1)
    return jax.ops.segment_sum(contrib, gi, num_segments=plan.out_rows)


def mttkrp_plan_ref(plan, factors_padded: Sequence[jax.Array], rank_padded: int) -> jax.Array:
    """Oracle operating on the *kernel's* input layout (BlockPlan): computes
    exactly what the Pallas kernel should produce, including padded rows.
    N-mode: one padded factor per input mode, in plan.in_modes order.
    Returns (out_rows_padded, rank_padded)."""
    blk = plan.blk
    vals = jnp.asarray(plan.vals)
    gi = jnp.repeat(jnp.asarray(plan.block_it), blk) * plan.tile_i + jnp.asarray(plan.iloc)
    contrib = vals[:, None]
    for f_pad, tids, loc, tile in zip(
        factors_padded, plan.block_in, plan.in_locs, plan.in_tiles
    ):
        g = jnp.repeat(jnp.asarray(tids), blk) * tile + jnp.asarray(loc)
        contrib = contrib * f_pad[g]
    return jax.ops.segment_sum(contrib, gi, num_segments=plan.out_rows)
