"""Blocked sorted-COO MTTKRP Pallas kernel — the memory controller in silicon.

Engine mapping (DESIGN.md Sec. 2):
  * DMA Engine      — the non-zero stream arrives as (nblocks, blk) BlockSpec
                      tiles; Pallas double-buffers consecutive grid steps
                      (HBM->VMEM DMA overlap with compute).
  * Cache Engine    — factor tiles (tile_j x R_pad), (tile_k x R_pad) are
                      selected per block via scalar-prefetched tile ids; Pallas
                      skips the copy when the id repeats between consecutive
                      blocks, so the BlockPlan's run-length structure IS the
                      cache-hit behaviour. Random access happens as an in-VMEM
                      row gather.
  * Approach 1      — blocks are sorted by output tile (Tensor Remapper), so
                      the accumulator tile is resident across its whole run and
                      flushed to HBM exactly once (no DRAM partial sums).
  * MXU             — per-block segment accumulation is a one-hot matmul
                      (tile_i x blk) @ (blk x R_pad) on the systolic array.

Validated in interpret=True mode against kernels/ref.py (CPU container; TPU is
the target).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.remap import BlockPlan

__all__ = ["mttkrp_pallas_call", "pad_factor", "rank_padded"]


def rank_padded(rank: int) -> int:
    return max(128, ((rank + 127) // 128) * 128)


def pad_factor(f: jax.Array, rows: int, rp: int) -> jax.Array:
    """Zero-pad a factor matrix to (rows, rp); padded rows/lanes contribute 0."""
    out = jnp.zeros((rows, rp), f.dtype)
    return out.at[: f.shape[0], : f.shape[1]].set(f)


def _kernel(tile_i: int, it_ref, jt_ref, kt_ref, vals_ref, iloc_ref, jloc_ref, kloc_ref, b_ref, c_ref, out_ref):
    b = pl.program_id(0)
    # Approach-1 accumulator management: zero on the first block of each
    # output tile's contiguous run (Tensor Remapper guarantees contiguity).
    prev = jnp.maximum(b - 1, 0)
    first_visit = jnp.logical_or(b == 0, it_ref[b] != it_ref[prev])

    @pl.when(first_visit)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[0, :]  # (blk,)
    il = iloc_ref[0, :]
    jl = jloc_ref[0, :]
    kl = kloc_ref[0, :]

    # Cache Engine: random row access served from the VMEM-resident tiles.
    b_rows = jnp.take(b_ref[...], jl, axis=0)  # (blk, rp)
    c_rows = jnp.take(c_ref[...], kl, axis=0)
    contrib = (vals[:, None].astype(jnp.float32) * b_rows.astype(jnp.float32) * c_rows.astype(jnp.float32))

    # MXU segment accumulation: one-hot (tile_i, blk) @ contrib (blk, rp).
    rows = jax.lax.broadcasted_iota(jnp.int32, (tile_i, vals.shape[0]), 0)
    onehot = (rows == il[None, :]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(onehot, contrib, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("tile_i", "tile_j", "tile_k", "blk", "out_rows", "interpret"),
)
def mttkrp_pallas_call(
    block_it: jax.Array,  # (nblocks,) int32
    block_jt: jax.Array,
    block_kt: jax.Array,
    vals: jax.Array,  # (nblocks, blk)
    iloc: jax.Array,  # (nblocks, blk) int32
    jloc: jax.Array,
    kloc: jax.Array,
    b_pad: jax.Array,  # (rows_j, rp)
    c_pad: jax.Array,  # (rows_k, rp)
    *,
    tile_i: int,
    tile_j: int,
    tile_k: int,
    blk: int,
    out_rows: int,
    interpret: bool = False,
) -> jax.Array:
    nblocks = vals.shape[0]
    rp = b_pad.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, blk), lambda b, it, jt, kt: (b, 0)),  # vals (DMA stream)
            pl.BlockSpec((1, blk), lambda b, it, jt, kt: (b, 0)),  # iloc
            pl.BlockSpec((1, blk), lambda b, it, jt, kt: (b, 0)),  # jloc
            pl.BlockSpec((1, blk), lambda b, it, jt, kt: (b, 0)),  # kloc
            pl.BlockSpec((tile_j, rp), lambda b, it, jt, kt: (jt[b], 0)),  # B tile (cache)
            pl.BlockSpec((tile_k, rp), lambda b, it, jt, kt: (kt[b], 0)),  # C tile (cache)
        ],
        out_specs=pl.BlockSpec((tile_i, rp), lambda b, it, jt, kt: (it[b], 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile_i),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, rp), jnp.float32),
        interpret=interpret,
    )(block_it, block_jt, block_kt, vals, iloc, jloc, kloc, b_pad, c_pad)
