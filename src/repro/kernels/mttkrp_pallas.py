"""Blocked sorted-COO MTTKRP Pallas kernel — the memory controller in silicon.

Engine mapping (DESIGN.md Sec. 2):
  * DMA Engine      — the non-zero stream arrives as (nblocks, blk) BlockSpec
                      tiles; Pallas double-buffers consecutive grid steps
                      (HBM->VMEM DMA overlap with compute).
  * Cache Engine    — one (tile_n x R_pad) factor tile per *input* mode is
                      selected per block via scalar-prefetched tile ids; Pallas
                      skips the copy when the id repeats between consecutive
                      blocks, so the BlockPlan's run-length structure IS the
                      cache-hit behaviour. Random access happens as an in-VMEM
                      row gather.
  * Approach 1      — blocks are sorted by output tile (Tensor Remapper), so
                      the accumulator tile is resident across its whole run and
                      flushed to HBM exactly once (no DRAM partial sums).
  * MXU             — per-block segment accumulation is a one-hot matmul
                      (tile_i x blk) @ (blk x R_pad) on the systolic array.

The kernel body is template-unrolled over the number of input modes (N-1 for
an N-mode tensor): `_kernel(tile_i, n_in, ...)` multiplies one gathered row
set per input factor, so 3-, 4- and 5-mode tensors (paper Table 2) all run on
the same generator.

Validated in interpret=True mode against kernels/ref.py (CPU container; TPU is
the target).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.remap import BlockPlan

__all__ = ["mttkrp_pallas_call", "pad_factor", "rank_padded"]


def rank_padded(rank: int) -> int:
    return max(128, ((rank + 127) // 128) * 128)


def pad_factor(f: jax.Array, rows: int, rp: int) -> jax.Array:
    """Zero-pad a factor matrix to (rows, rp); padded rows/lanes contribute 0."""
    out = jnp.zeros((rows, rp), f.dtype)
    return out.at[: f.shape[0], : f.shape[1]].set(f)


def _kernel(tile_i: int, n_in: int, *refs):
    """Template-unrolled kernel body for N-1 = n_in input factor tiles.

    refs layout (after the grid-spec plumbing):
      [0]                    it_ref           scalar-prefetch: output tile ids
      [1 : 1+n_in]           input tile ids   (scalar-prefetch, unused in body)
      [1+n_in]               vals_ref         (1, blk)
      [2+n_in]               iloc_ref         (1, blk)
      [3+n_in : 3+2*n_in]    input local idx  (1, blk) each
      [3+2*n_in : 3+3*n_in]  factor tiles     (tile_n, rp) each
      [3+3*n_in]             out_ref          (tile_i, rp)
    """
    it_ref = refs[0]
    vals_ref = refs[1 + n_in]
    iloc_ref = refs[2 + n_in]
    loc_refs = refs[3 + n_in : 3 + 2 * n_in]
    fac_refs = refs[3 + 2 * n_in : 3 + 3 * n_in]
    out_ref = refs[3 + 3 * n_in]

    b = pl.program_id(0)
    # Approach-1 accumulator management: zero on the first block of each
    # output tile's contiguous run (Tensor Remapper guarantees contiguity).
    prev = jnp.maximum(b - 1, 0)
    first_visit = jnp.logical_or(b == 0, it_ref[b] != it_ref[prev])

    @pl.when(first_visit)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[0, :]  # (blk,)
    il = iloc_ref[0, :]

    # Cache Engine: random row access served from the VMEM-resident tiles,
    # one gather + Hadamard multiply per input mode.
    contrib = vals[:, None].astype(jnp.float32)
    for loc_ref, fac_ref in zip(loc_refs, fac_refs):
        rows = jnp.take(fac_ref[...], loc_ref[0, :], axis=0)  # (blk, rp)
        contrib = contrib * rows.astype(jnp.float32)

    # MXU segment accumulation: one-hot (tile_i, blk) @ contrib (blk, rp).
    rows = jax.lax.broadcasted_iota(jnp.int32, (tile_i, vals.shape[0]), 0)
    onehot = (rows == il[None, :]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(onehot, contrib, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("tile_i", "in_tiles", "blk", "out_rows", "interpret"),
)
def mttkrp_pallas_call(
    block_it: jax.Array,  # (nblocks,) int32
    block_in: Sequence[jax.Array],  # N-1 x (nblocks,) int32 input tile ids
    vals: jax.Array,  # (nblocks, blk)
    iloc: jax.Array,  # (nblocks, blk) int32
    in_locs: Sequence[jax.Array],  # N-1 x (nblocks, blk) int32
    factors_pad: Sequence[jax.Array],  # N-1 x (rows_n, rp), plan.in_modes order
    *,
    tile_i: int,
    in_tiles: tuple[int, ...],  # N-1 input tile sizes
    blk: int,
    out_rows: int,
    interpret: bool = False,
) -> jax.Array:
    block_in = tuple(block_in)
    in_locs = tuple(in_locs)
    factors_pad = tuple(factors_pad)
    n_in = len(in_tiles)
    assert len(block_in) == len(in_locs) == len(factors_pad) == n_in
    nblocks = vals.shape[0]
    rp = factors_pad[0].shape[1]

    def stream_spec():
        return pl.BlockSpec((1, blk), lambda b, it, *ts: (b, 0))

    def factor_spec(n):
        return pl.BlockSpec(
            (in_tiles[n], rp), lambda b, it, *ts, n=n: (ts[n][b], 0)
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1 + n_in,  # output tile ids + one stream per input
        grid=(nblocks,),
        in_specs=(
            [stream_spec()]  # vals (DMA stream)
            + [stream_spec()]  # iloc
            + [stream_spec() for _ in range(n_in)]  # input local indices
            + [factor_spec(n) for n in range(n_in)]  # factor tiles (cache)
        ),
        out_specs=pl.BlockSpec((tile_i, rp), lambda b, it, *ts: (it[b], 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile_i, n_in),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, rp), jnp.float32),
        interpret=interpret,
    )(block_it, *block_in, vals, iloc, *in_locs, *factors_pad)
