"""Blocked sorted-COO TTM-chain (TTMc) Pallas kernel — sparse Tucker on the
same programmable memory controller as MTTKRP.

The Tucker HOOI loop needs, per output mode n,

    Y_(n) = X_(n) (U^(m_{N-2}) (x) ... (x) U^(m_1)),   m_* = modes != n,

restricted to X's non-zeros: every nnz z contributes
value_z * kron(U^(m_1)[i_{m_1}, :], ..., U^(m_{N-2})[i_{m_{N-2}}, :]) to output
row i_n.  That is MTTKRP with the per-element Hadamard product replaced by a
Kronecker (outer) product of the gathered factor rows — the irregular memory
access pattern is IDENTICAL, so the kernel reuses the exact BlockPlan layout
(per-output-mode tile-id streams + local indices) the Tensor Remapper builds
for MTTKRP.  Engine mapping is unchanged (see kernels/mttkrp_pallas.py):

  * DMA Engine    — (nblocks, blk) BlockSpec stream tiles, double-buffered;
  * Cache Engine  — one (tile_n x Rp_n) factor tile per input mode, selected
                    by scalar-prefetched tile ids (copy skipped on repeats);
  * Approach 1    — blocks sorted by output tile: the (tile_i x Pp) core-slice
                    accumulator is resident across its run, flushed once;
  * MXU           — segment accumulation as a one-hot matmul
                    (tile_i x blk) @ (blk x Pp).

Differences from the MTTKRP kernel: each input factor keeps its OWN rank
R_m (lane-padded to rank_padded(R_m)); the kernel slices the true columns
before the Kronecker chain, and the output carries P = prod(R_m) columns
(lane-padded to cols_padded(P)) instead of R.

Validated in interpret=True mode against kernels/ref.py (CPU container; TPU
is the target).
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .mttkrp_pallas import rank_padded

__all__ = ["ttmc_pallas_call", "cols_padded", "kron_cols"]


def cols_padded(ncols: int) -> int:
    """Lane padding for the TTMc output: P = prod(in_ranks) columns padded to
    the 128-lane boundary (same rule as rank_padded — shared on purpose, the
    output tile is a core-tensor slice, not a factor)."""
    return rank_padded(ncols)


def kron_cols(in_ranks: Sequence[int]) -> int:
    """Number of true output columns: P = prod of the input-factor ranks."""
    return math.prod(int(r) for r in in_ranks)


def _kernel(tile_i: int, n_in: int, in_ranks: tuple[int, ...], *refs):
    """Template-unrolled kernel body for n_in input factor tiles.

    refs layout is identical to the MTTKRP kernel (the plan layout is shared):
      [0]                    it_ref           scalar-prefetch: output tile ids
      [1 : 1+n_in]           input tile ids   (scalar-prefetch, unused in body)
      [1+n_in]               vals_ref         (1, blk)
      [2+n_in]               iloc_ref         (1, blk)
      [3+n_in : 3+2*n_in]    input local idx  (1, blk) each
      [3+2*n_in : 3+3*n_in]  factor tiles     (tile_n, Rp_n) each
      [3+3*n_in]             out_ref          (tile_i, Pp)
    """
    it_ref = refs[0]
    vals_ref = refs[1 + n_in]
    iloc_ref = refs[2 + n_in]
    loc_refs = refs[3 + n_in : 3 + 2 * n_in]
    fac_refs = refs[3 + 2 * n_in : 3 + 3 * n_in]
    out_ref = refs[3 + 3 * n_in]

    b = pl.program_id(0)
    # Approach-1 accumulator management: zero on the first block of each
    # output tile's contiguous run (Tensor Remapper guarantees contiguity).
    prev = jnp.maximum(b - 1, 0)
    first_visit = jnp.logical_or(b == 0, it_ref[b] != it_ref[prev])

    @pl.when(first_visit)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = vals_ref[0, :]  # (blk,)
    il = iloc_ref[0, :]
    blk = vals.shape[0]

    # Cache Engine gather + Kronecker chain: contrib grows from (blk, 1) to
    # (blk, prod(in_ranks)) one input mode at a time; each gathered row set is
    # sliced to its true rank so lane padding never enters the product.
    contrib = vals[:, None].astype(jnp.float32)
    for loc_ref, fac_ref, r in zip(loc_refs, fac_refs, in_ranks):
        rows = jnp.take(fac_ref[...], loc_ref[0, :], axis=0)  # (blk, Rp_n)
        rows = rows[:, :r].astype(jnp.float32)
        contrib = (contrib[:, :, None] * rows[:, None, :]).reshape(blk, -1)

    # Zero-pad the true P columns up to the output tile's lane width.
    pp = out_ref.shape[1]
    if contrib.shape[1] < pp:
        contrib = jnp.concatenate(
            [contrib, jnp.zeros((blk, pp - contrib.shape[1]), jnp.float32)], axis=1
        )

    # MXU segment accumulation: one-hot (tile_i, blk) @ contrib (blk, Pp).
    rows_iota = jax.lax.broadcasted_iota(jnp.int32, (tile_i, blk), 0)
    onehot = (rows_iota == il[None, :]).astype(jnp.float32)
    out_ref[...] += jax.lax.dot(onehot, contrib, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("tile_i", "in_tiles", "in_ranks", "blk", "out_rows", "interpret"),
)
def ttmc_pallas_call(
    block_it: jax.Array,  # (nblocks,) int32
    block_in: Sequence[jax.Array],  # N-1 x (nblocks,) int32 input tile ids
    vals: jax.Array,  # (nblocks, blk)
    iloc: jax.Array,  # (nblocks, blk) int32
    in_locs: Sequence[jax.Array],  # N-1 x (nblocks, blk) int32
    factors_pad: Sequence[jax.Array],  # N-1 x (rows_n, Rp_n), plan.in_modes order
    *,
    tile_i: int,
    in_tiles: tuple[int, ...],  # N-1 input tile sizes
    in_ranks: tuple[int, ...],  # N-1 true input-factor ranks
    blk: int,
    out_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """Returns (out_rows, cols_padded(prod(in_ranks))) float32: the mode-n
    TTMc unfolding with row-major column order over plan.in_modes."""
    block_in = tuple(block_in)
    in_locs = tuple(in_locs)
    factors_pad = tuple(factors_pad)
    in_ranks = tuple(int(r) for r in in_ranks)
    n_in = len(in_tiles)
    assert len(block_in) == len(in_locs) == len(factors_pad) == n_in
    assert len(in_ranks) == n_in
    nblocks = vals.shape[0]
    pp = cols_padded(kron_cols(in_ranks))

    def stream_spec():
        return pl.BlockSpec((1, blk), lambda b, it, *ts: (b, 0))

    def factor_spec(n):
        return pl.BlockSpec(
            (in_tiles[n], factors_pad[n].shape[1]),
            lambda b, it, *ts, n=n: (ts[n][b], 0),
        )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1 + n_in,  # output tile ids + one stream per input
        grid=(nblocks,),
        in_specs=(
            [stream_spec()]  # vals (DMA stream)
            + [stream_spec()]  # iloc
            + [stream_spec() for _ in range(n_in)]  # input local indices
            + [factor_spec(n) for n in range(n_in)]  # factor tiles (cache)
        ),
        out_specs=pl.BlockSpec((tile_i, pp), lambda b, it, *ts: (it[b], 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, tile_i, n_in, in_ranks),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, pp), jnp.float32),
        interpret=interpret,
    )(block_it, *block_in, vals, iloc, *in_locs, *factors_pad)
