"""Test-support utilities shipped with the package (not test-only code in
`tests/`): the fault-injection harness `repro.testing.faults` proves every
guard of the resilience layer fires and every policy recovers."""
from . import faults  # noqa: F401

__all__ = ["faults"]
