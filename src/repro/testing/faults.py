"""Fault-injection harness for the resilience layer (repro.resilience).

Each injector plants exactly one of the failure modes the guards exist to
catch, so the tests can assert detection AND recovery:

  * `inject_nan_factor`   — a factor goes non-finite after iteration k
                            (caught by the fit guard next iteration, or by
                            the factor-cadence check);
  * `corrupt_plan`        — a BlockPlan with an out-of-tile-bounds local
                            index (caught by `validate_plan`);
  * `shrunk_budget`       — an HBM budget just below a workspace's footprint
                            (forces the admission ladder to step down);
  * `deaden_shard`        — one shard's remapped values zero out mid-run in
                            the sharded sweep (caught by the fit-regression
                            guard: the model silently loses that shard's
                            contribution);
  * `kill_at`             — hard process death before iteration k (the
                            checkpoint/resume story, run under a subprocess).

The iteration-indexed injectors are ONE-SHOT: they fire once and disarm.
That is load-bearing for the recovery tests — a restart replays iterations
from 0, and a fault that re-fired every attempt would exhaust any
`max_restarts` budget.

All of them wrap `ws._sweep_call` as an instance attribute, which the drive
loop binds at entry; the "fallback" policy rebinds to the reference sweep
and thereby sheds the wrapper — exactly the semantics a mid-run hardware
degradation would have.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax.numpy as jnp
import numpy as np

__all__ = [
    "inject_nan_factor",
    "corrupt_plan",
    "shrunk_budget",
    "deaden_shard",
    "kill_at",
]


def inject_nan_factor(ws: Any, *, at_iter: int, mode: int | None = None) -> Any:
    """Arm `ws` so the sweep of iteration `at_iter` returns factors with
    `facs[mode]` poisoned to NaN — the canonical numerical blow-up.  The fit
    of iteration `at_iter` itself stays finite (the poison lands after the
    sweep), so detection happens on the NEXT iteration's fit (free guard) or
    on the factor-cadence check of iteration `at_iter`.  One-shot.

    `mode` defaults to the LAST mode: ALS-style loops update mode 0 first
    *from the other factors*, so a poisoned mode-0 factor would simply be
    solved away before anything reads it; poison in any later mode flows
    into the mode-0 update and cascades through the whole sweep."""
    tgt = (len(ws.shape) - 1) if mode is None else mode
    inner = ws._sweep_call
    state = {"fired": False}

    def wrapped(facs, *args, it: int):
        facs, aux, fit = inner(facs, *args, it=it)
        if it == at_iter and not state["fired"]:
            state["fired"] = True
            facs = list(facs)
            facs[tgt] = facs[tgt] * jnp.nan
            facs = tuple(facs)
        return facs, aux, fit

    ws._sweep_call = wrapped
    return ws


def corrupt_plan(plan: Any) -> Any:
    """A copy of `plan` whose first local output index is out of tile bounds
    (`iloc[0] == tile_i`) — the corruption `validate_plan` must catch.  The
    original plan is untouched."""
    iloc = np.array(plan.iloc, copy=True)
    if iloc.size == 0:
        raise ValueError("cannot corrupt an empty plan")
    iloc[0] = plan.tile_i  # one past the last valid in-tile row
    return dataclasses.replace(plan, iloc=iloc)


def shrunk_budget(ws: Any, fraction: float = 0.5) -> int:
    """An HBM budget strictly below `ws`'s resident footprint (`fraction` of
    it, at least one byte short) — guarantees the admission check rejects
    the workspace as built."""
    from ..resilience import admission_bytes

    total = admission_bytes(ws)["total_bytes"]
    return min(int(total * fraction), total - 1)


def deaden_shard(ws: Any, *, shard: int, at_iter: int) -> Any:
    """Arm a SHARDED workspace so shard `shard`'s remapped values zero out
    after iteration `at_iter` — a silently dead device: every later sweep
    loses that shard's contribution to the psum'd factor rows while the fit
    is still measured against the full tensor, so the fit degrades and the
    regression guard fires.  One-shot (the stacks stay dead afterwards —
    restarting cannot resurrect a dead shard, so pair this with
    policy='raise')."""
    if not hasattr(ws, "stacks"):
        raise ValueError("deaden_shard needs a ShardedWorkspace (no .stacks)")
    inner = ws._sweep_call
    state = {"fired": False}

    def wrapped(facs, *args, it: int):
        out = inner(facs, *args, it=it)
        if it == at_iter and not state["fired"]:
            state["fired"] = True
            for stack in ws.stacks.values():
                stack.vals = stack.vals.at[shard].set(0.0)
        return out

    ws._sweep_call = wrapped
    return ws


def kill_at(ws: Any, *, at_iter: int, exit_code: int = 17) -> Any:
    """Arm `ws` so the process dies hard (os._exit — no atexit, no cleanup)
    BEFORE the sweep of iteration `at_iter` runs: checkpoints written through
    iteration `at_iter - 1` survive, nothing later exists.  For subprocess
    checkpoint/resume tests only."""
    inner = ws._sweep_call

    def wrapped(facs, *args, it: int):
        if it == at_iter:
            os._exit(exit_code)
        return inner(facs, *args, it=it)

    ws._sweep_call = wrapped
    return ws
