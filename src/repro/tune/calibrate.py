"""Measured-roofline PMS calibration: fit a `TPUSpec` to this machine.

The PMS (core/pms.py) prices every candidate controller configuration with
two hardware constants — `hbm_bw` and `peak_flops_f32` — that ship as TPU
v5e datasheet guesses.  PR 8's `obs.calibrate` join made the resulting
mispredictions visible (`achieved_pct` of ~1e-3 % on CPU interpret-mode
Pallas); this module closes the loop the way the paper's PMS intends: run
microbenchmarks once per backend, fit the constants from measured sweep
timings, persist the fitted spec (`repro.tune.cache`), and let
`pms.search(spec="measured")` search with numbers the machine actually
achieves.

Two measurement layers, combined by `calibrate()`:

  * **Microbenchmarks** (`benchmarks/roofline.py`-style): a jitted
    streaming-copy kernel for raw memory bandwidth and a jitted
    segment-matmul — shaped like the Pallas kernel's one-hot
    `(tile_i, blk) @ (blk, R_pad)` MXU step — for raw f32 FLOP/s.  These
    bound what the backend can do, and serve as the fallback when the
    least-squares fit is degenerate.
  * **Block-sweep fit**: run the planned CP-ALS sweep at several controller
    configurations, read each workspace's *exact* per-plan byte and FLOP
    counts off the PMS itself (a unit-constant `TPUSpec` turns
    `pms_estimates()` into a byte/FLOP counter), and least-squares fit
    ``t_measured ≈ bytes / hbm_bw + flops / peak_flops_f32``.  The fitted
    constants are *effective* rates — they absorb whatever per-block
    overhead the execution path has (the CPU interpreter, most visibly) —
    which is exactly what makes the PMS's predictions land near measured
    wall-clock.

Validation rides PR 8's join: `calibrate()` re-prices every measured sample
through `obs.calibrate.CalibrationRow` under both the default and the fitted
spec, so the result carries its own achieved_pct evidence
(`CalibrationResult.validation`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from ..core.memctrl import (
    CacheEngineConfig,
    DMAEngineConfig,
    MemoryControllerConfig,
    TPUSpec,
)
from ..obs import trace as _trace
from .cache import AutotuneCache, current_backend, default_cache

__all__ = [
    "CalibSample",
    "CalibrationResult",
    "DEFAULT_CALIBRATION_CFGS",
    "measure_hbm_bw",
    "measure_peak_flops_f32",
    "roofline_counts",
    "sweep_sample",
    "fit_spec",
    "predicted_seconds",
    "calibrate",
    "calibrate_and_store",
    "resolve_spec",
]

#: The unit-constant spec that turns the PMS predictors into byte/FLOP
#: counters: with hbm_bw == peak_flops_f32 == 1, `t_mem` IS the byte count
#: and `t_compute` IS the FLOP count.
_UNIT_SPEC = TPUSpec(hbm_bw=1.0, peak_flops_f32=1.0)

#: Controller configurations the block-sweep fit runs at.  tile_i varies the
#: FLOP/byte ratio (the segment-matmul term scales with the output tile, the
#: stream term does not), blk varies the block count — together they give the
#: least-squares system two well-separated columns.
DEFAULT_CALIBRATION_CFGS: tuple[MemoryControllerConfig, ...] = (
    MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=128, tile_j=128, tile_k=128),
        dma=DMAEngineConfig(blk=128),
    ),
    MemoryControllerConfig(),  # the 256-cube default
    MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=512, tile_j=512, tile_k=512),
        dma=DMAEngineConfig(blk=512),
    ),
)


# ---------------------------------------------------------------------------
# Microbenchmarks
# ---------------------------------------------------------------------------


def measure_hbm_bw(nbytes: int = 1 << 26, reps: int = 3) -> float:
    """Raw streaming bandwidth (bytes/s) of the default backend: a jitted
    elementwise copy-scale over an `nbytes` f32 buffer (one read + one write
    per element), best of `reps` timed calls after a compile warmup."""
    import jax
    import jax.numpy as jnp

    n = max(1, nbytes // 4)
    x = jnp.ones((n,), jnp.float32)
    stream = jax.jit(lambda a: a * 1.0001 + 1.0)
    jax.block_until_ready(stream(x))  # compile
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(stream(x))
        best = min(best, time.perf_counter() - t0)
    return (2 * 4 * n) / best


def measure_peak_flops_f32(
    tile: int = 512, blk: int = 2048, lanes: int = 512, reps: int = 3
) -> float:
    """Raw f32 FLOP/s of the default backend via a jitted segment-matmul
    shaped like the kernel's MXU step — a `(tile, blk) @ (blk, lanes)`
    product (2*tile*blk*lanes FLOPs), best of `reps` after warmup."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (tile, blk), jnp.float32)
    b = jax.random.normal(key, (blk, lanes), jnp.float32)
    mm = jax.jit(lambda x, y: x @ y)
    jax.block_until_ready(mm(a, b))  # compile
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(mm(a, b))
        best = min(best, time.perf_counter() - t0)
    return (2.0 * tile * blk * lanes) / best


# ---------------------------------------------------------------------------
# Block-sweep samples
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CalibSample:
    """One measured sweep at one controller configuration: the exact PMS
    byte/FLOP counts of the built workspace (per output mode, so the
    max-form roofline can be re-priced under any spec) plus the measured
    steady-state seconds per sweep."""

    label: str
    per_mode: tuple[tuple[float, float], ...]  # (mem_bytes, flops) per mode
    measured_s: float

    @property
    def mem_bytes(self) -> float:
        return float(sum(b for b, _ in self.per_mode))

    @property
    def flops(self) -> float:
        return float(sum(f for _, f in self.per_mode))


def roofline_counts(ws) -> tuple[tuple[float, float], ...]:
    """Exact (mem_bytes, flops) per output mode of a planned workspace, read
    off the PMS predictors with the unit-constant spec (measured fills and
    padding, not the analytic occupancy model)."""
    ests = ws.pms_estimates(_UNIT_SPEC)
    return tuple(
        (float(ests[m].t_mem), float(ests[m].t_compute)) for m in sorted(ests)
    )


def predicted_seconds(
    per_mode: Sequence[tuple[float, float]], spec: TPUSpec
) -> float:
    """Re-price stored byte/FLOP counts under a spec with the PMS's max-form
    roofline (per-mode max(t_mem, t_compute), summed over the sweep)."""
    return float(
        sum(max(b / spec.hbm_bw, f / spec.peak_flops_f32) for b, f in per_mode)
    )


def _cfg_label(cfg: MemoryControllerConfig) -> str:
    c, d = cfg.cache, cfg.dma
    return f"tiles=({c.tile_i},{c.tile_j},{c.tile_k}),blk={d.blk}"


def sweep_sample(
    st, rank: int, cfg: MemoryControllerConfig, *, reps: int = 2,
    interpret: bool = True, seed: int = 0,
) -> CalibSample:
    """Build the planned CP-ALS workspace at `cfg`, time its steady-state
    jitted sweep (one compile + one warm call, then best of `reps`), and
    pair the measurement with the workspace's exact byte/FLOP counts."""
    import jax
    import jax.numpy as jnp

    from ..core.coo import random_factors
    from ..kernels.ops import make_planned_cp_als

    ws = make_planned_cp_als(st, rank, cfg=cfg, interpret=interpret)
    per_mode = roofline_counts(ws)
    facs = ws.pad_factors(random_factors(jax.random.PRNGKey(seed), st.shape, rank))
    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    nxs = jnp.asarray(float(np.sum(st.values.astype(np.float64) ** 2)), jnp.float32)
    facs, lam, fit = ws.sweep(facs, idx, val, nxs, first=True)  # compile
    facs, lam, fit = ws.sweep(facs, idx, val, nxs, first=False)  # steady compile
    jax.block_until_ready(fit)
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        facs, lam, fit = ws.sweep(facs, idx, val, nxs, first=False)
        jax.block_until_ready(fit)
        best = min(best, time.perf_counter() - t0)
    return CalibSample(label=_cfg_label(cfg), per_mode=per_mode, measured_s=best)


# ---------------------------------------------------------------------------
# Least-squares fit
# ---------------------------------------------------------------------------


def fit_spec(
    samples: Sequence[CalibSample],
    base: TPUSpec = TPUSpec(),
    *,
    fallback_hbm_bw: float | None = None,
    fallback_peak_flops: float | None = None,
) -> TPUSpec:
    """Least-squares fit of (hbm_bw, peak_flops_f32) from measured sweeps.

    Solves ``t_i ≈ bytes_i * x0 + flops_i * x1`` for x = (1/hbm_bw,
    1/peak_flops_f32) over the samples' total byte/FLOP counts.  The sum
    form is the fit model (it upper-bounds the PMS's max-form roofline and
    keeps the system linear); the fitted constants are then used inside the
    unchanged max-form predictors.  If a coefficient comes back
    non-positive (collinear samples, or one term measurement-noise small),
    that constant falls back to the microbenchmark value (or `base`'s) and
    the other is refit alone.  `peak_flops` (bf16) keeps `base`'s
    f32-to-bf16 ratio.  Raises ValueError on an empty sample list."""
    if not samples:
        raise ValueError("fit_spec needs at least one calibration sample")
    B = np.array([s.mem_bytes for s in samples], dtype=np.float64)
    F = np.array([s.flops for s in samples], dtype=np.float64)
    t = np.array([s.measured_s for s in samples], dtype=np.float64)
    if np.any(t <= 0):
        raise ValueError("calibration samples must have measured_s > 0")
    A = np.stack([B, F], axis=1)
    x, *_ = np.linalg.lstsq(A, t, rcond=None)
    inv_bw, inv_pf = float(x[0]), float(x[1])
    if inv_bw <= 0 and inv_pf <= 0:
        # Degenerate system: keep the fallbacks for both.
        inv_bw = 1.0 / (fallback_hbm_bw or base.hbm_bw)
        inv_pf = 1.0 / (fallback_peak_flops or base.peak_flops_f32)
    elif inv_pf <= 0:
        inv_pf = 1.0 / (fallback_peak_flops or base.peak_flops_f32)
        inv_bw = float(np.dot(B, t - F * inv_pf) / np.dot(B, B))
        inv_bw = max(inv_bw, np.finfo(np.float64).tiny)
    elif inv_bw <= 0:
        inv_bw = 1.0 / (fallback_hbm_bw or base.hbm_bw)
        inv_pf = float(np.dot(F, t - B * inv_bw) / np.dot(F, F))
        inv_pf = max(inv_pf, np.finfo(np.float64).tiny)
    bf16_ratio = base.peak_flops / base.peak_flops_f32
    fitted_f32 = 1.0 / inv_pf
    return dataclasses.replace(
        base,
        hbm_bw=1.0 / inv_bw,
        peak_flops_f32=fitted_f32,
        peak_flops=fitted_f32 * bf16_ratio,
    )


# ---------------------------------------------------------------------------
# The end-to-end calibration workflow
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Everything one calibration run learned: the fitted spec, the raw
    measurements behind it, the microbenchmark peaks, and the
    `obs.calibrate` validation rows (achieved_pct under the default vs the
    fitted spec, per sample)."""

    spec: TPUSpec
    backend: str
    samples: tuple[CalibSample, ...]
    stream_hbm_bw: float | None
    matmul_peak_flops_f32: float | None
    validation: tuple[dict, ...]

    @property
    def residual_rel(self) -> float:
        """Mean relative error of the fitted sum-form model over the
        calibration samples (the fit's own goodness measure)."""
        errs = []
        for s in self.samples:
            pred = s.mem_bytes / self.spec.hbm_bw + s.flops / self.spec.peak_flops_f32
            errs.append(abs(pred - s.measured_s) / s.measured_s)
        return float(np.mean(errs)) if errs else float("nan")


def _validation_rows(
    samples: Sequence[CalibSample], fitted: TPUSpec, base: TPUSpec, preset: str
) -> tuple[dict, ...]:
    """Re-price every sample through PR 8's join (`obs.calibrate`
    CalibrationRow) under the default and the fitted spec."""
    from ..obs.calibrate import CalibrationRow

    rows = []
    for s in samples:
        default = CalibrationRow(
            format="cp", preset=preset,
            predicted_s=predicted_seconds(s.per_mode, base),
            measured_s=s.measured_s,
        )
        measured = CalibrationRow(
            format="cp", preset=preset,
            predicted_s=predicted_seconds(s.per_mode, fitted),
            measured_s=s.measured_s,
        )
        rows.append({
            "label": s.label,
            "measured_s": s.measured_s,
            "achieved_pct_default": default.achieved_pct,
            "achieved_pct_measured": measured.achieved_pct,
        })
    return tuple(rows)


def calibrate(
    preset: str = "tiny",
    *,
    rank: int = 8,
    cfgs: Sequence[MemoryControllerConfig] = DEFAULT_CALIBRATION_CFGS,
    reps: int = 2,
    base: TPUSpec = TPUSpec(),
    microbench: bool = True,
    interpret: bool = True,
    seed: int = 0,
) -> CalibrationResult:
    """Run the full calibration workflow on the default backend: (optional)
    microbenchmarks, one block-sweep sample per configuration in `cfgs`, the
    least-squares fit, and the `obs.calibrate` validation join.  Does not
    touch the on-disk cache — `calibrate_and_store` persists."""
    from ..core.coo import frostt_like

    backend = current_backend()
    with _trace.span("tune_calibrate", backend=backend, preset=preset):
        bw = measure_hbm_bw() if microbench else None
        pf = measure_peak_flops_f32() if microbench else None
        st = frostt_like(preset)
        samples = tuple(
            sweep_sample(st, rank, cfg, reps=reps, interpret=interpret, seed=seed)
            for cfg in cfgs
        )
        fitted = fit_spec(
            samples, base, fallback_hbm_bw=bw, fallback_peak_flops=pf
        )
        return CalibrationResult(
            spec=fitted,
            backend=backend,
            samples=samples,
            stream_hbm_bw=bw,
            matmul_peak_flops_f32=pf,
            validation=_validation_rows(samples, fitted, base, preset),
        )


#: Smaller workload for the implicit `spec="measured"` cache-miss path: one
#: rep, two configurations, no medium sweeps — seconds, not minutes.
QUICK_CALIBRATION_KWARGS = dict(
    preset="tiny", rank=8, cfgs=DEFAULT_CALIBRATION_CFGS[:2], reps=1
)


def calibrate_and_store(
    *, cache: AutotuneCache | None = None, **kwargs
) -> CalibrationResult:
    """`calibrate()` + persist the fitted spec for this backend in the
    autotune cache (so `pms.search(spec="measured")` finds it)."""
    cache = cache if cache is not None else default_cache()
    result = calibrate(**kwargs)
    cache.put_spec(
        result.backend,
        result.spec,
        fitted_at=time.time(),
        residual_rel=result.residual_rel,
        stream_hbm_bw=result.stream_hbm_bw,
        matmul_peak_flops_f32=result.matmul_peak_flops_f32,
        n_samples=len(result.samples),
    )
    return result


def resolve_spec(
    spec, *, cache: AutotuneCache | None = None, calibrate_on_miss: bool = True
):
    """Resolve the `spec=` argument every PMS entry point accepts:

      * a `TPUSpec` passes through;
      * ``"default"`` is the datasheet `TPUSpec()`;
      * ``"measured"`` is this backend's fitted spec from the autotune
        cache — on a cache miss, a quick calibration runs and persists
        (`QUICK_CALIBRATION_KWARGS`) when `calibrate_on_miss` is set,
        otherwise ValueError.
    """
    if isinstance(spec, TPUSpec):
        return spec
    if spec == "default":
        return TPUSpec()
    if spec != "measured":
        raise ValueError(
            f"unknown spec {spec!r}: expected a TPUSpec, 'default' or 'measured'"
        )
    cache = cache if cache is not None else default_cache()
    found = cache.get_spec(current_backend())
    if found is not None:
        return found
    if not calibrate_on_miss:
        raise ValueError(
            f"no fitted spec for backend {current_backend()!r} in "
            f"{cache.path}; run repro.tune.calibrate_and_store() (or "
            f"scripts/calibrate.py) first"
        )
    return calibrate_and_store(cache=cache, **QUICK_CALIBRATION_KWARGS).spec
