"""repro.tune — measured-roofline calibration + persistent autotune cache.

`calibrate` fits a `TPUSpec` to the running backend from microbenchmarks and
block-sweep timings; `cache` persists the fitted spec and winning PMS
configurations across processes.  `pms.search(spec="measured")` and
`decompose(..., auto_tune="cached")` are the two consumer entry points.
"""
from .cache import (
    SCHEMA_VERSION,
    AutotuneCache,
    cache_dir,
    cache_path,
    cached_config,
    config_key,
    current_backend,
    default_cache,
    spec_fingerprint,
)
from .calibrate import (
    DEFAULT_CALIBRATION_CFGS,
    CalibSample,
    CalibrationResult,
    calibrate,
    calibrate_and_store,
    fit_spec,
    measure_hbm_bw,
    measure_peak_flops_f32,
    predicted_seconds,
    resolve_spec,
    roofline_counts,
    sweep_sample,
)

__all__ = [
    "SCHEMA_VERSION",
    "AutotuneCache",
    "cache_dir",
    "cache_path",
    "cached_config",
    "config_key",
    "current_backend",
    "default_cache",
    "spec_fingerprint",
    "DEFAULT_CALIBRATION_CFGS",
    "CalibSample",
    "CalibrationResult",
    "calibrate",
    "calibrate_and_store",
    "fit_spec",
    "measure_hbm_bw",
    "measure_peak_flops_f32",
    "predicted_seconds",
    "resolve_spec",
    "roofline_counts",
    "sweep_sample",
]
