"""Persistent autotune cache: fitted TPUSpecs + winning PMS configurations.

The PMS (core/pms.py) re-searches the controller design space on every
`auto_tune=True` call, and its `TPUSpec` constants are compile-time guesses —
both costs repeat per process even though neither the tensor nor the machine
changed.  This module persists the two things worth keeping (the same idiom
as the XLA compilation cache the maxtext exemplar warms):

  * one fitted `TPUSpec` per backend (`repro.tune.calibrate` writes it;
    `pms.search(spec="measured")` reads it), and
  * the winning `search()` / `search_sharded()` configuration per
    (kernel kind, tensor fingerprint, mode, rank payload, backend, spec,
    shard count) — `decompose(..., auto_tune="cached")` reads it, so a warm
    cache skips the config sweep entirely.

Storage is one JSON file, `autotune.json`, under `$REPRO_AUTOTUNE_DIR` (or
`~/.cache/repro-autotune/`).  Robustness contract (tests/test_tune.py):

  * writes are atomic (same-directory temp file + `os.replace`), so
    concurrent writers can interleave but the file is always valid JSON —
    last writer wins per entry, nothing ever reads a half-written file;
  * a truncated/corrupt file, an unknown `schema_version`, or an entry whose
    fields this code version does not know all degrade to a clean miss
    (re-search / re-calibrate), never a crash;
  * the schema version is bumped whenever the key derivation or the stored
    payloads change meaning, invalidating every older file at once.

Hits and misses are counted in `repro.obs.metrics`
(``autotune_cache.{hits,misses,spec_hits,spec_misses}``) and mirrored as
trace events, so the parity tests can assert "zero search configs evaluated
on a warm hit" straight off the metrics snapshot.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
from pathlib import Path
from typing import Any

from ..core.memctrl import (
    MemoryControllerConfig,
    TPUSpec,
    config_from_dict,
    config_to_dict,
    spec_from_dict,
    spec_to_dict,
)
from ..obs import metrics as _metrics
from ..obs import trace as _trace

__all__ = [
    "SCHEMA_VERSION",
    "AutotuneCache",
    "cache_dir",
    "cache_path",
    "default_cache",
    "spec_fingerprint",
    "config_key",
    "cached_config",
    "current_backend",
]

#: Bump whenever the key derivation or stored payload semantics change: an
#: older on-disk file is then treated as empty (clean re-search), never
#: misread.
SCHEMA_VERSION = 1

_FILE_NAME = "autotune.json"
_ENV_DIR = "REPRO_AUTOTUNE_DIR"

# Serializes read-modify-write cycles *within* this process; cross-process
# safety comes from the atomic rename (last writer wins, file always valid).
_WRITE_LOCK = threading.Lock()


def cache_dir() -> Path:
    """Cache directory: `$REPRO_AUTOTUNE_DIR`, else `~/.cache/repro-autotune`.
    Resolved at call time so tests can re-point it via the environment."""
    env = os.environ.get(_ENV_DIR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-autotune"


def cache_path() -> Path:
    return cache_dir() / _FILE_NAME


def current_backend() -> str:
    """The jax backend this process tunes for ('cpu' / 'gpu' / 'tpu') — part
    of every cache key: a config tuned on one backend must never be served
    on another."""
    import jax

    return str(jax.default_backend())


def spec_fingerprint(spec: TPUSpec) -> str:
    """Short content hash of a TPUSpec — ties a cached winning configuration
    to the exact spec the search ran under (a recalibration that moves the
    constants must invalidate stale winners)."""
    payload = json.dumps(spec_to_dict(spec), sort_keys=True)
    return hashlib.sha1(payload.encode()).hexdigest()[:12]


def config_key(
    kind: str,
    fingerprint: str,
    mode: int,
    rank_key: Any,
    *,
    backend: str,
    spec: TPUSpec,
    nshards: int | None = None,
) -> str:
    """The winning-config cache key.  Collision contract: any two searches
    that could return different winners must map to different keys — hence
    kernel kind, tensor content fingerprint, output mode, the kernel's rank
    payload (CP rank int / TTMc in-rank tuple / TT bond-pair tuple), the
    backend, the spec fingerprint, and the shard count (None for the
    single-device search) all appear verbatim."""
    shard = "single" if nshards is None else f"shards{int(nshards)}"
    return (
        f"v{SCHEMA_VERSION}|{kind}|{fingerprint}|mode={int(mode)}"
        f"|rank={rank_key!r}|backend={backend}|spec={spec_fingerprint(spec)}"
        f"|{shard}"
    )


class AutotuneCache:
    """One on-disk autotune cache file (see module docstring for the
    robustness contract).  All methods are safe to call with no file, a
    corrupt file, or a file written by a different schema version."""

    def __init__(self, path: str | Path | None = None):
        self._explicit_path = Path(path) if path is not None else None

    @property
    def path(self) -> Path:
        return self._explicit_path if self._explicit_path is not None else cache_path()

    # -- load / store ------------------------------------------------------

    def _empty(self) -> dict:
        return {"schema_version": SCHEMA_VERSION, "specs": {}, "configs": {}}

    def load(self) -> dict:
        """The parsed cache contents, degraded to empty on any defect:
        missing file, unreadable bytes, invalid JSON, non-dict payload, or a
        schema_version this code does not speak."""
        try:
            raw = self.path.read_text()
        except (OSError, ValueError):
            return self._empty()
        try:
            data = json.loads(raw)
        except (json.JSONDecodeError, ValueError):
            return self._empty()
        if not isinstance(data, dict) or data.get("schema_version") != SCHEMA_VERSION:
            return self._empty()
        if not isinstance(data.get("specs"), dict) or not isinstance(
            data.get("configs"), dict
        ):
            return self._empty()
        return data

    def _write(self, data: dict) -> None:
        """Atomic replace: serialize, write to a same-directory temp file,
        fsync, rename.  A concurrent writer racing this one leaves the file
        as one writer's complete output — never a mix, never a truncation."""
        path = self.path
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = json.dumps(data, indent=1, sort_keys=True)
        fd, tmp = tempfile.mkstemp(
            prefix=path.name + ".", suffix=".tmp", dir=str(path.parent)
        )
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _update(self, mutate) -> None:
        """One read-modify-write cycle under the in-process lock."""
        with _WRITE_LOCK:
            data = self.load()
            mutate(data)
            self._write(data)

    def clear(self) -> None:
        """Drop every entry (writes an empty file atomically)."""
        self._update(lambda data: (data["specs"].clear(), data["configs"].clear()))

    # -- fitted specs ------------------------------------------------------

    def get_spec(self, backend: str) -> TPUSpec | None:
        """The fitted TPUSpec for `backend`, or None (miss on absence or on
        any entry this schema cannot rebuild)."""
        entry = self.load()["specs"].get(backend)
        if not isinstance(entry, dict):
            self._count("spec_misses", backend=backend)
            return None
        try:
            spec = spec_from_dict(entry.get("spec", {}))
        except (ValueError, TypeError):
            self._count("spec_misses", backend=backend)
            return None
        self._count("spec_hits", backend=backend)
        return spec

    def put_spec(self, backend: str, spec: TPUSpec, **meta) -> None:
        def mutate(data):
            data["specs"][backend] = {"spec": spec_to_dict(spec), "meta": meta}

        self._update(mutate)
        _trace.event("autotune_spec_store", backend=backend)

    # -- winning configurations -------------------------------------------

    def get_config(self, key: str) -> MemoryControllerConfig | None:
        entry = self.load()["configs"].get(key)
        if not isinstance(entry, dict):
            return None
        try:
            return config_from_dict(entry.get("cfg", {}))
        except (ValueError, TypeError):
            return None

    def put_config(self, key: str, cfg: MemoryControllerConfig, **meta) -> None:
        def mutate(data):
            data["configs"][key] = {"cfg": config_to_dict(cfg), "meta": meta}

        self._update(mutate)

    # -- accounting --------------------------------------------------------

    @staticmethod
    def _count(name: str, **labels) -> None:
        _metrics.counter(f"autotune_cache.{name}", **labels).inc()

    def stats(self) -> dict:
        data = self.load()
        return {
            "path": str(self.path),
            "schema_version": data["schema_version"],
            "specs": sorted(data["specs"]),
            "n_configs": len(data["configs"]),
        }


def default_cache() -> AutotuneCache:
    """The process-default cache (path resolved from the environment on
    every access, so re-pointing `REPRO_AUTOTUNE_DIR` takes effect
    immediately)."""
    return AutotuneCache()


def cached_config(
    kind: str,
    fingerprint: str,
    mode: int,
    rank_key: Any,
    spec: TPUSpec,
    search_thunk,
    *,
    nshards: int | None = None,
    cache: AutotuneCache | None = None,
) -> MemoryControllerConfig:
    """The `auto_tune="cached"` lookup the planned builders call: return the
    persisted winning configuration for this key, or run `search_thunk` (the
    full PMS sweep), persist its winner, and return it.  A hit skips the
    config sweep entirely — counted in ``autotune_cache.hits`` with zero
    ``pms.configs_evaluated`` increments; a miss counts one
    ``autotune_cache.misses`` and writes back."""
    cache = cache if cache is not None else default_cache()
    backend = current_backend()
    key = config_key(
        kind, fingerprint, mode, rank_key,
        backend=backend, spec=spec, nshards=nshards,
    )
    cfg = cache.get_config(key)
    if cfg is not None:
        AutotuneCache._count("hits", kind=kind)
        _trace.event("autotune_cache_hit", kind=kind, mode=int(mode))
        return cfg
    AutotuneCache._count("misses", kind=kind)
    with _trace.span("autotune_cache_search", kind=kind, mode=int(mode)):
        cfg = search_thunk()
    cache.put_config(key, cfg, backend=backend, kind=kind, mode=int(mode))
    return cfg
