"""Unified tensor-network decomposition facade.

One entry point for all three formats on the programmable-memory-controller
substrate:

    from repro.api import decompose

    cp  = decompose(st, rank=8)                          # CP-ALS
    tk  = decompose(st, rank=(4, 4, 4), format="tucker") # Tucker HOOI
    tt  = decompose(st, rank=(4, 3), format="tt")        # TT-ALS

Every format runs the same stack underneath: the Tensor Remapper builds one
BlockPlan per output mode, a `PlannedWorkspace` (kernels/workspace.py) keeps
lane-padded factors device-resident and drives the fully-jitted sweep with
host-side tol early-exit, and the format supplies only its sweep body
(MTTKRP + normal solve for CP, TTMc + Gram eigh for Tucker, TT-core +
kron(P, Q) solve for TT).  `method="pallas_sharded"` routes through the
distributed planned path (repro.dist.planned) for any format; `planned=`
accepts the format's prebuilt workspace for plan reuse across calls.

This module deliberately holds no algorithm logic — it normalizes the rank
argument per format and dispatches to `cp_als` / `tucker_hooi` / `tt_als`,
whose keyword surfaces are already aligned."""
from __future__ import annotations

from typing import Sequence

from .core.coo import SparseTensor
from .obs import trace as _trace

__all__ = ["decompose"]

_FORMATS = ("cp", "tucker", "tt")


def _normalized_rank(format: str, rank, nmodes: int):
    """Per-format rank normalization: CP takes a single int; Tucker an
    N-tuple (an int broadcasts to every mode); TT the N-1 interior bond
    ranks (an int broadcasts to every bond).  Detailed range validation
    stays with each format's driver."""
    if format == "cp":
        if not isinstance(rank, int):
            raise ValueError(
                f"format='cp' takes a single integer rank, got {rank!r}"
            )
        return rank
    if format == "tucker":
        if isinstance(rank, int):
            return (rank,) * nmodes
        return tuple(int(r) for r in rank)
    if isinstance(rank, int):
        return (rank,) * (nmodes - 1)
    return tuple(int(r) for r in rank)


def _lane_ranks(format: str, r, nmodes: int) -> tuple[int, ...]:
    """Per-mode factor lane widths (the `PlannedWorkspace.lane_ranks` rule)
    without building a workspace — sizes the reference rung of the admission
    ladder."""
    if format == "cp":
        return (r,) * nmodes
    if format == "tucker":
        return tuple(r)
    bounds = (1,) + tuple(r) + (1,)
    return tuple(bounds[m] * bounds[m + 1] for m in range(nmodes))


def _admitted(st, r, *, format, method, planned, hbm_budget, interpret,
              auto_tune, cfg, verbose):
    """`hbm_budget=` handling: admit a prebuilt workspace as-is, or run the
    graceful-degradation ladder (`repro.resilience.plan_with_budget`) over
    freshly built workspaces — stepping down the DMA block size, then the
    reference path, then `AdmissionError`.  Returns the (possibly built)
    workspace and the (possibly degraded) method."""
    from .resilience import admit, plan_with_budget, reference_footprint_bytes

    reference_method = "approach1" if format == "cp" else "reference"
    if method not in ("pallas", reference_method, "approach2"):
        raise ValueError(
            f"hbm_budget applies to method='pallas' and the reference "
            f"methods, got method={method!r}"
        )
    ref_bytes = reference_footprint_bytes(st, _lane_ranks(format, r, st.nmodes))
    if method != "pallas":
        if ref_bytes > hbm_budget:
            from .resilience import AdmissionError

            raise AdmissionError(hbm_budget, [], ref_bytes)
        return planned, method
    if planned is not None:
        admit(planned, hbm_budget)
        return planned, method
    if auto_tune:
        raise ValueError(
            "hbm_budget's degradation ladder steps the controller config "
            "explicitly; it is incompatible with auto_tune=True"
        )
    if format == "cp":
        from .kernels.ops import make_planned_cp_als as build_ws
    elif format == "tucker":
        from .tucker.hooi import make_planned_tucker as build_ws
    else:
        from .tt.als import make_planned_tt as build_ws
    ws, decision = plan_with_budget(
        lambda c: build_ws(st, r, cfg=c, interpret=interpret),
        hbm_budget, cfg=cfg, reference_bytes=ref_bytes,
    )
    if verbose:
        rungs = ", ".join(
            f"blk={a['blk']}:{a['total_bytes']:,}B" for a in decision["ladder"]
        )
        print(f"[admission] {decision['admitted']} admitted under "
              f"{hbm_budget:,}B (ladder: {rungs or 'none'})")
    if ws is None:
        return None, reference_method
    return ws, method


def decompose(
    st: SparseTensor,
    rank: int | Sequence[int],
    *,
    format: str = "cp",
    method: str = "pallas",
    iters: int = 10,
    seed: int = 0,
    tol: float | None = None,
    planned=None,
    interpret: bool = True,
    auto_tune: bool | str = False,
    spec="default",
    cfg=None,
    jit_sweep: bool = True,
    devices: int | None = None,
    dist=None,
    verbose: bool = False,
    guards=None,
    hbm_budget: int | None = None,
    checkpoint_every: int | None = None,
    checkpoint_path=None,
    trace=None,
    **format_kwargs,
):
    """Decompose a sparse tensor on the programmable memory controller.

    Args:
      st: host-side COO tensor (>= 3 modes).
      rank: CP rank (int), Tucker core ranks (N-tuple; int broadcasts), or
        TT interior bond ranks (N-1 tuple; int broadcasts) — selected by
        `format`.
      format: 'cp' (CP-ALS), 'tucker' (HOOI) or 'tt' (TT-ALS).
      method: 'pallas' — the planned memory-controller kernel (one remapped,
        device-resident BlockPlan per output mode, built once and reused
        every iteration); 'pallas_sharded' — the distributed planned path
        (one jitted shard_map sweep per iteration, a single psum per mode);
        'reference' — the format's pure-jnp oracle (Tucker/TT; for CP the
        eager compute-pattern methods 'approach1'/'approach2' play that
        role).
      iters / seed / tol / verbose: iteration count, init seed, host-side
        relative-fit early-exit, per-iteration fit printing.
      planned: a prebuilt format workspace (`PlannedCPALS`, `PlannedTucker`,
        `PlannedTT`, or their Sharded* variants) to reuse plans across
        calls; type-checked against `format`/`method`.
      interpret / auto_tune / cfg: pallas-path knobs — interpret-mode Pallas
        (CPU containers), per-mode PMS tuning, explicit controller config.
        auto_tune accepts False, True, or "cached": "cached" serves each
        mode's persisted PMS winner from the on-disk autotune cache
        (repro.tune.cache; `$REPRO_AUTOTUNE_DIR`), skipping the config
        sweep entirely on a warm hit — identical factors, zero search
        configs evaluated — and searching + writing back on a miss.
      spec: PMS hardware constants for the search — a
        `repro.core.memctrl.TPUSpec`, "default" (datasheet guesses), or
        "measured" (this backend's calibrated spec from the autotune cache;
        auto-calibrates on first use — see docs/autotune.md).
      jit_sweep: fully-jitted per-iteration sweep (the default); False keeps
        each format's eager per-mode dispatch loop as the parity baseline.
      devices / dist: 'pallas_sharded' placement.
      guards: a `repro.resilience.GuardConfig` — numerical guards in the
        planned drive loop (non-finite fit, sustained fit regression,
        factor finiteness on cadence) with raise/restart/fallback recovery.
      hbm_budget: admission control (method='pallas' and the reference
        methods): the workspace's resident footprint (`plan_bytes()` +
        padded factors + the PMS VMEM model) must fit this many bytes.
        Over budget, the degradation ladder halves the DMA block size down
        to a floor, then drops to the reference path, and only then raises
        `repro.resilience.AdmissionError`.  Incompatible with a prebuilt
        `planned=` (which is admitted as-is, no ladder) and with
        auto_tune=True.
      checkpoint_every / checkpoint_path: persist padded factors + fit
        history every k iterations via `train.checkpoint`; a populated
        checkpoint directory resumes the sweep bit-for-bit.
      trace: observability tracing for this call (docs/observability.md):
        True collects spans into a fresh in-memory `repro.obs.Tracer`; a
        path collects AND exports them as JSONL on exit; an existing
        `Tracer` appends to it; None/False leaves the process-global state
        alone (so `REPRO_TRACE=1` still applies).  Restores the previous
        tracer when the call returns.
      **format_kwargs: forwarded to the format driver (e.g. TT's
        `init='svd'|'random'|'auto'`, CP's `layout=` / `mttkrp_fn=`).

    Returns:
      The format's state object — `CPState(factors, lam, fit_history)`,
      `TuckerState(factors, core, fit_history)` or
      `TTState(cores, fit_history)`; all carry `fit_history`.
    """
    if format not in _FORMATS:
        raise ValueError(
            f"unknown format {format!r}: expected 'cp', 'tucker' or 'tt'"
        )
    if auto_tune not in (False, True, "cached"):
        raise ValueError(
            f"auto_tune must be False, True or 'cached', got {auto_tune!r}"
        )
    r = _normalized_rank(format, rank, st.nmodes)
    with _trace.tracing(trace), _trace.span(
        "decompose", format=format, method=method,
        shape=list(st.shape), nnz=st.nnz, iters=iters,
    ):
        if hbm_budget is not None:
            planned, method = _admitted(
                st, r, format=format, method=method, planned=planned,
                hbm_budget=hbm_budget, interpret=interpret,
                auto_tune=auto_tune, cfg=cfg, verbose=verbose,
            )
        common = dict(
            iters=iters, method=method, seed=seed, tol=tol, planned=planned,
            interpret=interpret, auto_tune=auto_tune, spec=spec, cfg=cfg,
            jit_sweep=jit_sweep, devices=devices, dist=dist, verbose=verbose,
            guards=guards, checkpoint_every=checkpoint_every,
            checkpoint_path=checkpoint_path,
            **format_kwargs,
        )
        if format == "cp":
            from .core.cp_als import cp_als

            return cp_als(st, r, **common)
        if format == "tucker":
            from .tucker.hooi import tucker_hooi

            return tucker_hooi(st, r, **common)
        from .tt.als import tt_als

        return tt_als(st, r, **common)
