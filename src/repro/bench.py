"""Benchmark trajectory schema + report helpers.

`benchmarks/bench_e2e.py` writes `BENCH_kernel.json` at the repo root so every
PR has a wall-clock baseline to move (GenTen and the authors' GPU follow-on
both treat layout-build cost and steady-state iteration time as first-class
measured quantities).  The schema is deliberately stable and flat:

    {
      "commit":    "<git sha or 'unknown'>",
      "timestamp": "<UTC ISO-8601>",
      "results": [
        {"name": "...", "preset": "...", "metric": "...",
         "value": <number>, "unit": "..."},
        ...
      ]
    }

`validate_report` / `validate_file` are the single source of truth for that
schema — the CI smoke job runs them against the freshly emitted file, so a
schema drift fails the build rather than silently breaking the trajectory.
"""
from __future__ import annotations

import json
import math
import subprocess
from datetime import datetime, timezone
from pathlib import Path
from typing import Any, Mapping, Sequence

__all__ = [
    "result_record",
    "make_report",
    "validate_report",
    "validate_file",
    "write_report",
]

_RESULT_FIELDS = {"name": str, "preset": str, "metric": str, "unit": str}


def result_record(name: str, preset: str, metric: str, value: float, unit: str) -> dict:
    """One benchmark observation in the trajectory schema."""
    rec = {"name": name, "preset": preset, "metric": metric,
           "value": float(value), "unit": unit}
    _validate_result(rec, where=f"result_record({name!r}, {metric!r})")
    return rec


def git_commit(cwd: str | Path | None = None) -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=cwd, capture_output=True, text=True, timeout=10,
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def make_report(results: Sequence[Mapping[str, Any]], *, cwd: str | Path | None = None) -> dict:
    report = {
        "commit": git_commit(cwd),
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "results": [dict(r) for r in results],
    }
    validate_report(report)
    return report


def _validate_result(rec: Any, where: str) -> None:
    if not isinstance(rec, Mapping):
        raise ValueError(f"{where}: result entry must be an object, got {type(rec).__name__}")
    for field, typ in _RESULT_FIELDS.items():
        if field not in rec:
            raise ValueError(f"{where}: missing field {field!r}")
        if not isinstance(rec[field], typ):
            raise ValueError(
                f"{where}: field {field!r} must be {typ.__name__}, "
                f"got {type(rec[field]).__name__}"
            )
    v = rec.get("value")
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        raise ValueError(f"{where}: field 'value' must be a number, got {type(v).__name__}")
    if isinstance(v, float) and not math.isfinite(v):
        raise ValueError(f"{where}: field 'value' must be finite, got {v!r}")
    extra = set(rec) - set(_RESULT_FIELDS) - {"value"}
    if extra:
        raise ValueError(f"{where}: unknown fields {sorted(extra)}")


def validate_report(obj: Any) -> None:
    """Raise ValueError unless `obj` conforms to the trajectory schema."""
    if not isinstance(obj, Mapping):
        raise ValueError(f"report must be an object, got {type(obj).__name__}")
    for field in ("commit", "timestamp"):
        if not isinstance(obj.get(field), str) or not obj.get(field):
            raise ValueError(f"report field {field!r} must be a non-empty string")
    results = obj.get("results")
    if not isinstance(results, list):
        raise ValueError("report field 'results' must be a list")
    if not results:
        raise ValueError("report field 'results' must not be empty")
    for i, rec in enumerate(results):
        _validate_result(rec, where=f"results[{i}]")


def validate_file(path: str | Path, *, expect_commit: str | None = None) -> dict:
    """Load + validate a trajectory file; returns the parsed report.

    `expect_commit` additionally pins the report's `commit` field: pass a
    full sha, or the sentinel "HEAD" to resolve the current checkout's HEAD
    (the CI freshness check — a regenerated trajectory file whose commit
    does not match the commit that produced it is a stale artifact, and
    comparing its numbers against HEAD's code is meaningless)."""
    path = Path(path)
    with open(path) as f:
        obj = json.load(f)
    validate_report(obj)
    if expect_commit is not None:
        if expect_commit == "HEAD":
            want = git_commit(path.resolve().parent)
            if want == "unknown":
                raise ValueError(
                    f"{path}: expect_commit='HEAD' but no git commit could "
                    f"be resolved next to the file"
                )
        else:
            want = expect_commit
        if obj["commit"] != want:
            raise ValueError(
                f"{path}: stale trajectory file — report commit "
                f"{obj['commit'][:12]} != expected {want[:12]}; regenerate "
                f"with benchmarks/bench_e2e.py at the current checkout"
            )
    n = len(obj["results"])
    print(f"[bench] {path}: schema OK ({n} results, commit {obj['commit'][:12]})")
    return obj


def write_report(path: str | Path, results: Sequence[Mapping[str, Any]]) -> dict:
    report = make_report(results, cwd=Path(path).resolve().parent)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report
