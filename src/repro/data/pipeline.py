"""Deterministic synthetic LM data pipeline.

Production posture mirrored at laptop scale:
  * **Deterministic + seekable**: batch `i` is a pure function of (seed, i) —
    restart from a checkpoint at step N reproduces exactly the batches N+1...
    without replaying the stream (the `skip_to` of real pipelines).
  * **Host-parallel sharding**: each host materializes only its slice of the
    global batch (``host_slice``), matching multi-host jax.Array creation.
  * **Prefetch depth**: a background thread keeps `depth` batches ready —
    the straggler-mitigation lever called out in DESIGN.md §4 (data stalls
    never serialize with compute).

The synthetic corpus is a mixture of Zipf unigrams and a Markov bigram chain
(fixed per seed) so models actually have learnable structure — examples/
train_lm.py reaches sub-entropy loss within a few hundred steps.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["TokenPipeline", "make_batch_iterator"]


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    markov_order: float = 0.7  # prob of following the bigram chain

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-self.zipf_alpha)
        self._unigram = p / p.sum()
        # Sparse deterministic bigram successor table: tok -> fixed successor
        self._succ = rng.permutation(v).astype(np.int64)

    def batch(self, index: int, host_slice: slice | None = None) -> dict[str, np.ndarray]:
        """The `index`-th global batch; optionally just this host's rows.
        The full batch is always generated from the same stream so every host
        sees identical global data regardless of its slice."""
        rng = np.random.default_rng((self.seed, index))
        B, S = self.global_batch, self.seq_len
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.choice(self.vocab, size=B, p=self._unigram)
        follow = rng.random((B, S)) < self.markov_order
        draws = rng.choice(self.vocab, size=(B, S), p=self._unigram)
        for t in range(S):
            toks[:, t + 1] = np.where(follow[:, t], self._succ[toks[:, t]], draws[:, t])
        if host_slice is not None:
            toks = toks[host_slice]
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


def make_batch_iterator(
    pipe: TokenPipeline,
    start_index: int = 0,
    depth: int = 2,
    host_slice: slice | None = None,
) -> Iterator[dict[str, np.ndarray]]:
    """Prefetching iterator: a daemon thread keeps `depth` batches queued."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def producer():
        i = start_index
        while not stop.is_set():
            q.put(pipe.batch(i, host_slice))
            i += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()
            try:  # unblock the producer if it is waiting on a full queue
                q.get_nowait()
            except queue.Empty:
                pass

    return _Iter()
