"""Data pipeline: deterministic synthetic token/tensor streams with
prefetch."""
from .pipeline import TokenPipeline, make_batch_iterator
