"""Production mesh definitions (TPU v5e pods).

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "dp_size", "MESH_SHAPES"]

MESH_SHAPES = {
    "single": ((16, 16), ("data", "model")),  # one v5e pod, 256 chips
    "multi": ((2, 16, 16), ("pod", "data", "model")),  # 2 pods, 512 chips
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_size(mesh) -> int:
    s = 1
    for name in mesh.axis_names:
        if name != "model":
            s *= mesh.shape[name]
    return s
