import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers, SPMD-
partitions, and fits per-device HBM — without hardware.

Per cell this script:
  1. builds the production mesh (single-pod 16x16 / multi-pod 2x16x16 of
     placeholder host devices — the two lines above MUST precede any jax
     import, device count locks at first init);
  2. lowers + compiles the cell's step (train_step / prefill / decode_step)
     against ShapeDtypeStruct stand-ins (no allocation at full scale);
  3. records compiled.memory_analysis() (fits-in-HBM proof),
     compiled.cost_analysis(), and the collective-op schedule parsed from the
     partitioned HLO;
  4. optionally (--probe) lowers unrolled depth-p / depth-2p cost probes —
     XLA counts a while-loop body once, so scanned-module cost_analysis
     undercounts; probes give exact per-period FLOPs/bytes/collective terms
     that benchmarks/roofline.py extrapolates (see EXPERIMENTS.md §Roofline
     methodology).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod] [--probe] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --matrix [--multi-pod]
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from functools import partial


# ---------------------------------------------------------------------------
# cell policy
# ---------------------------------------------------------------------------

FULL_ATTENTION = {
    "qwen3-0.6b", "qwen2-1.5b", "minitron-4b", "phi4-mini-3.8b",
    "phi3.5-moe-42b-a6.6b", "grok-1-314b", "whisper-large-v3",
    "llama-3.2-vision-11b",
}


def skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch in FULL_ATTENTION:
        return "long_500k needs sub-quadratic attention; skipped for pure full-attention archs (DESIGN.md §5)"
    return None


def default_microbatches(cfg, shape_cfg, mesh) -> int:
    """Gradient-accumulation depth: keep one-ish sequence per DP group per
    microbatch for wide models (activation-memory lever)."""
    if shape_cfg.kind != "train":
        return 1
    from .mesh import dp_size

    per_dp = max(1, shape_cfg.global_batch // dp_size(mesh))
    target = 1 if cfg.d_model >= 3072 else 4
    return max(1, per_dp // target)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z]*\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "s64": 8, "u64": 8, "f64": 8, "pred": 1, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum output bytes per collective kind over the partitioned module.
    NOTE: ops inside while bodies are counted once (see probe methodology)."""
    per_kind: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        sig, kind = m.group(1), m.group(2)
        b = _shape_bytes(sig)
        d = per_kind.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return per_kind


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def auto_remat_group(n_reps: int) -> int:
    """Largest divisor of n_reps <= sqrt(n_reps) (sqrt-remat schedule)."""
    if n_reps < 16:
        return 0
    best = 0
    d = 1
    while d * d <= n_reps:
        if n_reps % d == 0:
            best = d
        d += 1
    return best if best > 1 else 0


def build_cell(arch: str, shape_name: str, mesh, *, num_microbatches=None, sp=False,
               compress_grads=False, attn_chunk=2048, probe_depth=None, remat=None,
               remat_group=None, barrier_xs=None):
    """Returns (fn, args_abstract, in_shardings, donate) for one cell."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import get_config, SHAPES
    from ..dist.sharding import (batch_pspecs, batch_specs, make_plan,
                                 param_pspecs, valid_spec)
    from ..models import transformer as T
    from ..serve.engine import cache_pspecs, cache_specs
    from ..train.optimizer import AdamWConfig, adamw_init
    from ..train.train_step import TrainState, make_train_step

    cfg = get_config(arch)
    if SHAPES[shape_name].kind != "train":
        # serving uses bf16 checkpoints: halves parameter args + per-layer
        # weight traffic (fp32 master is a training-only concern)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if probe_depth is not None:  # unrolled shallow probe for exact costs
        period = cfg.period
        changes = dict(n_layers=probe_depth * period, scan_unroll=True)
        if cfg.encoder_layers:
            changes["encoder_layers"] = probe_depth
        cfg = dataclasses.replace(cfg, **changes)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if probe_depth is None:
        rg = remat_group if remat_group is not None else auto_remat_group(cfg.n_layers // cfg.period)
        cfg = dataclasses.replace(cfg, remat_group=rg)
    if barrier_xs is not None:
        cfg = dataclasses.replace(cfg, barrier_xs=barrier_xs)
    shape_cfg = SHAPES[shape_name]
    plan = make_plan(mesh, cfg, sp=sp)
    if (shape_cfg.kind == "prefill" and cfg.n_heads
            and cfg.n_heads % mesh.shape["model"] != 0):
        # heads can't shard over TP -> scores are batch-sharded only; cap the
        # query chunk so the per-chunk f32 score buffer stays ~2 GiB
        attn_chunk = min(attn_chunk, 1024)
    opt_cfg = AdamWConfig(
        state_dtype="bfloat16" if cfg.fsdp else "float32",
        update_slices=int(os.environ.get("REPRO_UPDATE_SLICES", "1")),
        factored_v=cfg.fsdp,  # Adafactor-style v for the HBM-bound archs
    )

    def named(tree_specs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                            is_leaf=lambda x: isinstance(x, P))

    params_abs = T.abstract_params(cfg)
    p_specs = param_pspecs(params_abs, plan)
    p_specs = jax.tree.map(lambda a, s: valid_spec(a.shape, s, mesh), params_abs, p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    batch_abs = batch_specs(cfg, shape_cfg, plan)
    b_specs = {k: valid_spec(batch_abs[k].shape, s, mesh)
               for k, s in batch_pspecs(cfg, shape_cfg, plan).items()}

    if shape_cfg.kind == "train":
        nmb = num_microbatches or default_microbatches(cfg, shape_cfg, mesh)

        def abstract_opt(params):
            opt = adamw_init(params, opt_cfg)
            if compress_grads:  # steady-state step: the "ef" residual is a
                from ..dist.compression import init_error_feedback  # live input

                opt = init_error_feedback(opt, params)
            return opt

        state_abs = jax.eval_shape(
            lambda: TrainState(
                params=T.init_params(jax.random.PRNGKey(0), cfg),
                opt=abstract_opt(params_abs),
                rng=jax.random.PRNGKey(0),
            )
        )
        from ..train.optimizer import opt_pspecs

        o_specs = opt_pspecs(params_abs, p_specs, opt_cfg)
        if compress_grads:
            o_specs["ef"] = p_specs
        state_specs = TrainState(params=p_specs, opt=o_specs, rng=P())
        step_fn = make_train_step(cfg, opt_cfg, plan, num_microbatches=nmb,
                                  attn_chunk=attn_chunk, compress_grads=compress_grads)
        fn = jax.jit(step_fn,
                     in_shardings=(named(state_specs), named(b_specs)),
                     donate_argnums=(0,))
        return fn, (state_abs, batch_abs), dict(num_microbatches=nmb, cfg=cfg)

    if shape_cfg.kind == "prefill":
        def prefill_fn(params, batch):
            return T.prefill(params, batch, cfg, cache_len=shape_cfg.seq_len,
                             plan=plan, attn_chunk=attn_chunk)

        fn = jax.jit(prefill_fn, in_shardings=(named(p_specs), named(b_specs)))
        return fn, (params_abs, batch_abs), dict(cfg=cfg)

    # decode: one new token against a seq_len cache
    B = shape_cfg.global_batch
    caches_abs = cache_specs(cfg, B, shape_cfg.seq_len)
    c_specs = cache_pspecs(cfg, plan)
    c_specs = jax.tree.map(lambda a, s: valid_spec(a.shape, s, mesh), caches_abs, c_specs,
                           is_leaf=lambda x: isinstance(x, P))
    tok_abs = batch_abs["tokens"]
    pos_abs = batch_abs["pos"]
    mem_abs = {k: v for k, v in batch_abs.items() if k in ("frames", "images")}

    def decode_fn(params, tokens, pos, caches, memory):
        return T.decode_step(params, tokens, pos, caches, memory, cfg, plan)

    fn = jax.jit(
        decode_fn,
        in_shardings=(
            named(p_specs),
            NamedSharding(mesh, valid_spec(tok_abs.shape, P(plan.dp or None, None), mesh)),
            NamedSharding(mesh, valid_spec(pos_abs.shape, P(plan.dp or None), mesh)),
            named(c_specs),
            named({k: b_specs[k] for k in mem_abs}),
        ),
        donate_argnums=(3,),  # caches update in place
    )
    return fn, (params_abs, tok_abs, pos_abs, caches_abs, mem_abs), dict(cfg=cfg)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, probe: bool = False,
             out_dir: str = "artifacts/dryrun", **overrides) -> dict:
    import jax
    from .mesh import make_production_mesh

    mesh_name = "multi" if multi_pod else "single"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "nchips": 512 if multi_pod else 256}
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["skipped"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args, info = build_cell(arch, shape_name, mesh, **overrides)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        rec.update(
            ok=True,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            num_microbatches=info.get("num_microbatches"),
            memory=dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                alias_bytes=int(ma.alias_size_in_bytes),
                peak_bytes=int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
            ),
            cost=dict(
                flops=float(ca.get("flops", -1.0)),
                bytes_accessed=float(ca.get("bytes accessed", -1.0)),
            ),
            collectives=parse_collectives(hlo),
        )

    if probe:  # exact per-depth costs: unrolled depth-1 / depth-2 periods
        rec["probes"] = {}
        for depth in (1, 2):
            with mesh:
                pfn, pargs, pinfo = build_cell(
                    arch, shape_name, mesh, probe_depth=depth,
                    **{**overrides, "num_microbatches": 1},
                )
                pcompiled = pfn.lower(*pargs).compile()
                pca = pcompiled.cost_analysis() or {}
                rec["probes"][f"depth{depth}"] = dict(
                    flops=float(pca.get("flops", -1.0)),
                    bytes_accessed=float(pca.get("bytes accessed", -1.0)),
                    transcendentals=float(pca.get("transcendentals", 0.0)),
                    collectives=parse_collectives(pcompiled.as_text()),
                )
        rec["probe_meta"] = {
            "period": info["cfg"].period if "cfg" in info else None,
            "n_reps_full": get_n_reps(arch),
        }

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    rec["artifact"] = path
    return rec


def get_n_reps(arch: str) -> int:
    from ..configs import get_config

    cfg = get_config(arch)
    return cfg.n_layers // cfg.period


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--probe", action="store_true", help="also lower unrolled cost probes")
    ap.add_argument("--matrix", action="store_true", help="run every (arch x shape)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--sp", action="store_true", help="sequence-parallel activations")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--attn-chunk", type=int, default=2048)
    ap.add_argument("--remat-group", type=int, default=None)
    ap.add_argument("--barrier-xs", action="store_true", default=None)
    args = ap.parse_args(argv)

    from ..configs import SHAPES, list_configs

    cells = (
        [(a, s) for a in list_configs() for s in SHAPES]
        if args.matrix
        else [(args.arch, args.shape)]
    )
    failures = 0
    for arch, shape in cells:
        try:
            rec = run_cell(
                arch, shape, multi_pod=args.multi_pod, probe=args.probe,
                out_dir=args.out, num_microbatches=args.microbatches,
                sp=args.sp, compress_grads=args.compress_grads,
                attn_chunk=args.attn_chunk, remat_group=args.remat_group,
                barrier_xs=args.barrier_xs,
            )
            if rec.get("skipped"):
                print(f"[dryrun] SKIP {arch} {shape}: {rec['skipped']}")
            else:
                m = rec["memory"]
                print(
                    f"[dryrun] OK {arch} {shape} {rec['mesh']}: "
                    f"peak/device={m['peak_bytes']/2**30:.2f} GiB "
                    f"args={m['argument_bytes']/2**30:.2f} temp={m['temp_bytes']/2**30:.2f} "
                    f"compile={rec['compile_s']}s colls={sum(c['count'] for c in rec['collectives'].values())}"
                )
        except Exception as e:  # a failing cell is a bug — surface and count
            failures += 1
            print(f"[dryrun] FAIL {arch} {shape}: {type(e).__name__}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
