"""Fault-tolerant training driver.

Supervisor loop (the 1000-node posture, exercised at laptop scale):
  * atomic keep-last-k checkpoints (train/checkpoint.py), async by default;
  * failure detection: any exception in the step loop (or an injected
    ``--fail-at-step``, used by tests) triggers a supervised restart from the
    latest checkpoint — up to ``--max-restarts``;
  * elastic re-mesh: on restart the mesh is rebuilt from the devices
    currently visible; checkpoints reshard on restore (device_put with the
    new sharding), so a shrink/grow restart is transparent;
  * straggler watchdog: step times exceeding ``watchdog_factor`` x the
    running median are logged as straggler events (on real fleets this feeds
    the scheduler; here it exercises the accounting);
  * deterministic data: batch i is a pure function of (seed, i), so restarts
    resume the stream exactly (no replays / skips).

Example (reduced config, CPU):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics
import time

import numpy as np


def build(args, mesh):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import get_config
    from ..data.pipeline import TokenPipeline, make_batch_iterator
    from ..dist.sharding import make_plan, param_pspecs, valid_spec
    from ..train.optimizer import AdamWConfig
    from ..train.train_step import TrainState, init_train_state, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, remat=not args.no_remat)
    plan = make_plan(mesh, cfg)
    opt_cfg = AdamWConfig(
        lr=args.lr, warmup_steps=args.warmup, total_steps=args.steps,
        state_dtype="bfloat16" if cfg.fsdp else "float32",
    )

    def shardings_of(state):
        from ..train.optimizer import opt_pspecs

        pspecs = param_pspecs(state.params, plan)
        pspecs = jax.tree.map(
            lambda a, s: valid_spec(a.shape, s, mesh), state.params, pspecs
        )
        ospecs = opt_pspecs(state.params, pspecs, opt_cfg)
        if args.compress_grads:  # error-feedback residual shards like params
            ospecs["ef"] = pspecs
        specs = TrainState(params=pspecs, opt=ospecs, rng=P())
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P),
        )

    step_fn = make_train_step(
        cfg, opt_cfg, plan, num_microbatches=args.microbatches,
        attn_chunk=args.attn_chunk, compress_grads=args.compress_grads,
    )
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=args.seed)
    return cfg, plan, opt_cfg, step_fn, pipe, shardings_of


def train_once(args, start_attempt: int) -> int:
    """One supervised attempt.  Returns the step reached.  Raises to signal
    a failure the supervisor should handle."""
    import jax
    import jax.numpy as jnp

    from ..data.pipeline import make_batch_iterator
    from ..launch.mesh import make_host_mesh
    from ..train.checkpoint import CheckpointManager

    mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)
    cfg, plan, opt_cfg, step_fn, pipe, shardings_of = build(args, mesh)
    ckpt = CheckpointManager(args.ckpt_dir, keep=args.keep) if args.ckpt_dir else None

    with mesh:
        from ..train.train_step import init_train_state

        state = init_train_state(
            jax.random.PRNGKey(args.seed), cfg, opt_cfg,
            compress_grads=args.compress_grads,
        )
        shardings = shardings_of(state)
        state = jax.device_put(state, shardings)
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            start, state = ckpt.restore(shardings=shardings)  # elastic reshard
            print(f"[train] restored step {start} (attempt {start_attempt})")

        jstep = jax.jit(step_fn, donate_argnums=(0,))
        step_times: list[float] = []
        it = make_batch_iterator(pipe, start_index=start, depth=args.prefetch)
        for step in range(start, args.steps):
            if args.fail_at_step == step and start_attempt == 0:
                raise RuntimeError("injected node failure (--fail-at-step)")
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, next(it))
            state, metrics = jstep(state, batch)
            loss = float(metrics["loss"])  # sync point
            dt = time.time() - t0
            step_times.append(dt)
            if len(step_times) >= 5:
                med = statistics.median(step_times[-50:])
                if dt > args.watchdog_factor * med:
                    print(f"[watchdog] straggler: step {step} took {dt:.2f}s (median {med:.2f}s)")
            if step % args.log_every == 0:
                print(f"[train] step {step:5d} loss={loss:.4f} lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state, blocking=False)
        it.close()
        if ckpt:
            ckpt.save(args.steps, state, blocking=True)
    return args.steps


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attn-chunk", type=int, default=2048)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--watchdog-factor", type=float, default=3.0)
    ap.add_argument("--fail-at-step", type=int, default=-1, help="inject a failure (tests)")
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args(argv)

    for attempt in range(args.max_restarts + 1):
        try:
            reached = train_once(args, attempt)
            print(f"[train] done at step {reached}")
            return 0
        except (RuntimeError, OSError) as e:
            print(f"[supervisor] attempt {attempt} failed: {e}")
            if attempt == args.max_restarts:
                print("[supervisor] max restarts exceeded")
                return 1
            if not args.ckpt_dir:
                print("[supervisor] no checkpoint dir; cold restart")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
