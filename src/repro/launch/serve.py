"""Batched serving driver: prefill a batch of synthetic prompts, then decode
greedily, reporting per-phase token throughput.

Example (reduced config, CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--attn-chunk", type=int, default=2048)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..dist.sharding import make_plan
    from ..launch.mesh import make_host_mesh
    from ..models import transformer as T
    from ..serve.engine import make_decode_step, make_prefill_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(data=args.mesh_data, model=args.mesh_model)
    plan = make_plan(mesh, cfg)
    key = jax.random.PRNGKey(args.seed)
    B, S = args.batch, args.prompt_len

    with mesh:
        params = T.init_params(key, cfg)
        batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)}
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
        if cfg.family == "vlm":
            batch["images"] = jax.random.normal(key, (B, cfg.img_tokens, cfg.d_model)) * 0.1

        cache_len = S + args.new_tokens
        prefill = jax.jit(make_prefill_step(cfg, plan, cache_len=cache_len, attn_chunk=args.attn_chunk))
        decode = jax.jit(make_decode_step(cfg, plan), donate_argnums=(3,))

        t0 = time.time()
        logits, caches = jax.block_until_ready(prefill(params, batch))
        t_prefill = time.time() - t0
        cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        pos = jnp.full((B,), S, jnp.int32)
        out = [cur]
        t0 = time.time()
        for i in range(args.new_tokens - 1):
            cur, _, caches = decode(params, cur, pos, caches, batch)
            out.append(cur)
            pos = pos + 1
        jax.block_until_ready(cur)
        t_decode = time.time() - t0

    toks = np.asarray(jnp.concatenate(out, 1))
    print(f"[serve] arch={cfg.name} batch={B} prompt={S} new={args.new_tokens}")
    print(f"[serve] prefill: {B*S/t_prefill:,.0f} tok/s ({t_prefill*1e3:.0f} ms)")
    print(f"[serve] decode:  {B*(args.new_tokens-1)/max(t_decode,1e-9):,.0f} tok/s "
          f"({t_decode/max(args.new_tokens-1,1)*1e3:.1f} ms/step)")
    print(f"[serve] sample continuation ids: {toks[0][:12].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
