"""Unified model: one composable stack covering every assigned family.

  dense / moe          decoder-only LM (GQA attn + MLP/MoE)
  ssm                  Mamba2 stack (attention-free)
  hybrid (jamba)       Mamba + attn 7:1 interleave, MoE every other layer
  audio (whisper)      enc-dec; encoder consumes stub frame embeddings
  vlm (llama-vision)   decoder LM with cross-attn image layers (stub patches)

Structure: the layer pattern repeats with period ``cfg.period``; parameters
for each period *position* are stacked over ``n_layers // period`` repeats and
the stack is a single ``lax.scan`` (bounded HLO regardless of depth).  With
``cfg.remat`` the period body is ``jax.checkpoint``-ed.

Entry points:
  init_params / abstract_params          parameters (concrete / eval_shape)
  apply_train -> (loss, metrics)         next-token CE (+ MoE aux losses)
  prefill    -> (last_logits, caches)    full-prompt pass, caches filled
  decode_step-> (logits, caches)         one token against the caches
  init_caches                            zeroed decode state

Caches are a tuple over period positions; each element's leaves carry a
leading n_reps dim and ride through the same scan as the parameters.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..dist.sharding import NOPLAN, ShardingPlan, shard
from .attention import (
    attn_init,
    cross_attention,
    full_attention,
    self_attention_decode,
    self_attention_prefill,
    self_attention_train,
    xattn_init,
)
from .layers import (
    Params,
    dtype_of,
    embed,
    embed_init,
    mlp,
    mlp_init,
    norm_apply,
    norm_init,
    sinusoid_positions,
)
from .moe import moe_apply, moe_init
from .ssm import mamba_decode, mamba_init, mamba_init_cache, mamba_train

__all__ = [
    "init_params",
    "abstract_params",
    "apply_train",
    "prefill",
    "decode_step",
    "init_caches",
    "lm_logits",
]


def _norm_kind(cfg) -> str:
    return getattr(cfg, "norm", "rms")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(key: jax.Array, cfg, mixer: str, ffn: str, dtype) -> Params:
    """One layer's parameters (pre-norm residual block)."""
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    nk = _norm_kind(cfg)
    p: Params = {"norm1": norm_init(nk, d, dtype)}
    if mixer == "attn":
        p["attn"] = attn_init(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dtype,
        )
    elif mixer == "mamba":
        p["mamba"] = mamba_init(ks[0], d, cfg.ssm, dtype)
    elif mixer == "xattn":
        p["xattn"] = xattn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype)
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_ffn"] = jnp.zeros((), jnp.float32)
    if cfg.family == "audio":  # whisper decoder: self-attn + cross-attn + mlp
        p["xattn"] = xattn_init(ks[1], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype)
        p["norm_x"] = norm_init(nk, d, dtype)
    if ffn != "none":
        p["norm2"] = norm_init(nk, d, dtype)
        if ffn == "moe":
            p["moe"] = moe_init(ks[2], d, cfg.moe, cfg.act, dtype)
        else:
            p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, cfg.act, dtype)
    return p


def _stack_blocks(keys: jax.Array, cfg, pattern, dtype) -> tuple[Params, ...]:
    """Stacked per-position parameter trees: blocks[pos] leaves lead with
    n_reps."""
    period = len(pattern)
    n_reps = cfg.n_layers // period
    blocks = []
    for pos, (mixer, ffn) in enumerate(pattern):
        reps = [
            _block_init(keys[r * period + pos], cfg, mixer, ffn, dtype)
            for r in range(n_reps)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps))
    return tuple(blocks)


def _encoder_init(key: jax.Array, cfg, dtype) -> Params:
    """Whisper-style encoder: full-attention + MLP blocks over frames."""
    nk = _norm_kind(cfg)
    keys = jax.random.split(key, cfg.encoder_layers)
    d = cfg.d_model
    reps = []
    for k in keys:
        ks = jax.random.split(k, 2)
        reps.append(
            {
                "norm1": norm_init(nk, d, dtype),
                "attn": attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype=dtype),
                "norm2": norm_init(nk, d, dtype),
                "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.act, dtype),
            }
        )
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    return {"blocks": stacked, "norm_post": norm_init(nk, d, dtype)}


def init_params(key: jax.Array, cfg) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    pattern = cfg.pattern_kinds()
    keys = jax.random.split(key, cfg.n_layers + 4)
    p: Params = {
        "embed": embed_init(keys[-1], cfg.vocab_padded, cfg.d_model, dtype),
        "blocks": _stack_blocks(keys[: cfg.n_layers], cfg, pattern, dtype),
        "norm_f": norm_init(_norm_kind(cfg), cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(keys[-2], cfg.vocab_padded, cfg.d_model, dtype)
    if cfg.family == "audio":
        p["encoder"] = _encoder_init(keys[-3], cfg, dtype)
    return p


def abstract_params(cfg) -> Params:
    """eval_shape over init — the dry-run's parameter stand-in (no alloc)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_ffn(bp: Params, x: jax.Array, cfg, ffn: str, plan: ShardingPlan = NOPLAN):
    """Residual FFN half-block. Returns (x, aux)."""
    aux = {}
    if ffn == "none":
        return x, aux
    h = norm_apply(_norm_kind(cfg), bp["norm2"], x, cfg.norm_eps)
    if ffn == "moe":
        out, aux = moe_apply(bp["moe"], h, cfg.moe, cfg.act, plan)
    else:
        out = mlp(bp["mlp"], h, cfg.act)
    return x + out, aux


def _apply_block_train(
    bp: Params,
    x: jax.Array,
    cfg,
    mixer: str,
    ffn: str,
    memory: jax.Array | None,
    plan: ShardingPlan,
    attn_chunk: int,
):
    nk = _norm_kind(cfg)
    h = norm_apply(nk, bp["norm1"], x, cfg.norm_eps)
    if mixer == "attn":
        x = x + self_attention_train(bp["attn"], h, cfg, chunk=attn_chunk, plan=plan)
    elif mixer == "mamba":
        x = x + mamba_train(bp["mamba"], h, cfg)
    elif mixer == "xattn":
        y, _ = cross_attention(bp["xattn"], h, memory, cfg, plan=plan)
        x = x + jnp.tanh(bp["gate_attn"]).astype(x.dtype) * y
    if cfg.family == "audio":  # decoder cross-attn into encoder memory
        hx = norm_apply(nk, bp["norm_x"], x, cfg.norm_eps)
        y, _ = cross_attention(bp["xattn"], hx, memory, cfg, plan=plan)
        x = x + y
    x, aux = _apply_ffn(bp, x, cfg, ffn, plan)
    x = shard(x, plan.hidden(), plan)
    return x, aux


def _scan_blocks(params: Params, x: jax.Array, cfg, fn):
    """lax.scan over layer repeats; `fn(carry, per_rep_blocks)` applies one
    period.  Returns (x, stacked_ys).

    cfg.scan_unroll=True replaces the scan with a Python loop — used by the
    roofline cost probes, because XLA's cost analysis counts a while-loop
    body once regardless of trip count."""
    blocks = params["blocks"]
    return _scan_or_unroll(cfg, fn, x, blocks)


def _scan_or_unroll(cfg, fn, carry, xs):
    if getattr(cfg, "barrier_xs", False) and not getattr(cfg, "scan_unroll", False):
        inner = fn

        def fn(c, xs_slice):  # noqa: F811 — barrier wrapper around the body
            xs_slice, c = jax.lax.optimization_barrier((xs_slice, c))
            return inner(c, xs_slice)

    body = jax.checkpoint(fn) if cfg.remat else fn
    if getattr(cfg, "scan_unroll", False):
        ys = []
        n_reps = jax.tree.leaves(xs)[0].shape[0]
        for r in range(n_reps):
            per_rep = jax.tree.map(lambda a: a[r], xs)
            carry, y = body(carry, per_rep)
            ys.append(y)
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) if ys else ()
        return carry, stacked
    grp = getattr(cfg, "remat_group", 0)
    n_reps = jax.tree.leaves(xs)[0].shape[0]
    if cfg.remat and grp > 1 and n_reps % grp == 0:
        # sqrt-remat: outer scan over n_reps/grp checkpointed groups — only
        # the group-boundary carries are saved for backward; the grp inner
        # carries are recomputed transiently per group.
        xs_g = jax.tree.map(lambda a: a.reshape((n_reps // grp, grp) + a.shape[1:]), xs)

        def group_fn(c, grp_xs):
            # inner layers are checkpointed too: the group recompute then
            # keeps one layer's working set + grp boundary carries live
            return jax.lax.scan(jax.checkpoint(fn), c, grp_xs)

        carry, ys = jax.lax.scan(jax.checkpoint(group_fn), carry, xs_g)
        return carry, jax.tree.map(lambda a: a.reshape((n_reps,) + a.shape[2:]), ys)
    return jax.lax.scan(body, carry, xs)


# ---------------------------------------------------------------------------
# encoder (audio)
# ---------------------------------------------------------------------------


def encode_audio(params: Params, frames: jax.Array, cfg, plan: ShardingPlan = NOPLAN) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    nk = _norm_kind(cfg)
    enc = params["encoder"]
    x = frames + sinusoid_positions(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(x, bp):
        h = norm_apply(nk, bp["norm1"], x, cfg.norm_eps)
        x = x + self_attention_train(bp["attn"], h, cfg, causal=False, plan=plan)
        h = norm_apply(nk, bp["norm2"], x, cfg.norm_eps)
        x = x + mlp(bp["mlp"], h, cfg.act)
        return shard(x, plan.memory(), plan), None

    x, _ = _scan_or_unroll(cfg, body, x, enc["blocks"])
    return norm_apply(nk, enc["norm_post"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _make_sharded_embed(plan: ShardingPlan, vocab: int, dtype):
    """Embedding gather whose BACKWARD is a vocab-sharded one-hot matmul.

    The natural gather backward is a scatter-add into a zeros(V, D) buffer;
    XLA SPMD replicates that scatter, materializing the full dense embedding
    gradient in f32 on every device (3 GiB for grok-1).  Expressing the
    cotangent as one_hot(ids)^T @ g lets the dot partitioner keep V sharded."""

    @jax.custom_vjp
    def gather(table, ids):
        return jnp.take(table, ids, axis=0)

    def fwd(table, ids):
        return gather(table, ids), ids

    def bwd(ids, g):
        oh = jax.nn.one_hot(ids.reshape(-1), vocab, dtype=g.dtype)  # (T, V)
        oh = shard(oh, jax.sharding.PartitionSpec(None, plan.tp), plan)
        gt = oh.T @ g.reshape(-1, g.shape[-1])
        return gt.astype(dtype), None

    gather.defvjp(fwd, bwd)
    return gather


def _embed_tokens(
    params: Params, tokens: jax.Array, cfg, plan: ShardingPlan, pos: jax.Array | None = None
) -> jax.Array:
    """Token embedding (+ sinusoid positions for rope-free archs).  `pos`
    (B,) selects per-batch positions during decode; None = arange(S)."""
    cd = dtype_of(cfg.compute_dtype)
    if plan.mesh is not None:
        tab = params["embed"]
        x = _make_sharded_embed(plan, tab.shape[0], tab.dtype)(tab, tokens).astype(cd)
    else:
        x = embed(params["embed"], tokens, cd)
    if cfg.family == "audio" or cfg.rope_theta == 0:
        if pos is None:
            x = x + sinusoid_positions(tokens.shape[1], cfg.d_model).astype(cd)[None]
        else:
            tab = sinusoid_positions(1 << 16, cfg.d_model)
            x = x + jnp.take(tab, jnp.minimum(pos, tab.shape[0] - 1), axis=0)[:, None].astype(cd)
    return shard(x, plan.hidden(), plan)


def lm_logits(params: Params, h: jax.Array, cfg, plan: ShardingPlan = NOPLAN) -> jax.Array:
    """Final-norm + unembed.  The matmul runs in compute dtype (bf16 feeds
    the MXU at full rate, half the weight traffic) with fp32 accumulation;
    logits come out fp32 for the loss."""
    h = norm_apply(_norm_kind(cfg), params["norm_f"], h, cfg.norm_eps)
    w = params["lm_head"] if "lm_head" in params else params["embed"]
    logits = jnp.einsum(
        "bsd,vd->bsv", h, w.astype(h.dtype), preferred_element_type=jnp.float32
    )
    if cfg.vocab_padded != cfg.vocab:
        if plan.mesh is None:  # host path: drop the pad columns
            logits = logits[..., : cfg.vocab]
        else:  # sharded path: mask them (slicing a TP-sharded dim resplits)
            pad_mask = jnp.arange(cfg.vocab_padded) < cfg.vocab
            logits = jnp.where(pad_mask, logits, -1e30)
    return shard(logits, plan.logits(), plan)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def _memory_of(params, batch, cfg, plan):
    if cfg.family == "audio":
        return encode_audio(params, batch["frames"], cfg, plan)
    if cfg.family == "vlm":
        return batch["images"]
    return None


def forward_hidden(
    params: Params, batch: dict, cfg, plan: ShardingPlan = NOPLAN, *, attn_chunk: int = 2048
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Token stream -> (final hidden states (B, S, D), summed MoE aux)."""
    pattern = cfg.pattern_kinds()
    memory = _memory_of(params, batch, cfg, plan)
    x = _embed_tokens(params, batch["tokens"], cfg, plan)

    def period_fn(x, per_rep):
        auxes = []
        for pos, (mixer, ffn) in enumerate(pattern):
            x, aux = _apply_block_train(per_rep[pos], x, cfg, mixer, ffn, memory, plan, attn_chunk)
            auxes.append(aux)
        lb = sum(a.get("load_balance", jnp.zeros(())) for a in auxes)
        rz = sum(a.get("router_z", jnp.zeros(())) for a in auxes)
        return x, {"load_balance": lb, "router_z": rz}

    x, aux = _scan_blocks(params, x, cfg, period_fn)
    return x, jax.tree.map(jnp.sum, aux)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Masked next-token CE.  labels < 0 are ignored.  Returns (sum, count)."""
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - gold, 0.0)
    return nll.sum(), valid.sum()


def apply_train(
    params: Params, batch: dict, cfg, plan: ShardingPlan = NOPLAN, *, attn_chunk: int = 2048
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Full forward + masked CE loss (+ MoE aux).  The train_step microbatches
    around this, so logits here exist only for one microbatch at a time."""
    h, aux = forward_hidden(params, batch, cfg, plan, attn_chunk=attn_chunk)
    logits = lm_logits(params, h, cfg, plan)
    nll_sum, count = cross_entropy(logits, batch["labels"])
    loss = nll_sum / jnp.maximum(count, 1)
    metrics = {"ce": loss, "tokens": count}
    loss = loss + 0.01 * aux.get("load_balance", 0.0) + 1e-3 * aux.get("router_z", 0.0)
    metrics.update(aux)
    return loss, metrics


# ---------------------------------------------------------------------------
# serve: caches, prefill, decode
# ---------------------------------------------------------------------------


def _block_cache_spec(cfg, mixer: str, batch: int, cache_len: int, mem_len: int, dtype):
    """Zeroed cache for one layer of one period position."""
    cache: dict[str, Any] = {}
    if mixer == "attn":
        kvh, hd = cfg.n_kv_heads, cfg.hd
        cache["k"] = jnp.zeros((batch, cache_len, kvh, hd), dtype)
        cache["v"] = jnp.zeros((batch, cache_len, kvh, hd), dtype)
    elif mixer == "mamba":
        cache.update(mamba_init_cache(batch, cfg.d_model, cfg.ssm, dtype))
    elif mixer == "xattn":
        kvh, hd = cfg.n_kv_heads, cfg.hd
        cache["xk"] = jnp.zeros((batch, mem_len, kvh, hd), dtype)
        cache["xv"] = jnp.zeros((batch, mem_len, kvh, hd), dtype)
    if cfg.family == "audio":
        kvh, hd = cfg.n_kv_heads, cfg.hd
        cache["xk"] = jnp.zeros((batch, mem_len, kvh, hd), dtype)
        cache["xv"] = jnp.zeros((batch, mem_len, kvh, hd), dtype)
    return cache


def init_caches(cfg, batch: int, cache_len: int, dtype=None) -> tuple:
    """Tuple over period positions; leaves lead with n_reps."""
    dtype = dtype or dtype_of(cfg.compute_dtype)
    pattern = cfg.pattern_kinds()
    n_reps = cfg.n_layers // len(pattern)
    mem_len = cfg.encoder_seq if cfg.family == "audio" else (cfg.img_tokens or 1)
    caches = []
    for mixer, _ in pattern:
        one = _block_cache_spec(cfg, mixer, batch, cache_len, mem_len, dtype)
        caches.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (n_reps,) + x.shape), one))
    return tuple(caches)


def _project_xkv(bp: Params, memory: jax.Array, cfg):
    kvh, hd = cfg.n_kv_heads, cfg.hd
    B, Skv, _ = memory.shape
    k = (memory @ bp["xattn"]["wk"].astype(memory.dtype)).reshape(B, Skv, kvh, hd)
    v = (memory @ bp["xattn"]["wv"].astype(memory.dtype)).reshape(B, Skv, kvh, hd)
    return k, v


def prefill(
    params: Params,
    batch: dict,
    cfg,
    cache_len: int | None = None,
    plan: ShardingPlan = NOPLAN,
    *,
    attn_chunk: int = 2048,
) -> tuple[jax.Array, tuple]:
    """Process the whole prompt; return (last-position logits (B, V), caches).

    KV caches are allocated at `cache_len` (>= prompt length) and written in
    [0, S).  Mamba caches carry the post-prompt state."""
    pattern = cfg.pattern_kinds()
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    memory = _memory_of(params, batch, cfg, plan)
    x = _embed_tokens(params, tokens, cfg, plan)
    cd = dtype_of(cfg.compute_dtype)
    nk = _norm_kind(cfg)

    def period_fn(x, per_rep):
        new_caches = []
        for pos, (mixer, ffn) in enumerate(pattern):
            bp = per_rep[pos]
            h = norm_apply(nk, bp["norm1"], x, cfg.norm_eps)
            cache: dict[str, Any] = {}
            if mixer == "attn":
                y, kv = self_attention_prefill(bp["attn"], h, cfg, chunk=attn_chunk, plan=plan)
                x = x + y
                pad = cache_len - S
                # two-step reshard into the cache layout: head-partial ->
                # replicated-heads (cheap per-layer all-gather) -> seq-sharded
                # (local slice); the direct reshard makes SPMD replicate a
                # cache-sized buffer (16 GiB on grok-1 prefill_32k)
                from jax.sharding import PartitionSpec as P

                rep4 = P(plan.dp or None, None, None, None)
                for key_, t in (("k", kv["k"]), ("v", kv["v"])):
                    t = shard(t.astype(cd), rep4, plan)
                    t = jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    cache[key_] = shard(t, plan.kv_cache(cfg.n_kv_heads), plan)
            elif mixer == "mamba":
                y, (hstate, conv) = mamba_train(bp["mamba"], h, cfg, return_state=True)
                x = x + y
                cache["h"] = shard(hstate, plan.ssm_state(), plan)
                cache["conv"] = conv.astype(cd)
            elif mixer == "xattn":
                xk, xv = _project_xkv(bp, memory, cfg)
                y, _ = cross_attention(bp["xattn"], h, None, cfg, {"k": xk, "v": xv}, plan=plan)
                x = x + jnp.tanh(bp["gate_attn"]).astype(x.dtype) * y
                cache["xk"], cache["xv"] = xk.astype(cd), xv.astype(cd)
            if cfg.family == "audio":
                xk, xv = _project_xkv(bp, memory, cfg)
                hx = norm_apply(nk, bp["norm_x"], x, cfg.norm_eps)
                y, _ = cross_attention(bp["xattn"], hx, None, cfg, {"k": xk, "v": xv}, plan=plan)
                x = x + y
                cache["xk"], cache["xv"] = xk.astype(cd), xv.astype(cd)
            x, _ = _apply_ffn(bp, x, cfg, ffn, plan)
            x = shard(x, plan.hidden(), plan)
            new_caches.append(cache)
        return x, tuple(new_caches)

    x, caches = _scan_or_unroll(cfg, period_fn, x, params["blocks"])
    last = x[:, -1:]
    logits = lm_logits(params, last, cfg, plan)[:, 0]
    return logits, caches


def decode_step(
    params: Params,
    tokens: jax.Array,  # (B, 1)
    pos: jax.Array,  # (B,)
    caches: tuple,
    batch: dict,
    cfg,
    plan: ShardingPlan = NOPLAN,
) -> tuple[jax.Array, tuple]:
    """One new token against the caches.  Returns (logits (B, V), caches)."""
    pattern = cfg.pattern_kinds()
    nk = _norm_kind(cfg)
    x = _embed_tokens(params, tokens, cfg, plan, pos=pos)

    def period_fn(x, inp):
        per_rep, cache_in = inp
        new_caches = []
        for p_, (mixer, ffn) in enumerate(pattern):
            bp, cache = per_rep[p_], cache_in[p_]
            h = norm_apply(nk, bp["norm1"], x, cfg.norm_eps)
            if mixer == "attn":
                y, kv = self_attention_decode(bp["attn"], h, cache, pos, cfg, plan=plan)
                x = x + y
                cache = {**cache, "k": kv["k"], "v": kv["v"]}
            elif mixer == "mamba":
                y, cache = mamba_decode(bp["mamba"], h, cache, cfg)
                x = x + y
            elif mixer == "xattn":
                y, _ = cross_attention(bp["xattn"], h, None, cfg, {"k": cache["xk"], "v": cache["xv"]}, plan=plan)
                x = x + jnp.tanh(bp["gate_attn"]).astype(x.dtype) * y
            if cfg.family == "audio":
                hx = norm_apply(nk, bp["norm_x"], x, cfg.norm_eps)
                y, _ = cross_attention(bp["xattn"], hx, None, cfg, {"k": cache["xk"], "v": cache["xv"]}, plan=plan)
                x = x + y
            x, _ = _apply_ffn(bp, x, cfg, ffn)
            new_caches.append(cache)
        return x, tuple(new_caches)

    decode_cfg = dataclasses.replace(cfg, remat=False)  # no remat in decode
    x, new_caches = _scan_or_unroll(decode_cfg, period_fn, x, (params["blocks"], caches))
    logits = lm_logits(params, x, cfg, plan)[:, 0]
    return logits, new_caches
