"""Mixture-of-Experts with the paper's memory-controller dispatch.

MoE token->expert dispatch is an spMTTKRP-shaped problem: a sparse
(token, expert) assignment stream drives gathers of dense rows.  The two
dispatch modes mirror the paper's Sec. 3 compute patterns exactly:

  * ``remap``  (Approach 1, the paper's choice): counting-sort the assignment
    stream by expert id (the Tensor Remapper), giving contiguous per-expert
    buffers -> dense per-expert GEMMs, **no** (T, E, C) partial tensors.  The
    sort runs along the *intra-group* axis, which sharding keeps local to a
    device — the per-device sort is the per-SLR memory controller.
  * ``onehot`` (Approach 2 baseline): classic one-hot dispatch einsum that
    materializes a (T, E, C) dispatch tensor — the DRAM partial sums of
    Alg. 4, kept as the comparison baseline.

Both produce identical outputs when no token is dropped (tested); they differ
only in memory traffic, which is the paper's entire point.

Sharding contract (dist/sharding.py): tokens arrive grouped (G, Tg, D) with G
on the data axes; expert weights (E, D, F) shard F over `model`.  The expert
GEMM is then local in E and G, and the down-projection's F-contraction
induces the single all-reduce per MoE layer (same collective as a dense TP
FFN — the dispatch itself adds zero communication).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import GLU_ACTS, Params, dense_init, is_glu

__all__ = [
    "moe_init",
    "router_topk",
    "capacity",
    "moe_apply",
    "dispatch_remap",
    "dispatch_onehot",
    "experts_ffn",
]


def moe_init(key: jax.Array, d: int, moe_cfg, act: str, dtype=jnp.float32) -> Params:
    E, f = moe_cfg.num_experts, moe_cfg.d_ff
    ks = jax.random.split(key, 4)

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))(jax.random.split(k, E))

    p: Params = {
        "router": dense_init(ks[0], d, E, dtype, scale=0.02),
        "wu": stack(ks[2], d, f),
        "wd": stack(ks[3], f, d),
    }
    if is_glu(act):
        p["wg"] = stack(ks[1], d, f)
    return p


def capacity(tokens_per_group: int, moe_cfg) -> int:
    """Per-group expert capacity, padded to an 8-row sublane multiple."""
    c = int(tokens_per_group * moe_cfg.top_k * moe_cfg.capacity_factor / moe_cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def router_topk(
    p: Params, x: jax.Array, moe_cfg
) -> tuple[jax.Array, jax.Array, jax.Array, dict[str, jax.Array]]:
    """Router: softmax over experts, take top-k.  x: (..., Tg, D).
    Returns (expert_ids (..., Tg, k), combine_w (..., Tg, k), probs, aux)."""
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (..., Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, moe_cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # renormalize over k
    # Aux losses: load-balance (Switch) + router z-loss.
    E = moe_cfg.num_experts
    me = probs.mean(axis=-2)  # (..., E) mean prob per expert
    ce = jax.nn.one_hot(ids[..., 0], E).mean(axis=-2)  # top-1 routed fraction
    lb = E * jnp.sum(me * ce, axis=-1).mean()
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return ids, w, probs, {"load_balance": lb, "router_z": z}


# ---------------------------------------------------------------------------
# Approach 1: remap dispatch (counting sort by expert — the Tensor Remapper)
# ---------------------------------------------------------------------------


def dispatch_remap(
    x: jax.Array,  # (Tg, D) one group's tokens
    ids: jax.Array,  # (Tg, k)
    E: int,
    C: int,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Sort the (token, expert) assignment stream by expert id and scatter
    tokens into contiguous per-expert buffers.  Returns (buffers (E, C, D),
    meta for combine).  Over-capacity assignments drop (standard MoE)."""
    Tg, k = ids.shape
    e_flat = ids.reshape(Tg * k)
    tok_flat = jnp.repeat(jnp.arange(Tg), k)  # token of each assignment
    # --- the remap: stable counting sort by output coordinate (expert id) ---
    perm = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[perm]
    tok_sorted = tok_flat[perm]
    # position within expert = rank - start_of_expert_run (the pointer table)
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(Tg * k, dtype=jnp.int32) - starts[e_sorted]
    keep = slot < C
    # scatter rows into (E*C, D); dropped rows go out-of-bounds -> mode=drop
    dest = jnp.where(keep, e_sorted * C + slot, E * C)
    buffers = jnp.zeros((E * C, x.shape[-1]), x.dtype)
    buffers = buffers.at[dest].set(x[tok_sorted], mode="drop")
    meta = {"dest": dest, "tok_sorted": tok_sorted, "perm": perm, "keep": keep}
    return buffers.reshape(E, C, x.shape[-1]), meta


def combine_remap(
    expert_out: jax.Array,  # (E, C, D)
    meta: dict[str, jax.Array],
    w_flat_unsorted: jax.Array,  # (Tg*k,) combine weights in assignment order
    Tg: int,
) -> jax.Array:
    """Gather expert outputs back per assignment, weight, and sum the k
    contributions of each token."""
    D = expert_out.shape[-1]
    rows = expert_out.reshape(-1, D).at[meta["dest"]].get(mode="fill", fill_value=0.0)
    w = w_flat_unsorted[meta["perm"]]
    rows = rows * w[:, None].astype(rows.dtype)
    out = jnp.zeros((Tg, D), rows.dtype).at[meta["tok_sorted"]].add(rows)
    return out


# ---------------------------------------------------------------------------
# Approach 2: one-hot dispatch (materialized (Tg, E, C) partials — baseline)
# ---------------------------------------------------------------------------


def dispatch_onehot(
    x: jax.Array,  # (Tg, D)
    ids: jax.Array,  # (Tg, k)
    w: jax.Array,  # (Tg, k)
    E: int,
    C: int,
) -> tuple[jax.Array, jax.Array]:
    """Classic mesh-tf dispatch: build a (Tg, E, C) one-hot dispatch tensor.
    Slot priority is token-major over the flattened (token, choice) stream —
    exactly the stable counting sort's order — so the two dispatch modes
    agree bit-for-bit including *which* assignments drop over capacity."""
    Tg, k = ids.shape
    e_flat = ids.reshape(Tg * k)  # token-major, same as dispatch_remap
    oh_e = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)  # (Tg*k, E)
    pos = jnp.cumsum(oh_e, axis=0) - 1  # running rank within each expert
    slot = jnp.sum(oh_e * pos, axis=-1)  # (Tg*k,)
    keep = slot < C
    oh = (
        jax.nn.one_hot(e_flat, E, dtype=x.dtype)[:, :, None]
        * jax.nn.one_hot(jnp.where(keep, slot, C), C + 1, dtype=x.dtype)[:, None, :C]
    )  # (Tg*k, E, C)
    oh = oh.reshape(Tg, k, E, C)
    dispatch = oh.sum(axis=1)
    combine = (oh.astype(jnp.float32) * w[:, :, None, None]).sum(axis=1)
    return dispatch, combine


# ---------------------------------------------------------------------------
# Expert FFN + full layer
# ---------------------------------------------------------------------------


def experts_ffn(p: Params, buffers: jax.Array, act: str) -> jax.Array:
    """Dense per-expert GEMMs on (..., E, C, D) buffers (MXU-friendly)."""
    if is_glu(act):
        g = GLU_ACTS[act](jnp.einsum("...ecd,edf->...ecf", buffers, p["wg"].astype(buffers.dtype)))
        u = jnp.einsum("...ecd,edf->...ecf", buffers, p["wu"].astype(buffers.dtype))
        h = g * u
    else:
        h = jax.nn.gelu(jnp.einsum("...ecd,edf->...ecf", buffers, p["wu"].astype(buffers.dtype)))
    return jnp.einsum("...ecf,efd->...ecd", h, p["wd"].astype(buffers.dtype))


def moe_apply(
    p: Params,
    x: jax.Array,  # (G, Tg, D) grouped tokens (G on the data axes)
    moe_cfg,
    act: str,
    plan=None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Full MoE layer.  Dispatch mode per moe_cfg.dispatch.

    The expert GEMM runs *between* two vmapped dispatch/combine stages with
    explicit sharding constraints on the (G, E, C, D) buffers: the sort/
    scatter ops inside dispatch otherwise make the SPMD partitioner drop the
    G sharding and replicate expert activations across the data axes (seen
    as GiB-scale f32 buffers + all-reduces in the grok-1 dry-run)."""
    G, Tg, D = x.shape
    E, k = moe_cfg.num_experts, moe_cfg.top_k
    C = capacity(Tg, moe_cfg)
    ids, w, _, aux = router_topk(p, x, moe_cfg)

    def constrain(t, spec_ndim):
        if plan is None or plan.mesh is None:
            return t
        from ..dist.sharding import shard
        from jax.sharding import PartitionSpec as P

        return shard(t, P(plan.dp or None, *(None,) * (spec_ndim - 1)), plan)

    if moe_cfg.dispatch == "remap":
        buffers, meta = jax.vmap(lambda xg, idsg: dispatch_remap(xg, idsg, E, C))(x, ids)
        buffers = constrain(buffers, 4)  # (G, E, C, D): G stays on dp
        out_e = experts_ffn(p, buffers, act)
        out_e = constrain(out_e, 4)
        out = jax.vmap(lambda oe, m, wg: combine_remap(oe, m, wg.reshape(-1), Tg))(
            out_e, meta, w
        )
    elif moe_cfg.dispatch == "onehot":
        dispatch, combine = jax.vmap(lambda xg, idsg, wg: dispatch_onehot(xg, idsg, wg, E, C))(x, ids, w)
        buffers = jnp.einsum("gtec,gtd->gecd", dispatch, x)
        buffers = constrain(buffers, 4)
        out_e = experts_ffn(p, buffers, act)
        out_e = constrain(out_e, 4)
        out = jnp.einsum("gtec,gecd->gtd", combine.astype(out_e.dtype), out_e)
    else:
        raise ValueError(f"unknown dispatch {moe_cfg.dispatch!r}")
    return constrain(out, 3), aux
