"""Model substrate: composable JAX layers for the assigned architectures."""
