"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Three execution paths over the same parameters:
  * ``ssd_chunked``   — production path: chunked matmul form (intra-chunk
                        attention-like matmuls on the MXU + an inter-chunk
                        `lax.scan` over per-chunk states).  Sub-quadratic:
                        O(S·Q) score work + O(S/Q) state hops, the reason the
                        ssm/hybrid archs run the ``long_500k`` shape.
  * ``ssd_reference`` — naive per-token recurrence (lax.scan over S); the
                        oracle the chunked path is tested against.
  * ``ssd_decode_step`` — one-token state update for serving.

Layout: x (B, S, H, P) heads x head_dim; B/C (B, S, G, N) groups x state;
dt (B, S, H).  State h is (B, H, P, N), fp32 throughout the recurrence.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import Params, dense_init, rmsnorm

__all__ = [
    "ssd_reference",
    "ssd_chunked",
    "ssd_decode_step",
    "mamba_init",
    "mamba_train",
    "mamba_decode",
    "mamba_init_cache",
    "causal_conv1d",
    "conv1d_decode_step",
]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _expand_groups(bc: jax.Array, H: int) -> jax.Array:
    """(B, S, G, N) -> (B, S, H, N): broadcast each group over its heads."""
    G = bc.shape[2]
    return jnp.repeat(bc, H // G, axis=2)


def ssd_reference(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (post-softplus)
    A: jax.Array,  # (H,) negative reals
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    D: jax.Array | None = None,  # (H,)
    h0: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Token-by-token recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T;
    y_t = C_t h_t (+ D x_t).  Returns (y (B,S,H,P), h_final)."""
    Bsz, S, H, P = x.shape
    Bh = _expand_groups(Bm, H).astype(jnp.float32)
    Ch = _expand_groups(Cm, H).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf * A[None, None, :])  # (B, S, H)
    h = jnp.zeros((Bsz, H, P, x.shape[-1] * 0 + Bm.shape[-1]), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, at, dtt, bt, ct = inp  # (B,H,P) (B,H) (B,H) (B,H,N) (B,H,N)
        h = at[..., None, None] * h + jnp.einsum("bhp,bhn->bhpn", dtt[..., None] * xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (
        xf.transpose(1, 0, 2, 3),
        a.transpose(1, 0, 2),
        dtf.transpose(1, 0, 2),
        Bh.transpose(1, 0, 2, 3),
        Ch.transpose(1, 0, 2, 3),
    )
    h, ys = jax.lax.scan(step, h, xs)
    y = ys.transpose(1, 0, 2, 3)  # (B, S, H, P)
    if D is not None:
        y = y + xf * D[None, None, :, None]
    return y.astype(x.dtype), h


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    D: jax.Array | None = None,
    h0: jax.Array | None = None,
    *,
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD (Mamba2 Alg. 1 structure).  Per chunk of length Q:

      intra:  Y1[t] = sum_{s<=t} (C_t.B_s) dt_s exp(l_t - l_s) x_s      (matmuls)
      state:  S_c   = sum_s exp(l_Q - l_s) dt_s x_s (x) B_s             (matmul)
      inter:  H_c   = exp(l_Q) H_{c-1} + S_c                            (scan)
              Y2[t] = C_t . (exp(l_t) H_{c-1})

    All recurrences are over S/Q chunk states only.  fp32 internally."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:  # pad tail with dt=0 steps: a=exp(0)=1, contribution 0 — the
        pad = Q - S % Q  # state is untouched and padded outputs are discarded.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, h = ssd_chunked(x, dt, A, Bm, Cm, D, h0, chunk=Q)
        return y[:, :S], h
    nc = S // Q

    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bh = _expand_groups(Bm, H).astype(jnp.float32).reshape(Bsz, nc, Q, H, N)
    Ch = _expand_groups(Cm, H).astype(jnp.float32).reshape(Bsz, nc, Q, H, N)

    loga = dtf * A[None, None, None, :]  # (B, nc, Q, H) log decay per step
    l = jnp.cumsum(loga, axis=2)  # inclusive cumulative log decay
    ltot = l[:, :, -1]  # (B, nc, H) chunk total

    # --- intra-chunk (attention-like, lower-triangular) ---
    # M[t,s] = (C_t . B_s) * dt_s * exp(l_t - l_s), s <= t
    cb = jnp.einsum("bcqhn,bcshn->bchqs", Ch, Bh)  # (B, nc, H, Q, Q)
    # exp(l_t - l_s): build (B, nc, H, Q, Q)
    lt = l.transpose(0, 1, 3, 2)  # (B, nc, H, Q)
    delta = lt[..., :, None] - lt[..., None, :]  # l_t - l_s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    seg = jnp.where(tri[None, None, None], jnp.exp(delta), 0.0)
    M = cb * seg * dtf.transpose(0, 1, 3, 2)[..., None, :]  # * dt_s
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", M, xf)

    # --- per-chunk states ---
    # S_c = sum_s exp(ltot - l_s) dt_s x_s (x) B_s   -> (B, nc, H, P, N)
    w = jnp.exp(ltot[:, :, None, :] - l) * dtf  # (B, nc, Q, H)
    Sc = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn", w, xf, Bh)

    # --- inter-chunk scan over nc states ---
    h_init = (
        jnp.zeros((Bsz, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )

    def chunk_step(h, inp):
        sc, lt_c = inp  # (B,H,P,N), (B,H)
        h_out = h  # state *entering* this chunk
        h = jnp.exp(lt_c)[..., None, None] * h + sc
        return h, h_out

    h_final, h_enter = jax.lax.scan(
        chunk_step, h_init, (Sc.transpose(1, 0, 2, 3, 4), ltot.transpose(1, 0, 2))
    )
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N) state before chunk

    # --- inter-chunk contribution ---
    # Y2[t] = exp(l_t) * C_t . H_enter
    y_inter = jnp.exp(l)[..., None] * jnp.einsum("bcqhn,bchpn->bcqhp", Ch, h_enter)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_final


def ssd_decode_step(
    h: jax.Array,  # (B, H, P, N) fp32 state
    x: jax.Array,  # (B, H, P)
    dt: jax.Array,  # (B, H) post-softplus
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, G, N)
    Cm: jax.Array,  # (B, G, N)
    D: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One-token SSD update. Returns (y (B,H,P), h_new)."""
    H = x.shape[1]
    G = Bm.shape[1]
    Bh = jnp.repeat(Bm, H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm, H // G, axis=1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf * A[None, :])  # (B, H)
    h = a[..., None, None] * h + jnp.einsum("bhp,bhn->bhpn", dtf[..., None] * xf, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch)
    if D is not None:
        y = y + xf * D[None, :, None]
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# causal depthwise conv1d (the Mamba front conv)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """x (B, S, C), w (K, C), b (C).  Left-pad with `state` (B, K-1, C) (zeros
    if None).  Returns (y (B,S,C) silu-activated, new_state = last K-1 inputs)."""
    Bsz, S, C = x.shape
    K = w.shape[0]
    pad = jnp.zeros((Bsz, K - 1, C), x.dtype) if state is None else state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    y = jnp.zeros((Bsz, S, C), jnp.float32)
    for k in range(K):
        y = y + xp[:, k : k + S].astype(jnp.float32) * w[k].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32))
    new_state = xp[:, S:]  # last K-1 raw inputs
    return y.astype(x.dtype), new_state


def conv1d_decode_step(x: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, C) one token; state (B, K-1, C). Returns (y (B,C), new_state)."""
    K = w.shape[0]
    window = jnp.concatenate([state, x[:, None]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    y = jax.nn.silu(y + b.astype(jnp.float32))
    return y.astype(x.dtype), window[:, 1:]


# ---------------------------------------------------------------------------
# full Mamba2 block
# ---------------------------------------------------------------------------


def mamba_init(key: jax.Array, d: int, ssm_cfg, dtype=jnp.float32) -> Params:
    """Mamba2 block parameters.  in_proj fans out to
    [z (d_in) | x (d_in) | B (G*N) | C (G*N) | dt (H)]; conv runs over
    [x | B | C]; gated RMSNorm before out_proj (Mamba2 convention)."""
    s = ssm_cfg
    d_in = s.expand * d
    H = d_in // s.head_dim
    G, N = s.n_groups, s.d_state
    conv_dim = d_in + 2 * G * N
    ks = jax.random.split(key, 3)
    dt_bias = jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32, jnp.log(1e-3), jnp.log(1e-1)))))
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * G * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32) * (1.0 / jnp.sqrt(s.d_conv * 1.0))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),  # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "out_norm": {"scale": jnp.ones((d_in,), dtype)},
        "out_proj": dense_init(ks[2], d_in, d, dtype),
    }


def _mamba_split(p: Params, xz: jax.Array, d_in: int, G: int, N: int, H: int):
    z, rest = xz[..., :d_in], xz[..., d_in:]
    xbc = rest[..., : d_in + 2 * G * N]
    dt_raw = rest[..., d_in + 2 * G * N :]  # (..., H)
    return z, xbc, dt_raw


def mamba_train(p: Params, x: jax.Array, cfg, h0=None, conv0=None, *, return_state: bool = False):
    """Full-sequence Mamba2 block.  x (B, S, D) -> (B, S, D).
    With return_state=True also returns (h_final, conv_state) for prefill."""
    s = cfg.ssm
    d = x.shape[-1]
    d_in = s.expand * d
    H = d_in // s.head_dim
    G, N = s.n_groups, s.d_state
    Bsz, S, _ = x.shape

    xz = x @ p["in_proj"].astype(x.dtype)  # (B, S, 2*d_in + 2GN + H)
    z, xbc, dt_raw = _mamba_split(p, xz, d_in, G, N, H)
    xbc, conv_state = causal_conv1d(xbc, p["conv_w"], p["conv_b"], conv0)
    xs = xbc[..., :d_in].reshape(Bsz, S, H, s.head_dim)
    Bm = xbc[..., d_in : d_in + G * N].reshape(Bsz, S, G, N)
    Cm = xbc[..., d_in + G * N :].reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, h = ssd_chunked(xs, dt, A, Bm, Cm, p["D"], h0, chunk=s.chunk)
    y = y.reshape(Bsz, S, d_in)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))  # gated norm
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, (h, conv_state)
    return out


def mamba_init_cache(batch: int, d: int, ssm_cfg, dtype=jnp.float32) -> dict[str, jax.Array]:
    s = ssm_cfg
    d_in = s.expand * d
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return {
        "h": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


def mamba_decode(p: Params, x: jax.Array, cache: dict[str, jax.Array], cfg):
    """One-token Mamba2 step.  x (B, 1, D) -> (B, 1, D), updated cache."""
    s = cfg.ssm
    d = x.shape[-1]
    d_in = s.expand * d
    H = d_in // s.head_dim
    G, N = s.n_groups, s.d_state
    Bsz = x.shape[0]

    xz = x[:, 0] @ p["in_proj"].astype(x.dtype)  # (B, ...)
    z, xbc, dt_raw = _mamba_split(p, xz, d_in, G, N, H)
    xbc, conv_state = conv1d_decode_step(xbc, p["conv_w"], p["conv_b"], cache["conv"])
    xs = xbc[..., :d_in].reshape(Bsz, H, s.head_dim)
    Bm = xbc[..., d_in : d_in + G * N].reshape(Bsz, G, N)
    Cm = xbc[..., d_in + G * N :].reshape(Bsz, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    y, h = ssd_decode_step(cache["h"], xs, dt, A, Bm, Cm, p["D"])
    y = y.reshape(Bsz, d_in)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = (y @ p["out_proj"].astype(x.dtype))[:, None]
    return out, {"h": h, "conv": conv_state}
