"""Attention: GQA self-attention (train/prefill + cached decode) and
cross-attention (whisper enc-dec, vlm image layers).

Memory posture (the paper's lens applied to attention): scores are never
materialized at (S x S).  `causal_attention` walks query chunks with a
*static* growing KV slice — block-lower-triangular, so HLO FLOPs match the
causal work (~S^2/2) instead of the dense S^2, and peak score memory is
(B, H, chunk, S).

Sharding: scores are computed FLAT over heads (KV broadcast to H heads —
identical math to grouped GQA) so the model axis can shard them: the
(B, KVH, G, Sq, Sk) grouped layout cannot shard KVH=8 over 16-way TP and
replicates multi-GiB score buffers (measured 25 GiB/device on grok-1
prefill_32k).  `ShardingPlan.scores()` prefers the head dim and falls back
to the query-chunk dim when H doesn't divide the axis (qwen2's 12 heads,
whisper's 20).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..dist.sharding import NOPLAN, ShardingPlan, shard
from .layers import Params, dense_init, rmsnorm, apply_rope, rope_angles

NEG_INF = -1e30


def attn_init(
    key: jax.Array,
    d: int,
    n_heads: int,
    n_kv: int,
    hd: int,
    *,
    qkv_bias: bool = False,
    qk_norm: bool = False,
    dtype=jnp.float32,
) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, n_kv * hd, dtype),
        "wv": dense_init(ks[2], d, n_kv * hd, dtype),
        "wo": dense_init(ks[3], n_heads * hd, d, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((n_kv * hd,), dtype)
        p["bv"] = jnp.zeros((n_kv * hd,), dtype)
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((hd,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dtype)}
    return p


def qkv_project(
    p: Params,
    x: jax.Array,
    n_heads: int,
    n_kv: int,
    hd: int,
    *,
    eps: float = 1e-6,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Project + reshape (+ optional per-head qk rmsnorm, qwen3-style)."""
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, n_heads, hd)
    k = k.reshape(B, S, n_kv, hd)
    v = v.reshape(B, S, n_kv, hd)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, eps)
        k = rmsnorm(p["k_norm"], k, eps)
    return q, k, v


def _repeat_kv(t: jax.Array, G: int) -> jax.Array:
    """(B, S, KVH, hd) -> (B, S, KVH*G, hd); head h reads kv-head h // G
    (matches the (KVH, G) reshape convention of grouped GQA)."""
    return jnp.repeat(t, G, axis=2) if G > 1 else t


def _attend(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KVH, hd)
    v: jax.Array,
    mask: jax.Array | None,  # broadcastable to (B, 1, Sq, Sk); True = visible
    plan: ShardingPlan,
) -> jax.Array:
    """Flat-head attention core.  Returns (B, Sq, H, hd)."""
    H, hd = q.shape[2], q.shape[3]
    G = H // k.shape[2]
    kr = _repeat_kv(k, G)
    vr = _repeat_kv(v, G)
    s = jnp.einsum("bqhe,bshe->bhqs", q, kr, preferred_element_type=jnp.float32)
    s = s * (hd**-0.5)
    s = shard(s, plan.scores(H), plan)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshe->bqhe", w, vr)


def causal_attention(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, KVH, hd)
    v: jax.Array,
    *,
    chunk: int = 2048,
    plan: ShardingPlan = NOPLAN,
) -> jax.Array:
    """Block-lower-triangular causal attention.  Query chunk c attends to the
    static slice kv[: (c+1)*chunk]; softmax is exact per row (the full visible
    prefix is present), so no online-softmax carry is needed."""
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nchunks = S // chunk

    qpos = jnp.arange(chunk)
    diag_mask = qpos[:, None] >= jnp.arange(chunk)[None, :]  # (chunk, chunk)

    outs = []
    for c in range(nchunks):
        qs = jax.lax.slice_in_dim(q, c * chunk, (c + 1) * chunk, axis=1)
        kv_len = (c + 1) * chunk
        ks = jax.lax.slice_in_dim(k, 0, kv_len, axis=1)
        vs = jax.lax.slice_in_dim(v, 0, kv_len, axis=1)
        # mask only the diagonal block; earlier blocks are fully visible
        mask = jnp.concatenate([jnp.ones((chunk, c * chunk), bool), diag_mask], axis=1)
        outs.append(_attend(qs, ks, vs, mask[None, None], plan))
    return jnp.concatenate(outs, axis=1)


def full_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KVH, hd)
    v: jax.Array,
    mask: jax.Array | None = None,  # (Sq, Sk), True = visible
    plan: ShardingPlan = NOPLAN,
) -> jax.Array:
    """Unchunked attention (encoder / cross-attention / short sequences)."""
    return _attend(q, k, v, None if mask is None else mask[None, None], plan)


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd) — the new token's query
    k_cache: jax.Array,  # (B, S, KVH, hd)
    v_cache: jax.Array,
    pos: jax.Array,  # (B,) int32 — index of the new token in the cache
    plan: ShardingPlan = NOPLAN,
) -> jax.Array:
    """One-token attention over the KV cache, masked to positions <= pos."""
    S = k_cache.shape[1]
    visible = jnp.arange(S)[None, :] <= pos[:, None]  # (B, S)
    return _attend(q, k_cache, v_cache, visible[:, None, None, :], plan)


# ---------------------------------------------------------------------------
# Self-attention block entry points used by transformer.py
# ---------------------------------------------------------------------------


def self_attention_train(
    p: Params,
    x: jax.Array,
    cfg,
    positions: jax.Array | None = None,
    *,
    chunk: int = 2048,
    causal: bool = True,
    plan: ShardingPlan = NOPLAN,
) -> jax.Array:
    """Full-sequence self-attention (train / prefill)."""
    B, S, _ = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = qkv_project(p, x, H, KVH, hd, eps=cfg.norm_eps)
    if positions is None:
        positions = jnp.arange(S)
    if cfg.rope_theta > 0:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if causal:
        out = causal_attention(q, k, v, chunk=chunk, plan=plan)
    else:
        out = full_attention(q, k, v, plan=plan)
    return out.reshape(B, S, H * hd) @ p["wo"].astype(x.dtype)


def self_attention_prefill(
    p: Params, x: jax.Array, cfg, *, chunk: int = 2048, plan: ShardingPlan = NOPLAN
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Prefill: causal attention + return the (rope'd) KV for the cache."""
    B, S, _ = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = qkv_project(p, x, H, KVH, hd, eps=cfg.norm_eps)
    positions = jnp.arange(S)
    if cfg.rope_theta > 0:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    out = causal_attention(q, k, v, chunk=chunk, plan=plan)
    y = out.reshape(B, S, H * hd) @ p["wo"].astype(x.dtype)
    return y, {"k": k, "v": v}


def self_attention_decode(
    p: Params,
    x: jax.Array,  # (B, 1, D)
    cache: dict[str, jax.Array],  # k/v (B, S, KVH, hd)
    pos: jax.Array,  # (B,) int32
    cfg,
    plan: ShardingPlan = NOPLAN,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One decode step: write the new KV at `pos`, attend over [0, pos]."""
    B = x.shape[0]
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q, k, v = qkv_project(p, x, H, KVH, hd, eps=cfg.norm_eps)
    if cfg.rope_theta > 0:
        cos, sin = rope_angles(pos[:, None], hd, cfg.rope_theta)  # (B,1,hd/2)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # Replicate the (tiny) new KV over the model axis BEFORE the scatter:
    # head-partial k_new broadcast against the seq-sharded cache otherwise
    # forces SPMD's "involuntary full rematerialization" of a cache-sized
    # buffer (measured GiB-scale on grok-1 decode_32k).
    from jax.sharding import PartitionSpec as P

    k = shard(k, P(plan.dp or None, None, None, None), plan)
    v = shard(v, P(plan.dp or None, None, None, None), plan)
    # Scatter the new token's KV into the cache at per-batch positions.
    onehot = (jnp.arange(cache["k"].shape[1])[None, :] == pos[:, None]).astype(k.dtype)
    k_cache = cache["k"] * (1 - onehot)[..., None, None] + onehot[..., None, None] * k
    v_cache = cache["v"] * (1 - onehot)[..., None, None] + onehot[..., None, None] * v
    out = decode_attention(q, k_cache, v_cache, pos, plan=plan)
    y = out.reshape(B, 1, H * hd) @ p["wo"].astype(x.dtype)
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder / llama-vision image layers)
# ---------------------------------------------------------------------------


def xattn_init(key: jax.Array, d: int, n_heads: int, n_kv: int, hd: int, dtype=jnp.float32) -> Params:
    return attn_init(key, d, n_heads, n_kv, hd, dtype=dtype)


def cross_attention(
    p: Params,
    x: jax.Array,  # (B, Sq, D) queries (text/decoder stream)
    kv_src: jax.Array | None,  # (B, Skv, D) memory (encoder / image tokens)
    cfg,
    cached_kv: dict[str, jax.Array] | None = None,
    plan: ShardingPlan = NOPLAN,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Non-causal attention into a memory stream.  Pass `cached_kv` during
    decode to skip reprojecting the (static) memory."""
    B, Sq, _ = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, Sq, H, hd)
    if cached_kv is None:
        assert kv_src is not None
        Skv = kv_src.shape[1]
        k = (kv_src @ p["wk"].astype(x.dtype)).reshape(B, Skv, KVH, hd)
        v = (kv_src @ p["wv"].astype(x.dtype)).reshape(B, Skv, KVH, hd)
        cached_kv = {"k": k, "v": v}
    out = full_attention(q, cached_kv["k"], cached_kv["v"], plan=plan)
    return out.reshape(B, Sq, H * hd) @ p["wo"].astype(x.dtype), cached_kv
