"""Primitive layers shared by every architecture: norms, projections,
embeddings, RoPE, MLPs.

Conventions (used repo-wide):
  * Parameters are nested dicts of jax.Arrays; every leaf is created through
    `init` functions taking an explicit PRNG key, so `jax.eval_shape` over the
    init gives the abstract parameter tree the dry-run lowers against.
  * Compute dtype (bf16 on TPU) is applied at use; params stay in param_dtype.
  * No framework (flax/haiku) — pure functions over pytrees, pjit-friendly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None) -> jax.Array:
    """Truncated-normal fan-in init (LLM standard)."""
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out), jnp.float32) * s).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> Params:
    return rmsnorm_init(d, dtype) if kind == "rms" else layernorm_init(d, dtype)


def norm_apply(kind: str, p: Params, x: jax.Array, eps: float) -> jax.Array:
    return rmsnorm(p, x, eps) if kind == "rms" else layernorm(p, x, eps)


# ---------------------------------------------------------------------------
# linear / embedding
# ---------------------------------------------------------------------------


def linear_init(key: jax.Array, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32) -> Params:
    p = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed(table: jax.Array, ids: jax.Array, compute_dtype) -> jax.Array:
    """Token embedding gather — the dense-arch instance of the paper's
    Cache-Engine access pattern (random row fetch with power-law reuse)."""
    return jnp.take(table, ids, axis=0).astype(compute_dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """(pos..., hd/2) cos/sin tables, fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, heads, hd); cos/sin: (..., seq, hd/2) broadcast over heads.
    Rotate-half convention (llama/qwen)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoid_positions(seq: int, d: int) -> jax.Array:
    """Classic sinusoidal position table (whisper adaptation), (seq, d) f32."""
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * dim / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return jnp.asarray(out, jnp.float32)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


GLU_ACTS = {"silu": jax.nn.silu, "gelu_glu": jax.nn.gelu}  # 3-matrix gated MLPs


def is_glu(act: str) -> bool:
    return act in GLU_ACTS


def mlp_init(key: jax.Array, d: int, d_ff: int, act: str, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    if is_glu(act):  # gated: gate, up, down (SwiGLU / GeGLU)
        return {
            "wg": dense_init(ks[0], d, d_ff, dtype),
            "wu": dense_init(ks[1], d, d_ff, dtype),
            "wd": dense_init(ks[2], d_ff, d, dtype),
        }
    return {  # classic 2-matrix GELU MLP
        "wu": dense_init(ks[0], d, d_ff, dtype),
        "wd": dense_init(ks[1], d_ff, d, dtype),
        "bu": jnp.zeros((d_ff,), dtype),
        "bd": jnp.zeros((d,), dtype),
    }


def mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    if is_glu(act):
        g = GLU_ACTS[act](x @ p["wg"].astype(x.dtype))
        u = x @ p["wu"].astype(x.dtype)
        return (g * u) @ p["wd"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["wu"].astype(x.dtype) + p["bu"].astype(x.dtype))
    return h @ p["wd"].astype(x.dtype) + p["bd"].astype(x.dtype)
