"""Training substrate: optimizer, microbatched train step, checkpointing,
data pipeline."""
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .train_step import TrainState, make_train_step, init_train_state
from .checkpoint import CheckpointManager
