"""AdamW, implemented in-repo (no optax), pjit-friendly.

Distributed-optimization posture:
  * Optimizer state inherits the parameter sharding (FSDP archs therefore get
    ZeRO-style sharded m/v for free through pjit).
  * ``state_dtype`` (bf16 by default for fsdp archs) halves m/v HBM — the
    8-bit/16-bit Adam family of tricks (Dettmers et al.); master math is fp32.
  * Decoupled weight decay, global-norm clipping, linear-warmup cosine decay.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_at"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "float32"  # 'bfloat16' halves m/v memory
    sequential_updates: bool = True  # barrier-chain leaf updates: peak fp32
    # temps become O(largest leaf) instead of O(all params) — the lever that
    # fits 314B-param optimizer steps in 16 GB HBM (EXPERIMENTS.md §Perf)
    update_slices: int = 1  # >1: unrolled sliced update of huge (>=256 MiB)
    # stacked leaves, shrinking the fp32 working set to leaf/nslices
    factored_v: bool = False  # Adafactor-style factored second moment
    # (Shazeer & Stern 2018): for >=2D leaves store row/col running means of
    # g^2 instead of the full tensor — O(n+m) not O(nm).  With first-moment
    # kept, this is "Adam with factored v" (T5 finetuning recipe).  The lever
    # that puts 314B-param optimizer state on one 16 GB-HBM pod.


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup then cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def _v_factored(p) -> bool:
    return p.ndim >= 2


def adamw_init(params: Params, cfg: AdamWConfig) -> dict:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)

    def v_init(p):
        if cfg.factored_v and _v_factored(p):
            return {
                "r": jnp.zeros(p.shape[:-1], jnp.float32),  # rowwise E[g^2]
                "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return zeros(p)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(v_init, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_pspecs(params: Params, p_specs: Params, cfg: AdamWConfig):
    """PartitionSpec tree for adamw_init's state, mirroring its structure
    (factored v leaves are {r, c} dicts with the trailing dim(s) dropped)."""
    from jax.sharding import PartitionSpec as P

    def v_spec(p, s):
        if cfg.factored_v and _v_factored(p):
            e = list(s) + [None] * (p.ndim - len(s))
            return {"r": P(*e[:-1]), "c": P(*e[:-2], e[-1])}
        return s

    return {
        "m": p_specs,
        "v": jax.tree.map(v_spec, params, p_specs),
        "step": P(),
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(
    params: Params, grads: Params, state: dict, cfg: AdamWConfig, shardings: Params | None = None
) -> tuple[Params, dict, dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_state, metrics).

    `shardings`: optional pytree of NamedSharding matching params — re-pins
    intermediate sharding where the serialization chain would otherwise let
    the partitioner replicate (measured: 412 GiB/device without it)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd_math(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * g
        if isinstance(v, dict):  # factored second moment (Adafactor RC^T)
            # row/col means as contractions of g with itself — never
            # materializes g^2 (measured 4.5 GiB/device of f32 expert-stack
            # squares on grok-1 with the naive mean(g*g) form)
            r = b2 * v["r"] + (1 - b2) * jnp.einsum("...ij,...ij->...i", g, g) / g.shape[-1]
            c = b2 * v["c"] + (1 - b2) * jnp.einsum("...ij,...ij->...j", g, g) / g.shape[-2]
            denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True), 1e-30)
            vf = (r / denom)[..., None] * c[..., None, :]
            new_v = {"r": r, "c": c}
        else:
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            new_v = vf
        upd = (mf / c1) / (jnp.sqrt(vf / c2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * upd
        if not isinstance(v, dict):
            new_v = new_v.astype(v.dtype)  # factored r/c stay fp32 (tiny)
        return newp.astype(p.dtype), mf.astype(m.dtype), new_v

    def upd(p, g, m, v):
        # Sliced update for layer-stacked leaves: unrolled slices (NOT a
        # scan — scan xs hoist whole-stack fp32 converts out of the loop,
        # measured +9 GiB) with barrier chaining, so the fp32 working set is
        # one slice at a time.  Slices only pay off for multi-GiB leaves.
        nslices = cfg.update_slices
        if nslices > 1 and p.ndim >= 3 and p.shape[0] % nslices == 0 and p.size >= (1 << 28):
            outs = []
            tok = jnp.zeros((), jnp.float32)
            step_n = p.shape[0] // nslices
            for i in range(nslices):
                sl = slice(i * step_n, (i + 1) * step_n)
                vi = jax.tree.map(lambda a: a[sl], v)
                pi, gi, mi, vi, tok = jax.lax.optimization_barrier(
                    (p[sl], g[sl], m[sl], vi, tok)
                )
                np_, nm, nv = upd_math(pi, gi, mi, vi)
                tok = np_[(0,) * np_.ndim].astype(jnp.float32)
                outs.append((np_, nm, nv))
            cat = lambda k: jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *[o[k] for o in outs])
            return cat(0), cat(1), cat(2)
        return upd_math(p, g, m, v)

    _is_vleaf = lambda x: isinstance(x, dict) and set(x) == {"r", "c"}
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.flatten(state["v"], is_leaf=_is_vleaf)[0]
    flat_s = jax.tree.leaves(shardings) if shardings is not None else [None] * len(flat_p)
    out = []
    tok = jnp.zeros((), jnp.float32)
    big = 1 << 26  # only chain leaves >= 64M elements — small leaves can
    # update concurrently without memory impact
    for p, g, m, v, s in zip(flat_p, flat_g, flat_m, flat_v, flat_s):
        if cfg.sequential_updates and p.size >= big:
            # True data dependence: leaf i+1's gradient adds 0*token of leaf
            # i's output.  XLA cannot fold 0*x (NaN/Inf semantics), so one
            # big leaf's fp32 update temporaries must retire before the next
            # leaf starts — measured: all three grok-1 expert-stack updates
            # otherwise run concurrently (9 GiB of co-live f32 temps).  The
            # result is re-pinned to the leaf's sharding (the fresh value
            # otherwise lets the partitioner replicate it).
            g = g.at[(0,) * g.ndim].add((tok * 0.0).astype(g.dtype))
            if s is not None:
                g = jax.lax.with_sharding_constraint(g, s)
        np_, nm, nv = upd(p, g, m, v)
        if cfg.sequential_updates and p.size >= big:
            # scalar index (NOT ravel()[0]: reshaping a sharded stack to
            # 1-D all-gathers the whole fp32 leaf — measured 412 GiB/device)
            tok = np_[(0,) * np_.ndim].astype(jnp.float32)
        out.append((np_, nm, nv))
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return (
        new_p,
        # preserve keys other subsystems thread through the opt dict (the
        # compression error-feedback residual lives under "ef")
        {**state, "m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
