"""Microbatched, pjit-ready training step.

Memory posture (the reason every assigned cell compiles on 16 GB chips):
  * Gradient accumulation over ``num_microbatches`` via ``lax.scan`` — peak
    activation memory is ONE microbatch's remat boundaries; the full (B, S)
    batch never has live activations at once.
  * Loss (and therefore logits (mb, S, V)) is computed inside the microbatch
    scan — full-batch logits are never materialized (vocab 100k+ at 1M tokens
    would be TBs).
  * Optional int8 gradient compression with error feedback
    (dist/compression.py) applied at the accumulation boundary.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..dist.sharding import NOPLAN, ShardingPlan, shard
from ..models import transformer as T
from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["TrainState", "init_train_state", "make_train_step"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: dict
    rng: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.rng), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_train_state(
    key: jax.Array, cfg, opt_cfg: AdamWConfig, *, compress_grads: bool = False
) -> TrainState:
    params = T.init_params(key, cfg)
    opt = adamw_init(params, opt_cfg)
    if compress_grads:  # stable opt structure: the "ef" residual exists from
        from ..dist.compression import init_error_feedback  # step 0 onward

        opt = init_error_feedback(opt, params)
    return TrainState(params=params, opt=opt, rng=key)


def _split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) along the batch dim."""

    def r(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape((n, B // n) + x.shape[1:])

    return jax.tree.map(r, batch)


def make_train_step(
    cfg,
    opt_cfg: AdamWConfig,
    plan: ShardingPlan = NOPLAN,
    *,
    num_microbatches: int = 1,
    attn_chunk: int = 2048,
    compress_grads: bool = False,
    accum_dtype: str | None = None,
) -> Callable:
    """Build ``train_step(state, batch) -> (state, metrics)``.

    With num_microbatches > 1, grads are accumulated over a lax.scan whose
    per-step working set is one microbatch (activation-memory lever).
    accum_dtype defaults to bf16 for fsdp archs (halves the accumulation
    carry; the 8-16-way sum stays well inside bf16's 8-bit mantissa budget
    given per-microbatch grads are O(1e-2))."""
    from ..dist.compression import compress_decompress

    if accum_dtype is None:
        accum_dtype = "bfloat16" if getattr(cfg, "fsdp", False) else "float32"
    acc_dt = jnp.bfloat16 if accum_dtype == "bfloat16" else jnp.float32
    cd = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32

    def cast_params(params):
        """Cast-then-gather: fp32 master -> compute dtype ONCE per step, on
        the sharded stacks.  Every downstream FSDP all-gather, layer-scan xs
        buffer, and backward grad stack then moves half the bytes; grads
        arrive in compute dtype and only meet fp32 inside the optimizer
        (EXPERIMENTS.md §Perf)."""
        return jax.tree.map(
            lambda p: p.astype(cd) if (p.dtype == jnp.float32 and p.ndim >= 2) else p,
            params,
        )

    def loss_fn(params_c, mb):
        loss, metrics = T.apply_train(params_c, mb, cfg, plan, attn_chunk=attn_chunk)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def param_shardings_of(params):
        if plan.mesh is None:
            return None
        from jax.sharding import NamedSharding
        from ..dist.sharding import param_pspecs, valid_spec

        specs = param_pspecs(params, plan)
        return jax.tree.map(
            lambda t, s: NamedSharding(plan.mesh, valid_spec(t.shape, s, plan.mesh)),
            params,
            specs,
        )

    def constrain_like_params(tree, params):
        """Pin gradient / accumulator sharding to the parameter sharding.
        Without this, XLA's propagation is free to leave the grad tree
        replicated over the data axes (measured: 24.8 GiB/device of
        replicated grok-1 expert grads vs 2.4 GiB sharded)."""
        sh = param_shardings_of(params)
        if sh is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, sh)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        params = state.params
        params_c = cast_params(params)

        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params_c, batch)
            grads = constrain_like_params(grads, params_c)
        else:
            mbs = _split_microbatches(batch, num_microbatches)
            zero_g = constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params_c), params_c
            )

            def acc(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params_c, mb)
                g = constrain_like_params(g, params_c)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            (grads, loss_sum), metrics = jax.lax.scan(acc, (zero_g, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        if compress_grads:  # int8 + error feedback at the accumulation boundary
            grads, state_opt = compress_decompress(grads, state.opt)
        else:
            state_opt = state.opt

        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state_opt, opt_cfg, shardings=param_shardings_of(params)
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        new_state = TrainState(params=new_params, opt=new_opt, rng=state.rng)
        return new_state, metrics

    return train_step
