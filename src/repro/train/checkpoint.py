"""Sharded, atomic, keep-last-k checkpointing with async write and
reshard-on-restore (the fault-tolerance substrate).

Layout: ``<dir>/step_<n>/``
    manifest.json        treedef, shapes, dtypes, step, mesh shape
    arr_<i>.npy          one file per leaf (host-gathered)

Guarantees:
  * **Atomic**: writes go to ``step_<n>.tmp`` and are renamed only after
    fsync — a crash mid-write can never corrupt the latest checkpoint.
  * **Keep-last-k**: older steps are pruned after a successful save.
  * **Async**: `save(..., blocking=False)` hands the host-side write to a
    daemon thread; training continues (double-buffered: at most one
    outstanding save).
  * **Elastic restore**: `restore(..., shardings=...)` re-lays out every leaf
    for a *different* mesh than the one that saved it — grow/shrink restarts
    reshard transparently (leaves are host np arrays, device_put re-shards).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        """Snapshot to host memory synchronously (cheap), write to disk
        async unless blocking."""
        import pickle

        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(l) for l in leaves]  # device->host gather
        meta = {
            "step": int(step),
            "treedef": pickle.dumps(treedef).hex(),
            "nleaves": len(host),
            "dtypes": [str(h.dtype) for h in host],
            "shapes": [list(h.shape) for h in host],
        }
        if self._thread is not None:
            self._thread.join()  # at most one outstanding async save
            self._thread = None
        if blocking:
            self._write(step, host, meta)
        else:
            t = threading.Thread(target=self._write, args=(step, host, meta), daemon=True)
            t.start()
            self._thread = t

    def _write(self, step: int, host: list[np.ndarray], meta: dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, h in enumerate(host):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), h)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._prune()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings: Any = None) -> tuple[int, Any]:
        """Load a checkpoint; `shardings` (optional pytree of NamedSharding,
        same structure) re-lays the leaves onto the *current* mesh (elastic
        restart).  Returns (step, tree)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        import pickle

        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        td = pickle.loads(bytes.fromhex(meta["treedef"]))
        host = [np.load(os.path.join(d, f"arr_{i}.npy")) for i in range(meta["nleaves"])]
        if shardings is not None:
            sh_struct = jax.tree.structure(shardings)
            if sh_struct != td:
                # a silent zip misalignment here device_puts leaves onto the
                # wrong shardings (e.g. resuming with a different
                # --compress-grads setting adds/drops the opt "ef" subtree)
                raise ValueError(
                    f"checkpoint step {step} tree structure does not match the "
                    f"requested shardings ({td.num_leaves} saved leaves vs "
                    f"{sh_struct.num_leaves}); was the run configuration "
                    "(e.g. --compress-grads) changed since the save?"
                )
            sh_leaves = jax.tree.leaves(shardings)
            leaves = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
        else:
            leaves = [jnp.asarray(h) for h in host]
        return step, jax.tree.unflatten(td, leaves)
