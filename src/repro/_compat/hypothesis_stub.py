"""Minimal stand-in for the ``hypothesis`` API surface the test suite uses.

Installed into ``sys.modules`` by tests/conftest.py ONLY when the real
package is absent (the repo's property tests must still run in hermetic
containers that bake no test extras).  It is deliberately tiny:

  * ``@given(**strategies)`` draws ``max_examples`` pseudo-random examples
    per test from a deterministic per-test seed (no shrinking, no database);
  * strategies: ``integers``, ``floats``, ``tuples``, ``sampled_from``,
    ``booleans``, ``just``, ``lists``;
  * ``settings(max_examples=, deadline=)`` (deadline ignored);
  * ``assume(cond)`` skips the current example without consuming a failure.

Determinism: the RNG seed is crc32(test qualname) + example index, so a
passing run is reproducible and CI cannot flake on draw order.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

DEFAULT_MAX_EXAMPLES = 100


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5)


def just(value) -> _Strategy:
    return _Strategy(lambda rng: value)


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng: options[rng.randrange(len(options))])


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def lists(elements: _Strategy, *, min_size: int = 0, max_size: int = 10) -> _Strategy:
    return _Strategy(
        lambda rng: [elements.example(rng) for _ in range(rng.randint(min_size, max_size))]
    )


def given(**strategies: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples", DEFAULT_MAX_EXAMPLES)
            base = zlib.crc32(fn.__qualname__.encode())
            ran = 0
            i = 0
            # draw until n examples actually ran (assume() rejections retry),
            # with a generous rejection budget so a bad filter still halts
            while ran < n and i < n * 50 + 100:
                rng = random.Random(base * 1_000_003 + i)
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                i += 1
                try:
                    fn(*args, **kwargs, **drawn)
                except UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__name__}): {drawn!r}"
                    ) from e
                ran += 1
            return None

        wrapper._hyp_given = True
        # hide strategy-filled params from pytest's fixture resolution (the
        # real hypothesis does the same); remaining params stay fixtures
        remaining = [
            p for p in inspect.signature(fn).parameters.values()
            if p.name not in strategies
        ]
        wrapper.__signature__ = inspect.Signature(remaining)
        return wrapper

    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``)."""
    if "hypothesis" in sys.modules:
        return
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "just", "sampled_from", "tuples", "lists"):
        setattr(st_mod, name, globals()[name])
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st_mod
    hyp.HealthCheck = types.SimpleNamespace(filter_too_much=None, too_slow=None)
    hyp.__is_repro_stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
