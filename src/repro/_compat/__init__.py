"""Fallback shims for optional third-party test dependencies (the container
image may lack them; nothing here is used when the real package exists)."""
