"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256. Cross-attn image layers every 5th layer (8 of 40); vision tower
is a STUB: input_specs() supplies precomputed, projected patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ModelConfig, register


@register("llama-3.2-vision-11b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b",
        family="vlm",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14_336,
        vocab=128_256,
        head_dim=128,
        rope_theta=500_000.0,
        act="silu",
        norm_eps=1e-5,
        xattn_stride=5,
        xattn_offset=3,  # layers 3, 8, ..., 38
        img_tokens=1601,  # one 448px tile -> 1601 patch tokens (projected)
        fsdp=True,
        source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    )
