"""Config registry: one module per assigned architecture + paper workloads."""
from .base import ModelConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES, get_config, list_configs

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from . import (  # noqa: F401
        qwen3_0_6b,
        minitron_4b,
        phi4_mini_3_8b,
        qwen2_1_5b,
        phi3_5_moe,
        grok1_314b,
        mamba2_370m,
        whisper_large_v3,
        llama32_vision_11b,
        jamba_v0_1,
    )

    _LOADED = True
