"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from .base import ModelConfig, MoEConfig, register


@register("grok-1-314b")
def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32_768,
        vocab=131_072,
        head_dim=128,
        rope_theta=10_000.0,
        act="gelu_glu",  # grok-1: gated GeGLU experts (3 matrices -> 314B total)
        norm_eps=1e-5,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32_768, dispatch="remap"),
        fsdp=True,
        source="hf:xai-org/grok-1; unverified",
    )
