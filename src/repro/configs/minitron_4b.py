"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000. Pruned nemotron (squared-ReLU MLP). [arXiv:2407.14679; hf]"""
from .base import ModelConfig, register


@register("minitron-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab=256_000,
        head_dim=128,
        rope_theta=10_000.0,
        act="relu2",  # nemotron-family squared-ReLU, 2-matrix MLP
        norm_eps=1e-5,
        fsdp=True,
        source="arXiv:2407.14679; hf",
    )
