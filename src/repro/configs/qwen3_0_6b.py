"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf] (head_dim=128 per the Qwen3 family)."""
from .base import ModelConfig, register


@register("qwen3-0.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b",
        family="dense",
        n_layers=28,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=3072,
        vocab=151_936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        act="silu",
        norm_eps=1e-6,
        fsdp=False,
        source="hf:Qwen/Qwen3-8B; hf",
    )
