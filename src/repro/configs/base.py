"""Architecture config system.

One `ModelConfig` per assigned architecture (exact published numbers) plus a
`reduced()` shrink used by CPU smoke tests.  `layer_kinds()` derives the
per-layer (mixer, ffn) pattern; models scan over `period` repeats so HLO size
is bounded by one period regardless of depth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

__all__ = ["MoEConfig", "SSMConfig", "ModelConfig", "ShapeConfig", "register", "get_config", "list_configs", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    capacity_factor: float = 1.25
    dispatch: str = "remap"  # 'remap' (paper Approach 1) | 'onehot' (Approach 2 baseline)
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    norm: str = "rms"  # 'rms' | 'ln' (whisper)
    act: str = "silu"  # 'silu' -> SwiGLU (3 mats), 'gelu' -> classic 2-mat MLP
    moe: MoEConfig | None = None
    moe_stride: int = 1  # MoE at layers where (idx % stride == offset)
    moe_offset: int = 0
    ssm: SSMConfig | None = None
    attn_stride: int = 0  # hybrid: attention at layers where idx % stride == offset
    attn_offset: int = 0
    # enc-dec (audio family)
    encoder_layers: int = 0
    encoder_seq: int = 1500  # whisper 30 s @ 50 Hz after conv stub
    # vlm
    xattn_stride: int = 0  # cross-attn at layers where idx % stride == offset
    xattn_offset: int = 0
    img_tokens: int = 0
    # numerics / distribution hints
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    fsdp: bool = False  # shard params over the data axis too (ZeRO-3 analogue)
    remat: bool = True
    remat_group: int = 0  # >1: two-level (sqrt) remat — outer scan saves only
    # n_reps/remat_group boundary activations; inner layers recompute within
    # the group on backward (Chen et al. 2016 sqrt-schedule)
    scan_unroll: bool = False  # unroll layer loop (roofline cost probes only)
    barrier_xs: bool = False  # tie each layer's param slice to the running
    # carry via optimization_barrier: defeats XLA's slice-of-all-gather
    # hoisting, which otherwise keeps a fully-gathered copy of the whole
    # (bf16) parameter stack live across the loop (memory <-> overlap trade)
    source: str = ""  # provenance tag from the assignment table

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        """Embedding rows padded to a 256 multiple so the vocab dim always
        shards over TP (whisper's 51866 / mamba2's 50280 otherwise fall back
        to d_model-sharded tables, which trips an XLA SPMD dynamic-slice bug
        and shards worse).  Pad logits are masked to -inf in lm_logits."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def period(self) -> int:
        """Smallest repeating layer-pattern period (scan unit)."""
        p = 1
        for s in (self.moe_stride if self.moe else 1, self.attn_stride or 1, self.xattn_stride or 1):
            p = math.lcm(p, max(s, 1))
        return p

    def layer_kinds(self) -> list[tuple[str, str]]:
        """(mixer, ffn) per layer. mixer: attn|mamba|xattn; ffn: mlp|moe|none."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                mixer = "mamba"
            elif self.attn_stride:
                mixer = "attn" if i % self.attn_stride == self.attn_offset else "mamba"
            elif self.xattn_stride:
                mixer = "xattn" if i % self.xattn_stride == self.xattn_offset else "attn"
            else:
                mixer = "attn"
            if self.family == "ssm":
                ffn = "none"  # mamba2 blocks carry no separate FFN
            elif self.moe and i % self.moe_stride == self.moe_offset:
                ffn = "moe"
            else:
                ffn = "mlp"
            kinds.append((mixer, ffn))
        return kinds

    def pattern_kinds(self) -> list[tuple[str, str]]:
        """One period of layer kinds (repeated n_layers/period times)."""
        kinds = self.layer_kinds()
        p = self.period
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        assert kinds[:p] * (self.n_layers // p) == kinds, "pattern not periodic"
        return kinds[:p]

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        for mixer, ffn in self.layer_kinds():
            if mixer == "attn" or mixer == "xattn":
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                if mixer == "xattn":  # extra kv proj for image stream shares the count above
                    pass
            elif mixer == "mamba":
                s = self.ssm or SSMConfig()
                d_in = s.expand * d
                nheads = d_in // s.head_dim
                conv_dim = d_in + 2 * s.n_groups * s.d_state
                total += d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
                total += conv_dim * s.d_conv + d_in * d  # conv + out_proj
            if ffn == "mlp":
                nmat = 3 if self.act in ("silu", "gelu_glu") else 2
                total += nmat * d * self.d_ff
            elif ffn == "moe":
                m = self.moe
                nmat = 3 if self.act in ("silu", "gelu_glu") else 2
                total += m.num_experts * nmat * d * m.d_ff + d * m.num_experts
            total += 2 * d  # norms
        if self.encoder_layers:
            per = 4 * d * hd * self.n_heads / self.hd  # enc attn  (approx: full heads)
            total += int(self.encoder_layers * (4 * d * d + 2 * d * self.d_ff + 2 * d))
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        nmat = 3 if self.act in ("silu", "gelu_glu") else 2
        moe_layers = sum(1 for _, f in self.layer_kinds() if f == "moe")
        dense_equiv = self.param_count() - moe_layers * m.num_experts * nmat * self.d_model * m.d_ff
        return int(dense_equiv + moe_layers * m.top_k * nmat * self.d_model * m.d_ff)

    def reduced(self) -> "ModelConfig":
        """CPU-smoke-test shrink: same family/pattern, tiny dims."""
        p = self.period
        changes = dict(
            n_layers=2 * p,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            d_ff=128,
            vocab=256,
            head_dim=16,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_layers else 1500,
            img_tokens=8 if self.img_tokens else 0,
            fsdp=False,
            remat=False,
            compute_dtype="float32",
        )
        if self.moe:
            # capacity_factor = num_experts makes drops impossible, so smoke
            # tests can assert exact prefill/decode and remap/onehot equality.
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_ff=96, capacity_factor=4.0
            )
        if self.ssm:
            changes["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk=8)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    from . import _load_all  # noqa — populate registry

    _load_all()
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    from . import _load_all

    _load_all()
    return sorted(_REGISTRY)
