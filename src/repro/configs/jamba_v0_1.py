"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2. Mamba:attn 7:1 interleave (attention at
layer index 4 of each 8), MoE every other layer. [arXiv:2403.19887; hf]

Adaptation note: Mamba blocks are implemented as Mamba2/SSD (the repo's SSM
substrate); Jamba v0.1 ships Mamba1 — state size kept at Jamba's 16."""
from .base import ModelConfig, MoEConfig, SSMConfig, register


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14_336,
        vocab=65_536,
        head_dim=128,
        rope_theta=10_000.0,  # jamba attn layers use no rope in v0.1; kept for cache sizing
        act="silu",
        norm_eps=1e-6,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=14_336, dispatch="remap"),
        moe_stride=2,
        moe_offset=1,
        attn_stride=8,
        attn_offset=4,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
        fsdp=True,
        source="arXiv:2403.19887; hf",
    )
