"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064. RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""
from .base import ModelConfig, register


@register("phi4-mini-3.8b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab=200_064,
        head_dim=128,
        rope_theta=10_000.0,
        act="silu",
        tie_embeddings=True,
        norm_eps=1e-5,
        fsdp=True,
        source="arXiv:2412.08905; hf",
    )
