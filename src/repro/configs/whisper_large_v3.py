"""whisper-large-v3 [audio] — 32L d_model=1280 20H (kv=20, full MHA)
d_ff=5120 vocab=51866. Enc-dec; conv frontend is a STUB: input_specs()
supplies precomputed 1500-frame embeddings. [arXiv:2212.04356; unverified]

Adaptation note (DESIGN.md §5): learned positional embeddings are replaced by
sinusoidal so the assigned 4k/32k decoder lengths are representable."""
from .base import ModelConfig, register


@register("whisper-large-v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32,  # decoder layers; encoder_layers below
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51_866,
        head_dim=64,
        act="gelu",
        norm="ln",
        rope_theta=0.0,  # sinusoid positions (adaptation: learned -> sinusoid)
        tie_embeddings=True,
        norm_eps=1e-5,
        encoder_layers=32,
        encoder_seq=1500,
        source="arXiv:2212.04356; unverified",
    )
