"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]

MoE dispatch uses the paper's Approach-1 remap (DESIGN.md §5)."""
from .base import ModelConfig, MoEConfig, register


@register("phi3.5-moe-42b-a6.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab=32_064,
        head_dim=128,
        rope_theta=10_000.0,
        act="silu",
        norm_eps=1e-5,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400, dispatch="remap"),
        fsdp=True,
        source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
    )
