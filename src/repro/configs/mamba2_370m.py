"""mamba2-370m [ssm] — 48L d_model=1024 (attn-free) d_ff=0 vocab=50280,
ssm_state=128. SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from .base import ModelConfig, SSMConfig, register


@register("mamba2-370m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=50_280,
        tie_embeddings=True,
        norm_eps=1e-5,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
        source="arXiv:2405.21060; unverified",
    )
