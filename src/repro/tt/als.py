"""Sparse tensor-train decomposition (TT-ALS) on the programmable memory
controller.

The third workload of the substrate: after CP (MTTKRP) and Tucker (TTMc),
the TT-core update exercises the same irregular-access problem through a
Kronecker of TWO chained interfaces.  TT represents X by N cores
G_k (rl_k, I_k, rr_k) with boundary bonds rl_0 = rr_{N-1} = 1, and ALS
updates one core at a time, left to right:

    repeat:
      for each mode m:
        B_m[i, :] = sum_{z: i_m(z)=i} v_z * kron(l_z, r_z)   # the kernel
        A_m       = kron(P_{m-1}, Q_{m+1})                   # interface Grams
        W_m       = solve(A_m, B_m^T)^T                      # normal equations
        G_m       = fold(W_m)
      fit = 1 - sqrt(||X||^2 + ||TT||^2 - 2<X, TT>) / ||X||

where l_z / r_z are the left/right interface chains of the other cores at
non-zero z, P_{m-1} = (left chain)^T (left chain) is the (rl_m, rl_m) left
Gram (rank-sized — never materialized over prod(I)), and Q_{m+1} the
(rr_m, rr_m) right Gram.  Within one left-to-right sweep the right Grams are
computed once from the incoming cores (cores > m are untouched until the
sweep reaches them) and the left Gram is updated with each freshly solved
core — the standard single-site TT-ALS dataflow.

Core <-> matrix convention used everywhere (kernels included): the mode-m
interface matrix is W_m = transpose(G_m, (1, 0, 2)).reshape(I_m, rl_m*rr_m),
columns row-major over (rl, rr) — rl slow, rr fast — matching the kernel's
kron(l, r) column order and kron(P, Q) normal matrix.

Three methods, mirroring cp_als / tucker_hooi:
  * 'pallas'         — the planned TT-core kernel (kernels/tt_pallas.py) on a
                       `PlannedTT` workspace: one PMS-tunable BlockPlan +
                       device-resident layout per output mode, built once and
                       reused across every ALS iteration.  jit_sweep=True
                       runs each iteration as one compiled sweep with
                       lane-padded, device-resident interface matrices;
                       jit_sweep=False keeps the eager per-mode dispatch loop
                       as the parity baseline.
  * 'pallas_sharded' — the distributed planned path (repro.dist.planned):
                       shard-local layouts, one jitted shard_map sweep per
                       iteration, a single psum of partial B_m rows per mode.
  * 'reference'      — the pure-jnp TT-core oracle (kernels/ref.py), also
                       available as a jitted whole-iteration sweep.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.coo import SparseTensor
from ..core.loop import (
    check_drive_extras,
    check_planned_method,
    check_workspace,
    finish_iter,
    require_sharded_sweep,
)
from ..core.memctrl import MemoryControllerConfig, TPUSpec
from ..kernels.ops import (
    PlannedTTCore,
    _tt_bond_pairs,
    make_planned_ttcore,
    planned_layout_bytes,
)
from ..kernels.ref import ttcore_ref
from ..kernels.workspace import PlannedWorkspace

__all__ = [
    "TTState",
    "tt_als",
    "PlannedTT",
    "make_planned_tt",
    "init_tt_cores",
    "tt_svd",
    "core_to_matrix",
    "matrix_to_core",
    "tt_inner",
    "tt_norm_sq",
    "tt_fit_value",
]

# tt_svd densifies the tensor (float64) for the sequential truncated SVD;
# init='auto' falls back to the random init above this element count.
_TT_SVD_DENSE_LIMIT = 1 << 22


@dataclasses.dataclass
class TTState:
    cores: list[jax.Array]  # one (rl_m, I_m, rr_m) per mode; boundary bonds 1
    fit_history: list[float]

    @property
    def tt_ranks(self) -> tuple[int, ...]:
        """The N-1 interior bond ranks."""
        return tuple(int(c.shape[2]) for c in self.cores[:-1])

    def full(self) -> jax.Array:
        """Dense reconstruction (I_0, ..., I_{N-1}) — tiny shapes only."""
        out = self.cores[0]  # (1, I_0, r)
        for c in self.cores[1:]:
            out = jnp.tensordot(out, c, axes=[[-1], [0]])
        return out.reshape(tuple(int(c.shape[1]) for c in self.cores))


def _validated_tt_ranks(st: SparseTensor, tt_ranks: int | Sequence[int]) -> tuple[int, ...]:
    """Normalize/validate the N-1 interior bond ranks (an int broadcasts).
    Bond k sits between modes k and k+1; its rank cannot exceed the matrix
    rank bound min(prod(I_0..I_k), prod(I_{k+1}..I_{N-1}))."""
    if isinstance(tt_ranks, (int, np.integer)):
        tt_ranks = (int(tt_ranks),) * (st.nmodes - 1)
    tr = tuple(int(r) for r in tt_ranks)
    if len(tr) != st.nmodes - 1:
        raise ValueError(
            f"tt_ranks has {len(tr)} entries for a {st.nmodes}-mode tensor "
            f"(pass the N-1 interior TT ranks, or an int to broadcast)"
        )
    for k, r in enumerate(tr):
        bound = min(math.prod(st.shape[: k + 1]), math.prod(st.shape[k + 1 :]))
        if not 1 <= r <= bound:
            raise ValueError(
                f"TT rank {r} for bond {k} (modes {k}|{k + 1}) out of range "
                f"[1, {bound}] (unfolding rank bound)"
            )
    return tr


def core_to_matrix(core: jax.Array) -> jax.Array:
    """G (rl, I, rr) -> W (I, rl*rr), columns row-major over (rl, rr)."""
    rl, i, rr = core.shape
    return jnp.transpose(core, (1, 0, 2)).reshape(i, rl * rr)


def matrix_to_core(w: jax.Array, rl: int, rr: int) -> jax.Array:
    """W (I, rl*rr) -> G (rl, I, rr) — inverse of `core_to_matrix`."""
    return jnp.transpose(w.reshape(w.shape[0], rl, rr), (1, 0, 2))


def init_tt_cores(
    key: jax.Array,
    shape: Sequence[int],
    tt_ranks: Sequence[int],
    dtype=jnp.float32,
) -> list[jax.Array]:
    """Random left-orthogonal TT cores: each core's left unfolding
    (rl*I, rr) is the reduced QR of a Gaussian (plain scaled Gaussian when
    rl*I < rr, where no orthonormal frame exists)."""
    pairs = _tt_bond_pairs(tuple(int(r) for r in tt_ranks), len(shape))
    keys = jax.random.split(key, len(shape))
    cores = []
    for k, s, (rl, rr) in zip(keys, shape, pairs):
        m = jax.random.normal(k, (rl * int(s), rr), dtype)
        if rl * int(s) >= rr:
            m, _ = jnp.linalg.qr(m)
        else:
            m = m / jnp.sqrt(jnp.asarray(float(rr), dtype))
        cores.append(m.reshape(rl, int(s), rr))
    return cores


def tt_svd(st: SparseTensor, tt_ranks: Sequence[int]) -> list[jax.Array]:
    """TT-SVD init (Oseledets): densify, then peel cores off left to right
    by sequential truncated SVD.  Deterministic and near-optimal for the
    given ranks — the standard warm start for TT-ALS.  Rank-deficient
    unfoldings are zero-padded up to the requested bond rank (the padded
    directions carry zero singular value and are refined by ALS).

    Densifies to float64 — guarded to prod(shape) <= 2^22 elements; use
    init='random' beyond that."""
    tr = _validated_tt_ranks(st, tt_ranks)
    nelem = math.prod(st.shape)
    if nelem > _TT_SVD_DENSE_LIMIT:
        raise ValueError(
            f"tt_svd densifies the tensor: prod(shape)={nelem} exceeds the "
            f"{_TT_SVD_DENSE_LIMIT}-element guard; use init='random'"
        )
    shape, nmodes = st.shape, st.nmodes
    dense = np.zeros(shape, np.float64)
    np.add.at(
        dense,
        tuple(st.indices[:, m] for m in range(nmodes)),
        st.values.astype(np.float64),
    )
    cores: list[jax.Array] = []
    c = dense.reshape(1, -1)
    rl = 1
    for k in range(nmodes - 1):
        c = c.reshape(rl * shape[k], -1)
        r = tr[k]
        u, s, vt = np.linalg.svd(c, full_matrices=False)
        keep = min(r, s.shape[0])
        u, s, vt = u[:, :keep], s[:keep], vt[:keep]
        if keep < r:
            u = np.concatenate([u, np.zeros((u.shape[0], r - keep))], axis=1)
            s = np.concatenate([s, np.zeros(r - keep)])
            vt = np.concatenate([vt, np.zeros((r - keep, vt.shape[1]))], axis=0)
        cores.append(jnp.asarray(u.reshape(rl, shape[k], r), jnp.float32))
        c = s[:, None] * vt
        rl = r
    cores.append(jnp.asarray(c.reshape(rl, shape[-1], 1), jnp.float32))
    return cores


def _p_next(p: jax.Array, core: jax.Array) -> jax.Array:
    """Left-interface Gram recursion: P_m = sum_i G_m[:,i,:]^T P_{m-1}
    G_m[:,i,:], shape (rr_m, rr_m)."""
    return jnp.einsum("aib,ac,cid->bd", core, p, core)


def _q_prev(q: jax.Array, core: jax.Array) -> jax.Array:
    """Right-interface Gram recursion: Q_m = sum_i G_m[:,i,:] Q_{m+1}
    G_m[:,i,:]^T, shape (rl_m, rl_m)."""
    return jnp.einsum("aib,bc,dic->ad", core, q, core)


def _q_suffix(cores: Sequence[jax.Array]) -> list[jax.Array]:
    """qs[m] = the right Gram over cores STRICTLY right of m — the Q_{m+1}
    factor of mode m's normal matrix (ones((1,1)) for the last mode).
    Computed once per sweep from the incoming cores."""
    nmodes = len(cores)
    qs = [None] * nmodes
    q = jnp.ones((1, 1), jnp.float32)
    for m in range(nmodes - 1, -1, -1):
        qs[m] = q
        q = _q_prev(q, cores[m])
    return qs


def _solve_core(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve the core normal equations W A = B for W (I, rl*rr) with A =
    kron(P, Q) symmetric PSD; a trace-scaled ridge keeps the solve finite
    when an interface direction has collapsed."""
    dim = a.shape[0]
    ridge = 1e-8 * (jnp.trace(a) / dim) + 1e-12
    a = a + ridge * jnp.eye(dim, dtype=a.dtype)
    return jax.scipy.linalg.solve(a, b.T, assume_a="pos").T


def tt_inner(indices: jax.Array, values: jax.Array, cores: Sequence[jax.Array]) -> jax.Array:
    """<X, TT> restricted to X's non-zeros: per-nnz left-to-right chain of
    core slices, then the value-weighted sum.  Zero-valued (padding) entries
    contribute exactly nothing."""
    nnz = values.shape[0]
    v = jnp.ones((nnz, 1), jnp.float32)
    for k, core in enumerate(cores):
        rows = jnp.transpose(core, (1, 0, 2))[indices[:, k]]
        v = jnp.einsum("za,zab->zb", v, rows.astype(jnp.float32))
    return jnp.sum(values.astype(jnp.float32) * v[:, 0])


def tt_norm_sq(cores: Sequence[jax.Array]) -> jax.Array:
    """||TT||_F^2 via the left Gram recursion — rank-sized intermediates
    only."""
    p = jnp.ones((1, 1), jnp.float32)
    for core in cores:
        p = _p_next(p, core)
    return p[0, 0]


def tt_fit_value(
    indices: jax.Array,
    values: jax.Array,
    cores: Sequence[jax.Array],
    norm_x_sq: jax.Array,
) -> jax.Array:
    """fit = 1 - ||X - TT|| / ||X||, expanded as ||X||^2 + ||TT||^2 -
    2<X, TT> — one pass over the non-zeros, no densification."""
    resid_sq = jnp.maximum(
        norm_x_sq + tt_norm_sq(cores) - 2.0 * tt_inner(indices, values, cores), 0.0
    )
    return 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)


@partial(jax.jit, static_argnames=("shape",))
def _sweep_reference(cores, idx, val, norm_x_sq, *, shape):
    """One full jitted TT-ALS iteration on the pure-jnp TT-core oracle:
    every mode's B_m -> normal solve -> core update, plus the fit, in a
    single compiled function."""
    cores = list(cores)
    qs = _q_suffix(cores)
    p = jnp.ones((1, 1), jnp.float32)
    for m in range(len(shape)):
        b = ttcore_ref(idx, val, cores, m, shape[m])
        w = _solve_core(jnp.kron(p, qs[m]), b)
        cores[m] = matrix_to_core(w, cores[m].shape[0], cores[m].shape[2])
        p = _p_next(p, cores[m])
    inner = tt_inner(idx, val, cores)
    resid_sq = jnp.maximum(norm_x_sq + p[0, 0] - 2.0 * inner, 0.0)
    fit = 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)
    return tuple(cores), fit


@dataclasses.dataclass
class PlannedTT(PlannedWorkspace):
    """Per-mode plan cache driving the whole TT-ALS loop on the memory
    controller — the tensor-train mirror of `PlannedCPALS`.

    One `PlannedTTCore` per output mode — each holds its own remapped,
    device-resident copy of the non-zero stream — constructed once and
    reused for every ALS iteration.  The steady-state iteration is `sweep`:
    one jitted function running a full left-to-right sweep (every mode's
    TT-core kernel -> kron(P, Q) normal solve -> core update, plus the
    on-device fit).  Padding/residency (each mode's interface matrix to its
    own rank_padded(rl_m*rr_m)) and the host drive loop come from
    `PlannedWorkspace` — this class supplies only the TT sweep body.

    The padded-space factors are the interface MATRICES W_m, not the 3-way
    cores; `tt_als` folds them back at the end."""

    ops: dict[int, PlannedTTCore]
    shape: tuple[int, ...]
    tt_ranks: tuple[int, ...]  # N-1 interior bond ranks

    @property
    def bond_pairs(self) -> tuple[tuple[int, int], ...]:
        return _tt_bond_pairs(self.tt_ranks, self.nmodes)

    @property
    def lane_ranks(self) -> tuple[int, ...]:
        return tuple(a * b for a, b in self.bond_pairs)

    def plan_for(self, mode: int):
        return self.ops[mode].plan

    def _geoms(self) -> dict:
        return {m: op.plan for m, op in self.ops.items()}

    def _layout_bytes(self) -> int:
        return planned_layout_bytes(self.ops)

    def _build_sweep(self) -> Callable:
        shape, nmodes = self.shape, self.nmodes
        pairs, lr = self.bond_pairs, self.lane_ranks
        rps, prows = self.rank_pads, self.padded_rows
        ops = self.ops

        def sweep(facs, idx, val, norm_x_sq):
            facs = list(facs)
            cores = [
                matrix_to_core(facs[m][: shape[m], : lr[m]], *pairs[m])
                for m in range(nmodes)
            ]
            # Right Grams once from the incoming cores; the left Gram runs
            # ahead with each freshly solved core.
            qs = _q_suffix(cores)
            p = jnp.ones((1, 1), jnp.float32)
            for m in range(nmodes):
                op, pln = ops[m], ops[m].plan
                in_mats = tuple(
                    facs[im][: pln.in_rows[n]] for n, im in enumerate(pln.in_modes)
                )
                out = op.call_padded(in_mats)
                b = out[: shape[m], : lr[m]]
                w = _solve_core(jnp.kron(p, qs[m]), b)
                cores[m] = matrix_to_core(w, *pairs[m])
                # Re-pad in place of the old padded matrix (padding rows and
                # lanes stay exactly zero, so the next mode's kernel gathers
                # zeros for padding elements).
                facs[m] = (
                    jnp.zeros((prows[m], rps[m]), w.dtype)
                    .at[: shape[m], : lr[m]]
                    .set(w)
                )
                p = _p_next(p, cores[m])
            inner = tt_inner(idx, val, cores)
            resid_sq = jnp.maximum(norm_x_sq + p[0, 0] - 2.0 * inner, 0.0)
            fit = 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)
            return tuple(facs), None, fit

        return jax.jit(sweep)

    def sweep(self, facs, idx, val, norm_x_sq):
        """One jitted TT-ALS iteration in padded space.  Args: `facs` — the
        lane-padded interface matrices; `idx`, `val` — the raw COO stream
        (only the fit's inner product reads it); `norm_x_sq` — ||X||_F^2.
        Returns (new padded matrices, None, fit scalar on device)."""
        return super().sweep(facs, idx, val, norm_x_sq)

    def vmem_model_bytes(self) -> int:
        from ..core.pms import _tt_iface_cols
        from ..kernels.mttkrp_pallas import rank_padded

        return max(
            op.cfg.vmem_bytes_tt(
                rank_padded(op.out_pair[0] * op.out_pair[1]),
                tuple(rank_padded(a * b) for a, b in op.in_rank_pairs),
                _tt_iface_cols(op.in_rank_pairs, op.n_left),
            )
            for op in self.ops.values()
        )

    def pms_estimates(self, spec: TPUSpec = TPUSpec()) -> dict:
        """Per-mode exact PMS estimates from the built plans (the
        `obs.calibrate` hook — see PlannedCPALS.pms_estimates)."""
        from ..core.pms import predict_tt

        return {
            m: predict_tt(op.plan, self.tt_ranks, op.cfg, spec)
            for m, op in self.ops.items()
        }

    def _build_fallback_sweep(self) -> Callable:
        """Reference degradation target of the "fallback" guard policy: the
        same left-to-right sweep as `_build_sweep` with the per-mode Pallas
        TT-core kernels replaced by the pure-jnp `ttcore_ref` oracle on the
        raw stream (drive's args already carry it for the fit).  Operates on
        the SAME padded interface matrices."""
        shape, nmodes = self.shape, self.nmodes
        pairs, lr = self.bond_pairs, self.lane_ranks
        rps, prows = self.rank_pads, self.padded_rows

        def sweep(facs, idx, val, norm_x_sq):
            facs = list(facs)
            cores = [
                matrix_to_core(facs[m][: shape[m], : lr[m]], *pairs[m])
                for m in range(nmodes)
            ]
            qs = _q_suffix(cores)
            p = jnp.ones((1, 1), jnp.float32)
            for m in range(nmodes):
                b = ttcore_ref(idx, val, cores, m, shape[m])
                w = _solve_core(jnp.kron(p, qs[m]), b)
                cores[m] = matrix_to_core(w, *pairs[m])
                facs[m] = (
                    jnp.zeros((prows[m], rps[m]), w.dtype)
                    .at[: shape[m], : lr[m]]
                    .set(w)
                )
                p = _p_next(p, cores[m])
            inner = tt_inner(idx, val, cores)
            resid_sq = jnp.maximum(norm_x_sq + p[0, 0] - 2.0 * inner, 0.0)
            fit = 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)
            return tuple(facs), None, fit

        jitted = jax.jit(sweep)
        return lambda facs, *args, it: jitted(facs, *args)


def make_planned_tt(
    st: SparseTensor,
    tt_ranks: int | Sequence[int],
    *,
    cfg: MemoryControllerConfig | None = None,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = TPUSpec(),
    interpret: bool = True,
) -> PlannedTT:
    """Build the full TT-ALS workspace: one tuned TT-core plan per output
    mode.

    With auto_tune=True each mode gets its own PMS-selected controller
    configuration scored for the TT kernel (two interface scratch chains in
    the VMEM model); otherwise `cfg` (or the default) is shared by every
    mode."""
    tr = _validated_tt_ranks(st, tt_ranks)
    ops = {
        m: make_planned_ttcore(
            st, m, tr, cfg=cfg, auto_tune=auto_tune, spec=spec, interpret=interpret
        )
        for m in range(st.nmodes)
    }
    return PlannedTT(ops=ops, shape=st.shape, tt_ranks=tr)


def tt_als(
    st: SparseTensor,
    tt_ranks: int | Sequence[int],
    *,
    iters: int = 10,
    method: str = "pallas",
    init: str = "auto",
    seed: int = 0,
    tol: float | None = None,
    planned: "PlannedTT | None" = None,
    interpret: bool = True,
    auto_tune: bool | str = False,
    spec: TPUSpec | str = "default",
    cfg: MemoryControllerConfig | None = None,
    jit_sweep: bool = True,
    devices: int | None = None,
    dist=None,
    verbose: bool = False,
    guards=None,
    checkpoint_every: int | None = None,
    checkpoint_path=None,
) -> TTState:
    """Run sparse tensor-train ALS.

    tt_ranks: the N-1 interior bond ranks (an int broadcasts).
    method: 'pallas' — the planned TT-core memory-controller kernel: a
            `PlannedTT` workspace is built once (one remapped,
            device-resident BlockPlan per output mode) and reused for every
            iteration; 'pallas_sharded' — the distributed planned path
            (repro.dist.planned): per-mode balanced stream partitions,
            shard-local layouts, one jitted shard_map sweep per iteration
            with a single psum of the partial B_m per mode; 'reference' —
            the pure-jnp TT-core oracle.
    init:   'svd' — deterministic TT-SVD warm start (densifies; guarded to
            2^22 elements); 'random' — left-orthogonal random cores from
            `seed`; 'auto' — SVD when the dense guard allows, else random.
    planned / interpret / auto_tune / cfg: pallas-path knobs — pass a
            prebuilt `PlannedTT` (or `ShardedPlannedTT`) to reuse plans
            across calls, or let auto_tune run the TT-aware PMS per mode
            (worst-shard makespan for the sharded path).
            auto_tune="cached" persists/reuses the winners on disk; spec may
            be a TPUSpec, "default", or "measured" (repro.tune).
    jit_sweep: run each iteration as one jitted sweep (interface matrices
            stay device-resident, lane-padded, across iterations); False
            keeps the eager per-mode dispatch loop as the parity baseline
            ('pallas_sharded' is sweep-only and rejects jit_sweep=False).
    devices / dist: 'pallas_sharded' placement — a device count for the
            default 1-D `shard` mesh, or an explicit ShardingPlan.
    guards / checkpoint_every / checkpoint_path: the resilience surface of
            the planned drive loop (repro.resilience).  Planned jitted
            paths only.
    """
    tr = _validated_tt_ranks(st, tt_ranks)
    nmodes = st.nmodes
    pairs = _tt_bond_pairs(tr, nmodes)
    if init == "auto":
        init = "svd" if math.prod(st.shape) <= _TT_SVD_DENSE_LIMIT else "random"
    if init == "svd":
        cores = tt_svd(st, tr)
    elif init == "random":
        cores = init_tt_cores(jax.random.PRNGKey(seed), st.shape, tr)
    else:
        raise ValueError(
            f"unknown init {init!r}: expected 'auto', 'svd' or 'random'"
        )
    norm_x_sq = jnp.asarray(float(np.sum(st.values.astype(np.float64) ** 2)), jnp.float32)
    fits: list[float] = []

    check_planned_method(method, planned, devices, dist)
    check_drive_extras(method, jit_sweep, guards, checkpoint_every,
                       checkpoint_path)
    if method == "pallas_sharded":
        require_sharded_sweep(jit_sweep)
        from ..kernels.ops import ShardedPlannedTT, make_sharded_planned_tt

        if planned is None:
            planned = make_sharded_planned_tt(
                st, tr, dist=dist, devices=devices, cfg=cfg,
                auto_tune=auto_tune, spec=spec, interpret=interpret,
            )
        else:
            check_workspace(
                planned, ShardedPlannedTT, method,
                {"shape": st.shape, "tt_ranks": tr}, devices=devices,
            )
        mats = [core_to_matrix(c) for c in cores]
        mats, _, fits = planned.drive(
            mats, (norm_x_sq,), iters=iters, tol=tol, verbose=verbose,
            label="tt_als", guards=guards,
            checkpoint_every=checkpoint_every, checkpoint_path=checkpoint_path,
        )
        return TTState(
            cores=[matrix_to_core(w, *pairs[m]) for m, w in enumerate(mats)],
            fit_history=fits,
        )
    if method == "pallas":
        if planned is None:
            planned = make_planned_tt(
                st, tr, cfg=cfg, auto_tune=auto_tune, spec=spec,
                interpret=interpret,
            )
        else:
            check_workspace(
                planned, PlannedTT, method, {"shape": st.shape, "tt_ranks": tr}
            )
        if jit_sweep:
            # Fast path: interface matrices padded once, updated in padded
            # space by one jitted sweep per iteration; folded back to cores
            # only for the TTState.
            idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
            mats = [core_to_matrix(c) for c in cores]
            mats, _, fits = planned.drive(
                mats, (idx, val, norm_x_sq), iters=iters, tol=tol,
                verbose=verbose, label="tt_als", guards=guards,
                checkpoint_every=checkpoint_every,
                checkpoint_path=checkpoint_path,
            )
            return TTState(
                cores=[matrix_to_core(w, *pairs[m]) for m, w in enumerate(mats)],
                fit_history=fits,
            )
    elif method != "reference":
        raise ValueError(f"unknown method {method!r}: expected 'pallas' or 'reference'")

    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    if method == "reference" and jit_sweep:
        cores_t = tuple(cores)
        for it in range(iters):
            cores_t, fit = _sweep_reference(
                cores_t, idx, val, norm_x_sq, shape=st.shape
            )
            if finish_iter(fits, fit, it, tol, verbose, "tt_als"):
                break
        return TTState(cores=list(cores_t), fit_history=fits)

    # Eager per-mode dispatch loop: jit_sweep=False (both methods).
    for it in range(iters):
        qs = _q_suffix(cores)
        p = jnp.ones((1, 1), jnp.float32)
        for m in range(nmodes):
            if method == "pallas":
                mats = [core_to_matrix(c) for c in cores]
                b = planned.ops[m].output(mats, st.shape[m])
            else:
                b = ttcore_ref(idx, val, cores, m, st.shape[m])
            w = _solve_core(jnp.kron(p, qs[m]), b)
            cores[m] = matrix_to_core(w, *pairs[m])
            p = _p_next(p, cores[m])
        if finish_iter(
            fits, tt_fit_value(idx, val, cores, norm_x_sq), it, tol, verbose, "tt_als"
        ):
            break
    return TTState(cores=cores, fit_history=fits)
