"""Sparse tensor-train decomposition (TT-ALS) on the programmable memory
controller: the TT-core-update kernel family reuses the MTTKRP/TTMc
BlockPlan substrate (see kernels/tt_pallas.py); `tt_auto` is the one-shot
dispatcher sharing the kind-keyed plan cache in kernels/ops.py."""
from ..kernels.ops import PlannedTTCore, make_planned_ttcore, tt_auto
from .als import (
    PlannedTT,
    TTState,
    core_to_matrix,
    init_tt_cores,
    make_planned_tt,
    matrix_to_core,
    tt_als,
    tt_fit_value,
    tt_inner,
    tt_norm_sq,
    tt_svd,
)

__all__ = [
    "TTState",
    "tt_als",
    "PlannedTT",
    "make_planned_tt",
    "init_tt_cores",
    "tt_svd",
    "core_to_matrix",
    "matrix_to_core",
    "tt_inner",
    "tt_norm_sq",
    "tt_fit_value",
    "PlannedTTCore",
    "make_planned_ttcore",
    "tt_auto",
]
