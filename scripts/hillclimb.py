"""Hillclimb driver: lower one cell under a set of config variants and
report the roofline terms per variant (EXPERIMENTS.md §Perf).

  PYTHONPATH=src python scripts/hillclimb.py qwen3-0.6b train_4k \
      base remat_off mb1 ...
"""
import os
import sys

sys.path.insert(0, "src")
from repro.launch import dryrun as D  # noqa: E402  (sets XLA_FLAGS first)

VARIANTS = {
    "base": {},
    "remat_off": {"remat": False},
    "mb1": {"num_microbatches": 1},
    "mb4": {"num_microbatches": 4},
    "mb8": {"num_microbatches": 8},
    "sp": {"sp": True},
    "chunk512": {"attn_chunk": 512},
    "chunk4096": {"attn_chunk": 4096},
    "remat_off_mb1": {"remat": False, "num_microbatches": 1},
    "rg0": {"remat_group": 0},
    "barrier": {"barrier_xs": True},
}


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    variants = sys.argv[3:] or ["base"]
    out_dir = f"artifacts/hillclimb/{arch}__{shape}"
    os.makedirs(out_dir, exist_ok=True)
    for name in variants:
        overrides = VARIANTS[name]
        try:
            rec = D.run_cell(arch, shape, multi_pod=False, probe=True,
                             out_dir=os.path.join(out_dir, name), **overrides)
            if rec.get("skipped"):
                print(f"{name}: SKIP")
                continue
            from benchmarks.roofline import analyze_cell
            from repro.configs import get_config

            row = analyze_cell(rec, get_config(arch))
            m = rec["memory"]
            print(f"{name:16s} peak={m['peak_bytes']/2**30:6.2f}GiB "
                  f"compute={row['compute_s']:.3e}s memory={row['memory_s']:.3e}s "
                  f"coll={row['collective_s']:.3e}s bottleneck={row['bottleneck']} "
                  f"useful/HLO={row['useful_flop_ratio']:.3f} "
                  f"roofline={row['roofline_fraction']:.2%}")
        except Exception as e:
            print(f"{name}: FAIL {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
