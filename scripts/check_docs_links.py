"""Docs link check: every intra-repo markdown link in docs/ (and README.md)
must resolve to an existing file.  Zero dependencies; CI runs it on every
push so the handbook cannot silently rot as modules move.

  python scripts/check_docs_links.py [root]

Checked: relative `[text](target)` links (with optional #anchor stripped and
verified against the target's headings when the target is markdown).
Skipped: absolute URLs (http/https/mailto) and pure #anchors into the same
file (those are checked against the file's own headings).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _anchor(text: str) -> str:
    """GitHub-style heading anchor: lowercase, drop non-word chars except
    hyphens/spaces, spaces to hyphens."""
    text = re.sub(r"[`*_]", "", text.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return re.sub(r"\s+", "-", text)


def _headings(path: Path) -> set[str]:
    return {_anchor(m.group(1)) for m in HEADING_RE.finditer(path.read_text())}


def check_file(md: Path, root: Path) -> list[str]:
    errors = []
    for m in LINK_RE.finditer(md.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if not dest.exists():
            errors.append(f"{md.relative_to(root)}: dangling link -> {target}")
            continue
        if frag and dest.suffix == ".md":
            if _anchor(frag) not in _headings(dest):
                errors.append(
                    f"{md.relative_to(root)}: missing anchor -> {target}"
                )
    return errors


def main(root: Path) -> int:
    files = sorted((root / "docs").glob("**/*.md")) + [root / "README.md"]
    missing = [f for f in files if not f.exists()]
    errors = [f"missing expected file: {f}" for f in missing]
    for md in files:
        if md.exists():
            errors += check_file(md, root)
    if errors:
        print("\n".join(errors))
        print(f"[check_docs_links] FAILED: {len(errors)} problem(s)")
        return 1
    print(f"[check_docs_links] OK: {len(files)} files, all intra-repo links resolve")
    return 0


if __name__ == "__main__":
    root = Path(sys.argv[1]).resolve() if len(sys.argv) > 1 else Path(__file__).resolve().parent.parent
    sys.exit(main(root))
