"""Memory-iteration probe: grad-only grok-1 microbatch step with XLA buffer
dump, reporting the top temp regions.  Usage:
  PYTHONPATH=src python scripts/memprobe.py [--remat-group N] [--arch A]
"""
import os
import sys

args = dict(a.split("=") for a in sys.argv[1:] if "=" in a)
DUMP = args.get("dump", "/tmp/xladump")
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count=512 --xla_dump_to={DUMP}"
)

import re
import dataclasses
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, "src")
from repro.configs import get_config, SHAPES
from repro.dist.sharding import make_plan, param_pspecs, valid_spec, batch_specs, batch_pspecs
from repro.models import transformer as T
from repro.launch.mesh import make_production_mesh

arch = args.get("arch", "grok-1-314b")
rg = int(args.get("rg", "0"))
mesh = make_production_mesh()
cfg = dataclasses.replace(get_config(arch), remat_group=rg)
plan = make_plan(mesh, cfg)
params_abs = T.abstract_params(cfg)
pspecs = param_pspecs(params_abs, plan)
pspecs = jax.tree.map(lambda a, s: valid_spec(a.shape, s, mesh), params_abs, pspecs,
                      is_leaf=lambda x: isinstance(x, P))
named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P))
shape_cfg = SHAPES[args.get("shape", "train_4k")]
batch_abs = batch_specs(cfg, shape_cfg, plan)
mbsize = int(args.get("mb", "16"))
mb = {k: jax.ShapeDtypeStruct((mbsize,) + v.shape[1:], v.dtype) for k, v in batch_abs.items()}
b_named = {k: NamedSharding(mesh, valid_spec(mb[k].shape, s, mesh))
           for k, s in batch_pspecs(cfg, shape_cfg, plan).items()}

def grad_only(params, batch):
    pc = jax.tree.map(lambda p: p.astype(jnp.bfloat16) if (p.dtype == jnp.float32 and p.ndim >= 2) else p, params)
    def loss_fn(p):
        l, m = T.apply_train(p, batch, cfg, plan)
        return l
    return jax.grad(loss_fn)(pc)

with mesh:
    c2 = jax.jit(grad_only, in_shardings=(named, b_named)).lower(params_abs, mb).compile()
    ma = c2.memory_analysis()
    print("GRAD-ONLY rg=%d: args %.2f out %.2f temp %.2f peak %.2f GiB" % (
        rg, ma.argument_size_in_bytes / 2**30, ma.output_size_in_bytes / 2**30,
        ma.temp_size_in_bytes / 2**30,
        (ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
         - ma.alias_size_in_bytes) / 2**30))

# parse the buffer assignment
import glob
fn = sorted(glob.glob(f"{DUMP}/*buffer-assignment.txt"))[-1]
txt = open(fn).read()
m = re.search(r"allocation (\d+): size (\d+), preallocated-temp:\n((?: .*\n)*)", txt)
if m:
    body = m.group(3)
    vals = re.findall(r"value: <\d+ ([^@]+)@\d+> \(size=(\d+),offset=(\d+)\): (\S+)", body)
    byoff = {}
    for name, size, off, shape in vals:
        size, off = int(size), int(off)
        if off not in byoff or size > byoff[off][0]:
            byoff[off] = (size, name.strip(), shape)
    rows = sorted(byoff.values(), reverse=True)
    print(f"top temp regions (preallocated-temp {int(m.group(2))/2**30:.2f} GiB):")
    for s, n, sh in rows[:16]:
        print(f"{s/2**20:9.1f} MiB  {sh:44s} {n[:70]}")
