"""Generate the §Dry-run and §Roofline tables in EXPERIMENTS.md from
artifacts/dryrun/*.json (replaces the <!-- DRYRUN-TABLE --> and
<!-- ROOFLINE-TABLE --> markers).

  PYTHONPATH=src:. python scripts/fill_experiments.py
"""
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import load_artifacts, table, render_markdown  # noqa: E402
from repro.configs import SHAPES, get_config, list_configs  # noqa: E402


def dryrun_table() -> str:
    recs = {(r["arch"], r["shape"], r["mesh"]): r for r in load_artifacts()}
    out = [
        "| arch | shape | mesh | peak GiB | fits 16 GiB | args GiB | compile s | mb | collectives (count: ag/ar/rs/a2a/cp) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in list_configs():
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    out.append(f"| {arch} | {shape} | {mesh} | — | — | — | — | — | MISSING |")
                    continue
                if r.get("skipped"):
                    if mesh == "single":
                        out.append(f"| {arch} | {shape} | both | — | — | — | — | — | SKIP: sub-quadratic-only shape |")
                    continue
                m = r["memory"]
                c = r.get("collectives", {})
                cc = "/".join(
                    str(c.get(k, {}).get("count", 0))
                    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
                )
                peak = m["peak_bytes"] / 2**30
                out.append(
                    f"| {arch} | {shape} | {mesh} | {peak:.2f} | "
                    f"{'yes' if peak <= 16 else '**no**'} | {m['argument_bytes']/2**30:.2f} | "
                    f"{r['compile_s']} | {r.get('num_microbatches') or '-'} | {cc} |"
                )
    return "\n".join(out)


def main():
    dr = dryrun_table()
    rows = table()
    rl = render_markdown(rows) if rows else "(no probe artifacts yet)"
    with open("EXPERIMENTS.md") as f:
        txt = f.read()
    txt = txt.replace("<!-- DRYRUN-TABLE -->", dr)
    txt = txt.replace("<!-- ROOFLINE-TABLE -->", rl)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(txt)
    ok = sum(1 for r in load_artifacts() if r.get("ok"))
    sk = sum(1 for r in load_artifacts() if r.get("skipped"))
    print(f"[fill_experiments] {ok} compiled cells, {sk} skip records; tables written")


if __name__ == "__main__":
    main()
