"""Calibration CLI: fit this machine's TPUSpec and warm the autotune cache.

  PYTHONPATH=src python scripts/calibrate.py [options]

Runs the measured-roofline calibration workflow (repro.tune.calibrate):
microbenchmarks (streaming-copy bandwidth + segment-matmul FLOP/s), a
block-sweep least-squares fit of (hbm_bw, peak_flops_f32), the
`obs.calibrate` validation join, and a persisted fitted spec in the autotune
cache — after which `pms.search(spec="measured")` and
`decompose(spec="measured")` price configurations with numbers this backend
actually achieves (docs/autotune.md).

Options:
  --preset NAME     frostt_like preset for the sweep samples (default: tiny)
  --rank R          CP rank of the calibration sweeps (default: 8)
  --reps N          timed repetitions per sample (default: 2)
  --cache-dir PATH  override $REPRO_AUTOTUNE_DIR for this run
  --dry-run         fit + report, but do not write the cache
  --check-hit       after fitting, assert a warm `spec="measured"` resolve
                    serves the stored spec without re-calibrating (the CI
                    calibration smoke) — exits non-zero on a miss
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--check-hit", action="store_true")
    a = ap.parse_args(argv)
    if a.cache_dir:
        os.environ["REPRO_AUTOTUNE_DIR"] = a.cache_dir

    from repro.tune import (
        calibrate,
        calibrate_and_store,
        cache_path,
        current_backend,
        default_cache,
        resolve_spec,
    )

    kwargs = dict(preset=a.preset, rank=a.rank, reps=a.reps)
    if a.dry_run:
        result = calibrate(**kwargs)
    else:
        result = calibrate_and_store(**kwargs)

    spec = result.spec
    print(f"backend: {result.backend}")
    if result.stream_hbm_bw is not None:
        print(f"microbench: stream bw {result.stream_hbm_bw/1e9:.2f} GB/s, "
              f"matmul {result.matmul_peak_flops_f32/1e9:.1f} GFLOP/s (f32)")
    print(f"fitted: hbm_bw {spec.hbm_bw/1e9:.3f} GB/s, "
          f"peak_flops_f32 {spec.peak_flops_f32/1e9:.1f} GFLOP/s "
          f"(sum-model residual {result.residual_rel:.1%})")
    print(f"validation (obs.calibrate achieved_pct, default -> measured):")
    for row in result.validation:
        print(f"  {row['label']:32s} {row['achieved_pct_default']:10.4f}% -> "
              f"{row['achieved_pct_measured']:7.2f}%")
    if a.dry_run:
        print("dry run: cache not written")
        return 0
    print(f"stored -> {cache_path()} (backend {result.backend!r})")

    if a.check_hit:
        # The warm-path assertion CI gates on: the spec must come back from
        # the cache, not from a fresh calibration.
        got = default_cache().get_spec(current_backend())
        if got != spec:
            print("check-hit FAILED: cached spec does not match the fit",
                  file=sys.stderr)
            return 1
        assert resolve_spec("measured", calibrate_on_miss=False) == spec
        print("check-hit OK: warm spec='measured' resolves from the cache")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
