"""Trace-report CLI: summarize a repro.obs trace JSONL on the terminal.

  PYTHONPATH=src python scripts/trace_report.py TRACE.jsonl [options]

Default output is a per-span-name summary table (count, total/mean/max
duration) plus the instant-event counts — the sixty-second answer to "where
did this decompose() spend its time".  Options:

  --pms           achieved-vs-predicted table from the trace's "sweep" spans
                  (repro.obs.calibrate.join_trace; spans carry `predicted_s`
                  when the workspace has a PMS hook)
  --chrome PATH   convert the JSONL to Chrome trace-event JSON (open in
                  chrome://tracing or https://ui.perfetto.dev)
  --by-mode       break span rows out by their `mode` arg (plan_build /
                  plan_cache_build spans carry one)

The loader validates every line (repro.obs.trace.load_jsonl); a malformed
file exits non-zero, so CI can gate on "the emitted trace parses".
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.calibrate import format_table, join_trace  # noqa: E402
from repro.obs.trace import load_jsonl  # noqa: E402


def _span_key(rec: dict, by_mode: bool) -> str:
    name = rec["name"]
    if by_mode and "mode" in rec.get("args", {}):
        return f"{name}[mode={rec['args']['mode']}]"
    return name


def summarize(records: list[dict], by_mode: bool = False) -> str:
    spans: dict[str, list[float]] = defaultdict(list)
    events: dict[str, int] = defaultdict(int)
    for r in records:
        if r.get("ph") == "X":
            spans[_span_key(r, by_mode)].append(float(r.get("dur", 0.0)))
        elif r.get("ph") == "i":
            events[r["name"]] += 1
    lines = []
    if spans:
        header = (f"{'span':<28} {'count':>6} {'total_s':>10} "
                  f"{'mean_s':>10} {'max_s':>10}")
        lines += [header, "-" * len(header)]
        for name, durs in sorted(
            spans.items(), key=lambda kv: -sum(kv[1])
        ):
            tot = sum(durs) / 1e6
            lines.append(
                f"{name:<28} {len(durs):>6d} {tot:>10.4f} "
                f"{tot / len(durs):>10.4f} {max(durs) / 1e6:>10.4f}"
            )
    if events:
        lines.append("")
        header = f"{'event':<28} {'count':>6}"
        lines += [header, "-" * len(header)]
        for name, n in sorted(events.items(), key=lambda kv: -kv[1]):
            lines.append(f"{name:<28} {n:>6d}")
    return "\n".join(lines) if lines else "(empty trace)"


def to_chrome(records: list[dict], path: str | Path) -> None:
    """Chrome trace-event JSON: the JSONL records already use the trace-event
    field names (ph/name/ts/dur/pid/tid/args), so conversion is wrapping them
    in the envelope (and dropping the JSONL-only id/parent link fields)."""
    events = []
    for r in records:
        ev = {k: v for k, v in r.items() if k not in ("id", "parent")}
        events.append(ev)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        f.write("\n")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace JSONL (REPRO_TRACE=path / "
                                  "decompose(trace=path) output)")
    ap.add_argument("--pms", action="store_true",
                    help="achieved-vs-predicted PMS table from sweep spans")
    ap.add_argument("--chrome", metavar="PATH", default=None,
                    help="write Chrome trace-event JSON to PATH")
    ap.add_argument("--by-mode", action="store_true",
                    help="break spans out by their `mode` arg")
    a = ap.parse_args(argv)

    try:
        records = load_jsonl(a.trace)
    except (OSError, ValueError) as e:
        print(f"trace_report: invalid trace {a.trace}: {e}", file=sys.stderr)
        return 1
    if not records:
        print(f"trace_report: {a.trace} holds no records", file=sys.stderr)
        return 1

    print(f"# {a.trace}: {len(records)} records")
    print(summarize(records, by_mode=a.by_mode))
    if a.pms:
        rows = join_trace(records)
        print()
        if rows:
            print(format_table(rows))
        else:
            print("(no sweep spans to join)")
    if a.chrome:
        to_chrome(records, a.chrome)
        print(f"\nchrome trace -> {a.chrome}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
