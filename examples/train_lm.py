"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on the synthetic Markov corpus, with checkpoints and restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]

~100M params: 12 layers x d_model 512 + 32k vocab (tied) ≈ 60M backbone +
33M embedding.  Loss should fall well below the unigram entropy as the model
learns the bigram chain.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline, make_batch_iterator
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=32_768)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen3-0.6b"),
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=8,
        n_kv_heads=4,
        head_dim=64,
        d_ff=4 * args.d_model // 2 * 2,
        vocab=args.vocab,
        remat=False,
        compute_dtype="float32",
    )
    nparams = cfg.param_count()
    print(f"[train_lm] model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab} "
          f"~{nparams/1e6:.0f}M params")

    opt = AdamWConfig(lr=args.lr, warmup_steps=40, total_steps=args.steps)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step_fn = jax.jit(make_train_step(cfg, opt, num_microbatches=1, attn_chunk=256),
                      donate_argnums=(0,))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch, seed=0)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    it = make_batch_iterator(pipe, start_index=0, depth=2)
    t0 = time.time()
    toks_done = 0
    for step in range(args.steps):
        batch = jax.tree.map(jnp.asarray, next(it))
        state, metrics = step_fn(state, batch)
        toks_done += args.batch * args.seq
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:4d} loss={float(metrics['loss']):7.4f} "
                  f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):6.2f} "
                  f"{toks_done/dt:,.0f} tok/s")
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, state, blocking=False)
    ckpt.save(args.steps, state, blocking=True)
    it.close()
    print(f"[train_lm] done; final loss {float(metrics['loss']):.4f} "
          f"(unigram entropy of the corpus is ~6-7 nats; bigram structure "
          f"should pull CE toward ~{0.7*0+2.5:.1f})")


if __name__ == "__main__":
    main()
