"""Quickstart: decompose a synthetic FROSTT-like sparse tensor with CP-ALS,
with the memory-controller-planned Pallas MTTKRP as the compute engine.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core.coo import frostt_like
from repro.core.cp_als import cp_als
from repro.core.hypergraph import approach1_traffic, approach2_traffic, remap_overhead
from repro.core.pms import search
from repro.kernels.ops import make_planned_mttkrp


def main():
    # 1. A sparse tensor shaped like the FROSTT repository's (paper Table 2)
    st = frostt_like("small")
    rank = 16
    print(f"tensor: shape={st.shape} nnz={st.nnz:,} density={st.density:.2e}")

    # 2. The paper's Table 1: why Approach 1 (output-direction) wins
    t1 = approach1_traffic(st, 0, rank)
    t2 = approach2_traffic(st, 0, rank)
    print(f"traffic (elements): approach1={t1.total_elems:,} approach2={t2.total_elems:,} "
          f"(x{t2.total_elems/t1.total_elems:.2f}); remap overhead={remap_overhead(st, 0, rank):.2%}")

    # 3. PMS (Sec 5.3): pick the memory-controller configuration
    best = search(st, 0, rank, top_k=3)
    for e in best:
        c, d = e.cfg.cache, e.cfg.dma
        print(f"PMS: tiles=({c.tile_i},{c.tile_j},{c.tile_k}) blk={d.blk} "
              f"-> t={e.t_total*1e6:.1f}us [{e.bottleneck}-bound] vmem={e.vmem_bytes/2**20:.0f}MiB")

    # 4. CP-ALS with the planned Pallas kernel (interpret mode on CPU)
    small = frostt_like("tiny")
    ops = {m: make_planned_mttkrp(small.sorted_by(m), m, 8, interpret=True) for m in range(3)}

    def pallas_mttkrp(indices, values, factors, mode, out_rows):
        return ops[mode].output(factors, out_rows)

    t0 = time.time()
    state = cp_als(small, rank=8, iters=5, layout="copies", mttkrp_fn=pallas_mttkrp, verbose=True)
    print(f"CP-ALS fit={state.fit_history[-1]:.4f} in {time.time()-t0:.1f}s "
          f"(Pallas kernel, interpret mode)")


if __name__ == "__main__":
    main()
