"""Quickstart: decompose a synthetic FROSTT-like sparse tensor with CP-ALS,
with the memory-controller-planned Pallas MTTKRP as the compute engine —
`cp_als(method="pallas")` builds a `PlannedCPALS` workspace (one remapped,
device-resident BlockPlan per output mode, paper Alg. 5) once and reuses it
for every ALS iteration (paper Alg. 1).

  PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import argparse
import time

import jax

from repro.core.coo import frostt_like
from repro.core.cp_als import cp_als
from repro.core.hypergraph import approach1_traffic, approach2_traffic, remap_overhead
from repro.core.pms import search
from repro.kernels.ops import make_planned_cp_als


def main(fast: bool = False):
    # 1. A sparse tensor shaped like the FROSTT repository's (paper Table 2)
    st = frostt_like("tiny" if fast else "small")
    rank = 16
    print(f"tensor: shape={st.shape} nnz={st.nnz:,} density={st.density:.2e}")

    # 2. The paper's Table 1: why Approach 1 (output-direction) wins
    t1 = approach1_traffic(st, 0, rank)
    t2 = approach2_traffic(st, 0, rank)
    print(f"traffic (elements): approach1={t1.total_elems:,} approach2={t2.total_elems:,} "
          f"(x{t2.total_elems/t1.total_elems:.2f}); remap overhead={remap_overhead(st, 0, rank):.2%}")

    # 3. PMS (Sec 5.3): pick the memory-controller configuration
    best = search(st, 0, rank, top_k=3)
    for e in best:
        c, d = e.cfg.cache, e.cfg.dma
        print(f"PMS: tiles=({c.tile_i},{c.tile_j},{c.tile_k}) blk={d.blk} "
              f"-> t={e.t_total*1e6:.1f}us [{e.bottleneck}-bound] vmem={e.vmem_bytes/2**20:.0f}MiB")

    # 4. CP-ALS entirely on the planned Pallas kernel (interpret mode on CPU):
    #    plans are built once per mode and amortized over all iterations.
    small = frostt_like("tiny")
    planned = make_planned_cp_als(small, 8, interpret=True)
    print(f"planned workspace: {small.nmodes} mode plans, "
          f"{planned.plan_bytes()/2**20:.2f} MiB of remapped copies on HBM")

    iters = 2 if fast else 5
    t0 = time.time()
    state = cp_als(small, rank=8, iters=iters, method="pallas", planned=planned, verbose=True)
    print(f"CP-ALS fit={state.fit_history[-1]:.4f} in {time.time()-t0:.1f}s "
          f"(PlannedCPALS, interpret mode)")

    # 5. The same workspace drives higher-order tensors (Table 2 has 3–5 modes)
    if not fast:
        st4 = frostt_like("4d_small")
        s4 = cp_als(st4, rank=8, iters=2, method="pallas")
        print(f"4-mode CP-ALS fit={s4.fit_history[-1]:.4f} (N-mode kernel)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke subset")
    main(fast=ap.parse_args().fast)
