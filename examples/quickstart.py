"""Quickstart: decompose a synthetic FROSTT-like sparse tensor on the
memory-controller-planned Pallas kernels — both decompositions the substrate
serves run from this one entry point:

  * --algo cp      (default)  CP-ALS on the planned MTTKRP kernel:
    `cp_als(method="pallas")` builds a `PlannedCPALS` workspace (one
    remapped, device-resident BlockPlan per output mode, paper Alg. 5) once
    and reuses it for every ALS iteration (paper Alg. 1).
  * --algo tucker             Sparse Tucker (HOOI) on the planned TTM-chain
    kernel: `tucker_hooi(method="pallas")` drives the same per-mode BlockPlan
    layouts through the Kronecker-chain kernel — the controller is
    programmable, not CP-specific.

  PYTHONPATH=src python examples/quickstart.py [--algo {cp,tucker}] [--fast]
"""
import argparse
import time

import jax

from repro.core.coo import frostt_like
from repro.core.cp_als import cp_als
from repro.core.hypergraph import approach1_traffic, approach2_traffic, remap_overhead
from repro.core.pms import search
from repro.kernels.ops import make_planned_cp_als
from repro.tucker import make_planned_tucker, tucker_hooi


def _print_pms(best):
    for e in best:
        c, d = e.cfg.cache, e.cfg.dma
        print(f"PMS: tiles=({c.tile_i},{c.tile_j},{c.tile_k}) blk={d.blk} "
              f"-> t={e.t_total*1e6:.1f}us [{e.bottleneck}-bound] vmem={e.vmem_bytes/2**20:.0f}MiB")


def run_cp(st, fast: bool):
    rank = 16
    # The paper's Table 1: why Approach 1 (output-direction) wins
    t1 = approach1_traffic(st, 0, rank)
    t2 = approach2_traffic(st, 0, rank)
    print(f"traffic (elements): approach1={t1.total_elems:,} approach2={t2.total_elems:,} "
          f"(x{t2.total_elems/t1.total_elems:.2f}); remap overhead={remap_overhead(st, 0, rank):.2%}")

    # PMS (Sec 5.3): pick the memory-controller configuration for MTTKRP
    _print_pms(search(st, 0, rank, top_k=3))

    # CP-ALS entirely on the planned Pallas kernel (interpret mode on CPU):
    # plans are built once per mode and amortized over all iterations.
    small = frostt_like("tiny")
    planned = make_planned_cp_als(small, 8, interpret=True)
    print(f"planned workspace: {small.nmodes} mode plans, "
          f"{planned.plan_bytes()/2**20:.2f} MiB of remapped copies on HBM")

    iters = 2 if fast else 5
    t0 = time.time()
    state = cp_als(small, rank=8, iters=iters, method="pallas", planned=planned, verbose=True)
    print(f"CP-ALS fit={state.fit_history[-1]:.4f} in {time.time()-t0:.1f}s "
          f"(PlannedCPALS, interpret mode)")

    # The same workspace drives higher-order tensors (Table 2 has 3–5 modes)
    if not fast:
        st4 = frostt_like("4d_small")
        s4 = cp_als(st4, rank=8, iters=2, method="pallas")
        print(f"4-mode CP-ALS fit={s4.fit_history[-1]:.4f} (N-mode kernel)")


def run_tucker(st, fast: bool):
    core_ranks = (8, 8, 8)
    # PMS scored for the TTM-chain kernel: the core-tensor tile (Kronecker
    # width prod(R_m) lanes) changes both the VMEM fit and the roofline.
    _print_pms(search(st, 0, 16, kernel="ttmc", core_ranks=core_ranks, top_k=3))

    # HOOI entirely on the planned TTMc kernel — the SAME BlockPlan layouts
    # MTTKRP uses, built once per mode and amortized over all iterations.
    small = frostt_like("tiny")
    ranks_small = (4, 4, 4)
    planned = make_planned_tucker(small, ranks_small, interpret=True)
    print(f"planned workspace: {small.nmodes} mode plans, "
          f"{planned.plan_bytes()/2**20:.2f} MiB of remapped copies on HBM")

    iters = 2 if fast else 5
    t0 = time.time()
    state = tucker_hooi(small, ranks_small, iters=iters, method="pallas",
                        planned=planned, verbose=True)
    print(f"Tucker HOOI fit={state.fit_history[-1]:.4f} core={state.core.shape} "
          f"in {time.time()-t0:.1f}s (PlannedTucker, interpret mode)")

    if not fast:
        st4 = frostt_like("4d_small")
        s4 = tucker_hooi(st4, (3, 3, 3, 3), iters=2, method="pallas")
        print(f"4-mode Tucker fit={s4.fit_history[-1]:.4f} (N-mode TTMc kernel)")


def main(fast: bool = False, algo: str = "cp"):
    # A sparse tensor shaped like the FROSTT repository's (paper Table 2)
    st = frostt_like("tiny" if fast else "small")
    print(f"tensor: shape={st.shape} nnz={st.nnz:,} density={st.density:.2e} algo={algo}")
    if algo == "cp":
        run_cp(st, fast)
    elif algo == "tucker":
        run_tucker(st, fast)
    else:
        raise ValueError(f"unknown algo {algo!r}: expected 'cp' or 'tucker'")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke subset")
    ap.add_argument("--algo", choices=("cp", "tucker"), default="cp",
                    help="decomposition to run on the planned kernels")
    a = ap.parse_args()
    main(fast=a.fast, algo=a.algo)
