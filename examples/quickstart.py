"""Quickstart: decompose a synthetic FROSTT-like sparse tensor on the
memory-controller-planned Pallas kernels — every format the substrate serves
runs from this one entry point, through the unified `decompose()` facade
(repro/api.py):

  * --algo cp      (default)  CP-ALS on the planned MTTKRP kernel:
    `decompose(st, rank, format="cp")` builds a `PlannedCPALS` workspace
    (one remapped, device-resident BlockPlan per output mode, paper Alg. 5)
    once and reuses it for every ALS iteration (paper Alg. 1).
  * --algo tucker             Sparse Tucker (HOOI) on the planned TTM-chain
    kernel: `decompose(format="tucker")` drives the same per-mode BlockPlan
    layouts through the Kronecker-chain kernel — the controller is
    programmable, not CP-specific.
  * --algo tt                 Tensor-train ALS on the planned TT-core kernel:
    `decompose(format="tt")` drives the same layouts through the
    Kronecker-of-two-interfaces kernel — the third format on the substrate.
  * --devices N               Distribute any algorithm over N devices
    (`method="pallas_sharded"`, repro.dist.planned): the stream is
    partitioned into balanced output-tile ranges per mode, each shard's
    remapped layout is device-local, and every iteration is one shard_map
    sweep with a single psum per mode.  On CPU this forces an N-device host
    platform via XLA_FLAGS, which must happen BEFORE jax initializes — hence
    the deferred imports below.

  PYTHONPATH=src python examples/quickstart.py [--algo {cp,tucker,tt}]
                                               [--fast] [--devices N]
                                               [--trace PATH]
                                               [--auto-tune {off,on,cached}]

  --trace PATH exports an observability trace of the headline decompose()
  call as JSONL (repro.obs; summarize with scripts/trace_report.py, convert
  with --chrome for chrome://tracing).  REPRO_TRACE=1 (or =PATH) instead
  enables process-global tracing for everything this script runs.
  --auto-tune cached persists each mode's PMS winner in the on-disk autotune
  cache ($REPRO_AUTOTUNE_DIR or ~/.cache/repro-autotune; docs/autotune.md),
  so a rerun skips the config sweep entirely.
"""
import argparse
import os
import time


def _print_pms(best):
    for e in best:
        c, d = e.cfg.cache, e.cfg.dma
        print(f"PMS: tiles=({c.tile_i},{c.tile_j},{c.tile_k}) blk={d.blk} "
              f"-> t={e.t_total*1e6:.1f}us [{e.bottleneck}-bound] vmem={e.vmem_bytes/2**20:.0f}MiB")


def run_cp(st, fast: bool, devices: int, trace=None, auto_tune=False):
    from repro.api import decompose
    from repro.core.coo import frostt_like
    from repro.core.hypergraph import approach1_traffic, approach2_traffic, remap_overhead
    from repro.core.pms import search
    from repro.kernels.ops import make_planned_cp_als

    rank = 16
    # The paper's Table 1: why Approach 1 (output-direction) wins
    t1 = approach1_traffic(st, 0, rank)
    t2 = approach2_traffic(st, 0, rank)
    print(f"traffic (elements): approach1={t1.total_elems:,} approach2={t2.total_elems:,} "
          f"(x{t2.total_elems/t1.total_elems:.2f}); remap overhead={remap_overhead(st, 0, rank):.2%}")

    # PMS (Sec 5.3): pick the memory-controller configuration for MTTKRP
    _print_pms(search(st, 0, rank, top_k=3))

    # CP-ALS entirely on the planned Pallas kernel (interpret mode on CPU):
    # plans are built once per mode and amortized over all iterations.
    small = frostt_like("tiny")
    # With --auto-tune the facade builds (or, for "cached", loads) each
    # mode's PMS-selected configuration itself — no prebuilt workspace.
    planned = None if auto_tune else make_planned_cp_als(small, 8, interpret=True)
    if planned is not None:
        print(f"planned workspace: {small.nmodes} mode plans, "
              f"{planned.plan_bytes()/2**20:.2f} MiB of remapped copies on HBM")

    iters = 2 if fast else 5
    t0 = time.time()
    state = decompose(small, 8, format="cp", iters=iters, planned=planned,
                      auto_tune=auto_tune, verbose=True, trace=trace)
    print(f"CP-ALS fit={state.fit_history[-1]:.4f} in {time.time()-t0:.1f}s "
          f"(PlannedCPALS, interpret mode)")

    if devices > 1:
        # The same loop distributed: per-mode balanced stream partitions,
        # shard-local BlockPlans, one psum of factor rows per mode.
        t0 = time.time()
        sh = decompose(small, 8, format="cp", iters=iters,
                       method="pallas_sharded", devices=devices, verbose=True)
        print(f"CP-ALS (sharded x{devices}) fit={sh.fit_history[-1]:.4f} in "
              f"{time.time()-t0:.1f}s (single-device fit "
              f"{state.fit_history[-1]:.4f} — must match)")
        assert abs(sh.fit_history[-1] - state.fit_history[-1]) < 1e-4

    # The same workspace drives higher-order tensors (Table 2 has 3–5 modes)
    if not fast:
        st4 = frostt_like("4d_small")
        s4 = decompose(st4, 8, format="cp", iters=2)
        print(f"4-mode CP-ALS fit={s4.fit_history[-1]:.4f} (N-mode kernel)")


def run_tucker(st, fast: bool, devices: int, trace=None, auto_tune=False):
    from repro.api import decompose
    from repro.core.coo import frostt_like
    from repro.core.pms import search
    from repro.tucker import make_planned_tucker

    core_ranks = (8, 8, 8)
    # PMS scored for the TTM-chain kernel: the core-tensor tile (Kronecker
    # width prod(R_m) lanes) changes both the VMEM fit and the roofline.
    _print_pms(search(st, 0, 16, kernel="ttmc", core_ranks=core_ranks, top_k=3))

    # HOOI entirely on the planned TTMc kernel — the SAME BlockPlan layouts
    # MTTKRP uses, built once per mode and amortized over all iterations.
    small = frostt_like("tiny")
    ranks_small = (4, 4, 4)
    planned = None if auto_tune else make_planned_tucker(small, ranks_small, interpret=True)
    if planned is not None:
        print(f"planned workspace: {small.nmodes} mode plans, "
              f"{planned.plan_bytes()/2**20:.2f} MiB of remapped copies on HBM")

    iters = 2 if fast else 5
    t0 = time.time()
    state = decompose(small, ranks_small, format="tucker", iters=iters,
                      planned=planned, auto_tune=auto_tune, verbose=True,
                      trace=trace)
    print(f"Tucker HOOI fit={state.fit_history[-1]:.4f} core={state.core.shape} "
          f"in {time.time()-t0:.1f}s (PlannedTucker, interpret mode)")

    if devices > 1:
        t0 = time.time()
        sh = decompose(small, ranks_small, format="tucker", iters=iters,
                       method="pallas_sharded", devices=devices, verbose=True)
        print(f"Tucker HOOI (sharded x{devices}) fit={sh.fit_history[-1]:.4f} in "
              f"{time.time()-t0:.1f}s (single-device fit "
              f"{state.fit_history[-1]:.4f} — must match)")
        assert abs(sh.fit_history[-1] - state.fit_history[-1]) < 1e-4

    if not fast:
        st4 = frostt_like("4d_small")
        s4 = decompose(st4, (3, 3, 3, 3), format="tucker", iters=2)
        print(f"4-mode Tucker fit={s4.fit_history[-1]:.4f} (N-mode TTMc kernel)")


def run_tt(st, fast: bool, devices: int, trace=None, auto_tune=False):
    from repro.api import decompose
    from repro.core.coo import frostt_like
    from repro.core.pms import search
    from repro.tt import make_planned_tt

    tt_ranks = (8, 8)
    # PMS scored for the TT-core kernel: the two-interface scratch and the
    # rank_padded(rl*rr) lane widths change the VMEM fit and the roofline.
    _print_pms(search(st, 0, 16, kernel="tt", core_ranks=tt_ranks, top_k=3))

    # TT-ALS entirely on the planned TT-core kernel — the SAME BlockPlan
    # layouts MTTKRP/TTMc use, built once per mode and amortized over all
    # iterations.
    small = frostt_like("tiny")
    ranks_small = (4, 4)
    planned = None if auto_tune else make_planned_tt(small, ranks_small, interpret=True)
    if planned is not None:
        print(f"planned workspace: {small.nmodes} mode plans, "
              f"{planned.plan_bytes()/2**20:.2f} MiB of remapped copies on HBM")

    iters = 2 if fast else 5
    t0 = time.time()
    state = decompose(small, ranks_small, format="tt", iters=iters,
                      planned=planned, auto_tune=auto_tune, verbose=True,
                      trace=trace)
    print(f"TT-ALS fit={state.fit_history[-1]:.4f} tt_ranks={state.tt_ranks} "
          f"in {time.time()-t0:.1f}s (PlannedTT, interpret mode)")

    if devices > 1:
        t0 = time.time()
        sh = decompose(small, ranks_small, format="tt", iters=iters,
                       method="pallas_sharded", devices=devices, verbose=True)
        print(f"TT-ALS (sharded x{devices}) fit={sh.fit_history[-1]:.4f} in "
              f"{time.time()-t0:.1f}s (single-device fit "
              f"{state.fit_history[-1]:.4f} — must match)")
        assert abs(sh.fit_history[-1] - state.fit_history[-1]) < 1e-4

    if not fast:
        st4 = frostt_like("4d_small")
        s4 = decompose(st4, (3, 3, 3), format="tt", iters=2)
        print(f"4-mode TT-ALS fit={s4.fit_history[-1]:.4f} (N-mode TT kernel)")


def main(fast: bool = False, algo: str = "cp", devices: int = 1,
         trace: str | None = None, auto_tune=False):
    import jax

    from repro.core.coo import frostt_like

    if devices > 1 and jax.device_count() < devices:
        raise SystemExit(
            f"need {devices} devices but jax sees {jax.device_count()}; on "
            f"CPU run through `python examples/quickstart.py --devices "
            f"{devices}` (it sets XLA_FLAGS before jax initializes)"
        )
    # A sparse tensor shaped like the FROSTT repository's (paper Table 2)
    st = frostt_like("tiny" if fast else "small")
    print(f"tensor: shape={st.shape} nnz={st.nnz:,} density={st.density:.2e} "
          f"algo={algo} devices={devices}")
    if algo == "cp":
        run_cp(st, fast, devices, trace, auto_tune)
    elif algo == "tucker":
        run_tucker(st, fast, devices, trace, auto_tune)
    elif algo == "tt":
        run_tt(st, fast, devices, trace, auto_tune)
    else:
        raise ValueError(f"unknown algo {algo!r}: expected 'cp', 'tucker' or 'tt'")
    if trace:
        print(f"trace -> {trace} (summarize: python scripts/trace_report.py {trace})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke subset")
    ap.add_argument("--algo", choices=("cp", "tucker", "tt"), default="cp",
                    help="decomposition to run on the planned kernels")
    ap.add_argument("--devices", type=int, default=1,
                    help="run the sharded planned path over N devices "
                         "(forces an N-device CPU host platform if needed)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the headline decompose() call's obs trace "
                         "as JSONL to PATH (see scripts/trace_report.py)")
    ap.add_argument("--auto-tune", choices=("off", "on", "cached"),
                    default="off", dest="auto_tune",
                    help="PMS tuning for the headline decompose() call: "
                         "'on' searches every run; 'cached' persists/reuses "
                         "the winners on disk ($REPRO_AUTOTUNE_DIR, see "
                         "docs/autotune.md) — a warm cache skips the sweep")
    a = ap.parse_args()
    if a.devices > 1:
        # Must precede the first jax import: the host device count locks at
        # jax init.  Honor a pre-existing forced count only if it is large
        # enough — otherwise fail here with the actual conflict, not after
        # jax has locked the smaller count.
        import re

        flags = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"xla_force_host_platform_device_count=(\d+)", flags)
        if m is None:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={a.devices}".strip()
            )
        elif int(m.group(1)) < a.devices:
            raise SystemExit(
                f"XLA_FLAGS already forces {m.group(1)} host devices but "
                f"--devices {a.devices} was requested; unset "
                f"xla_force_host_platform_device_count or raise it to "
                f">= {a.devices}"
            )
    main(fast=a.fast, algo=a.algo, devices=a.devices, trace=a.trace,
         auto_tune={"off": False, "on": True, "cached": "cached"}[a.auto_tune])
