"""Fault-tolerance demo: inject a node failure mid-training and watch the
supervisor restore from the atomic checkpoint and finish, reproducing the
exact batch stream.

  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import shutil
import tempfile

from repro.launch.train import main as train_main


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_ft_")
    try:
        rc = train_main([
            "--arch", "qwen3-0.6b", "--reduced",
            "--steps", "24", "--batch", "4", "--seq", "64",
            "--ckpt-dir", ckpt, "--ckpt-every", "8",
            "--fail-at-step", "13",  # dies AFTER the step-8 checkpoint
            "--max-restarts", "2", "--log-every", "4",
            "--attn-chunk", "64",
        ])
        print(f"\n[demo] supervisor exit code: {rc} "
              f"(0 = recovered from the injected failure and completed)")
        assert rc == 0
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
