"""Batched serving example: prefill a batch of prompts on a reduced
qwen3 / jamba model and decode greedily, printing throughput per phase.

  PYTHONPATH=src python examples/serve_batch.py [--arch jamba-v0.1-52b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    B, S = args.batch, args.prompt_len
    params = T.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(key, (B, cfg.img_tokens, cfg.d_model)) * 0.1

    cache_len = S + args.new_tokens
    prefill = jax.jit(make_prefill_step(cfg, cache_len=cache_len, attn_chunk=32))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(3,))

    t0 = time.time()
    logits, caches = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.time() - t0

    cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((B,), S, jnp.int32)
    toks = [cur]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        cur, _, caches = decode(params, cur, pos, caches, batch)
        toks.append(cur)
        pos = pos + 1
    jax.block_until_ready(cur)
    t_decode = time.time() - t0

    out = np.asarray(jnp.concatenate(toks, 1))
    print(f"[serve] {args.arch} (reduced) batch={B} prompt={S} new={args.new_tokens}")
    print(f"[serve] prefill {B*S/t_prefill:,.0f} tok/s | decode "
          f"{B*(args.new_tokens-1)/t_decode:,.0f} tok/s "
          f"({t_decode/(args.new_tokens-1)*1e3:.1f} ms/step)")
    print(f"[serve] first sequence continuation: {out[0][:16].tolist()}")


if __name__ == "__main__":
    main()
