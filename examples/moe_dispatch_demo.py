"""The paper's memory-controller insight applied to MoE: dispatch tokens to
experts by Approach 1 (remap / counting sort — contiguous per-expert
buffers, no partial tensors) vs Approach 2 (one-hot dispatch tensors), and
verify they compute the same layer while moving very different traffic.

  PYTHONPATH=src python examples/moe_dispatch_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.moe import moe_apply, moe_init


def main():
    G, Tg, D, E, k = 2, 512, 128, 8, 2
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (G, Tg, D)) * 0.3

    outs = {}
    for dispatch in ("remap", "onehot"):
        cfg = MoEConfig(num_experts=E, top_k=k, d_ff=256, capacity_factor=1.25,
                        dispatch=dispatch)
        params = moe_init(key, D, cfg, "silu")
        fn = jax.jit(lambda p, x: moe_apply(p, x, cfg, "silu")[0])
        compiled = fn.lower(params, x).compile()
        ca = compiled.cost_analysis() or {}
        out = fn(params, x)
        out.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            out = fn(params, x)
        out.block_until_ready()
        wall = (time.perf_counter() - t0) / 10
        outs[dispatch] = np.asarray(out)
        print(f"{dispatch:7s}: bytes={ca.get('bytes accessed', -1):.3e} "
              f"flops={ca.get('flops', -1):.3e} wall={wall*1e6:.0f}us")

    err = np.abs(outs["remap"] - outs["onehot"]).max()
    print(f"max |remap - onehot| = {err:.2e}  (identical math, different memory "
          f"schedule — the paper's Approach 1 vs 2, Sec. 3)")


if __name__ == "__main__":
    main()
