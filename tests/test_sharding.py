"""Sharding rules: spec validity/fallbacks over every arch's parameter tree
(pure spec logic — no multi-device init needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config, list_configs
from repro.dist.sharding import ShardingPlan, _leaf_spec, batch_specs, valid_spec
from repro.models import transformer as T


class _FakeMesh:
    """Duck-typed mesh: only .shape and .axis_names are consulted by the
    spec rules."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _plan(fsdp=False):
    mesh = _FakeMesh({"data": 16, "model": 16})
    return ShardingPlan(mesh=mesh, dp=("data",), tp="model", fsdp=fsdp)


def _check_divisible(shape, spec, mesh):
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for dim, axis in zip(shape, entries):
        if axis is None:
            continue
        names = axis if isinstance(axis, tuple) else (axis,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        assert dim % size == 0, (shape, spec)


@pytest.mark.parametrize("arch", list_configs())
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_always_divisible(arch, fsdp):
    """For every arch and every leaf: the chosen spec divides the dims —
    with whisper's vocab (51866) exercising the fallback path."""
    cfg = get_config(arch)
    plan = _plan(fsdp)
    abstract = T.abstract_params(cfg)

    def check(path, leaf):
        keys = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        spec = _leaf_spec(keys, tuple(leaf.shape), plan)
        spec = valid_spec(tuple(leaf.shape), spec, plan.mesh)
        _check_divisible(leaf.shape, spec, plan.mesh)
        return spec

    specs = jax.tree_util.tree_map_with_path(check, abstract)
    # big matrices must actually be TP-sharded (not silently replicated)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    shas = {tuple(str(k) for k in path): spec for path, spec in flat}
    n_sharded = sum(
        1 for s in shas.values() if any(e is not None for e in (list(s) if s else []))
    )
    assert n_sharded > len(shas) / 4, "too few sharded leaves"


def test_whisper_vocab_fallback():
    """51866 doesn't divide 16 -> vocab dim unsharded, d_model picks up TP."""
    plan = _plan()
    spec = _leaf_spec(("embed",), (51_866, 1280), plan)
    assert spec == P(None, "model")


def test_valid_spec_drops_nondividing():
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert valid_spec((1, 524_288), P("data", "model"), mesh) == P(None, "model")
    assert valid_spec((256, 100), P("data", "model"), mesh) == P("data", None)
    assert valid_spec((32,), P(("data", "model"),), mesh) == P(None)
    assert valid_spec((512,), P(("data", "model"),), mesh) == P(("data", "model"))


@pytest.mark.parametrize("arch", list_configs())
@pytest.mark.parametrize("shape", list(SHAPES))
def test_batch_specs_cover_model_inputs(arch, shape):
    cfg = get_config(arch)
    plan = _plan()
    specs = batch_specs(cfg, SHAPES[shape], plan)
    assert "tokens" in specs
    if SHAPES[shape].kind == "decode":
        assert specs["tokens"].shape[1] == 1 and "pos" in specs
    else:
        assert specs["tokens"].shape == (SHAPES[shape].global_batch, SHAPES[shape].seq_len)
    if cfg.family == "audio":
        assert specs["frames"].shape == (SHAPES[shape].global_batch, cfg.encoder_seq, cfg.d_model)
    if cfg.family == "vlm":
        assert specs["images"].shape[1] == cfg.img_tokens
