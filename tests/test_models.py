"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with shape + finiteness asserts, and exact prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import transformer as T

ARCHS = list_configs()


def _batch(cfg, key, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(key, (B, cfg.img_tokens, cfg.d_model)) * 0.1
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = {get_config(a).family for a in ARCHS}
    assert fams == {"dense", "moe", "ssm", "audio", "vlm", "hybrid"}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.param_count() > 0 and cfg.source
    # spot-check the assignment table numbers
    table = {
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151_936),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256_000),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200_064),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151_936),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32_064),
        "grok-1-314b": (64, 6144, 48, 8, 32_768, 131_072),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50_280),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51_866),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14_336, 128_256),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14_336, 65_536),
    }
    L, d, h, kv, ff, v = table[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_train_step(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    loss, metrics = T.apply_train(params, batch, cfg, attn_chunk=8)
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) == batch["tokens"].size
    # one grad step moves the loss
    g = jax.grad(lambda p: T.apply_train(p, batch, cfg, attn_chunk=8)[0])(params)
    assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
    p2 = jax.tree.map(lambda p, gg: p - 0.1 * gg.astype(p.dtype), params, g)
    loss2, _ = T.apply_train(p2, batch, cfg, attn_chunk=8)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, key):
    """decode_step after prefill == direct forward at position S (exact)."""
    cfg = get_config(arch).reduced()
    B, S = 2, 16
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key, B, S)
    logits_pre, caches = T.prefill(params, batch, cfg, cache_len=S + 4, attn_chunk=8)
    assert logits_pre.shape == (B, cfg.vocab)
    nxt = jnp.argmax(logits_pre, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits_dec, _ = T.decode_step(params, nxt, pos, caches, batch, cfg)
    batch2 = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    h2, _ = T.forward_hidden(params, batch2, cfg, attn_chunk=1)
    ref = T.lm_logits(params, h2[:, -1:], cfg)[:, 0]
    err = float(jnp.abs(ref - logits_dec).max()) / max(1.0, float(jnp.abs(ref).max()))
    assert err < 2e-2, err


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "jamba-v0.1-52b", "phi3.5-moe-42b-a6.6b"])
def test_remat_and_groups_numerically_identical(arch, key):
    cfg0 = dataclasses.replace(get_config(arch).reduced(), remat=False)
    p = cfg0.period
    cfg0 = dataclasses.replace(cfg0, n_layers=4 * p)
    cfg1 = dataclasses.replace(cfg0, remat=True)
    cfg2 = dataclasses.replace(cfg0, remat=True, remat_group=2)
    params = T.init_params(key, cfg0)
    batch = _batch(cfg0, key)
    l0 = float(T.apply_train(params, batch, cfg0, attn_chunk=8)[0])
    l1 = float(T.apply_train(params, batch, cfg1, attn_chunk=8)[0])
    l2 = float(T.apply_train(params, batch, cfg2, attn_chunk=8)[0])
    assert l0 == pytest.approx(l1, abs=1e-6) == pytest.approx(l2, abs=1e-6)


def test_param_count_analytic_vs_actual():
    """Analytic param_count (used for roofline MODEL_FLOPS) matches the real
    tree within 2% for a dense arch."""
    cfg = get_config("qwen3-0.6b")
    small = cfg.reduced()
    params = T.init_params(jax.random.PRNGKey(0), small)
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    assert abs(actual - small.param_count()) / actual < 0.02


def test_moe_active_params_less_than_total():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    assert cfg.active_param_count() < cfg.param_count() / 3
    # ballpark the published sizes: 42B total / 6.6B active
    assert 30e9 < cfg.param_count() < 55e9
    assert 5e9 < cfg.active_param_count() < 9e9
