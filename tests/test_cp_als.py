"""CP-ALS (paper Alg. 1) end-to-end: convergence, method/layout equivalence,
and the Pallas-kernel-backed path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops_mod
from repro.core.coo import SparseTensor, frostt_like, synthetic_tensor
from repro.core.cp_als import _normalize, cp_als, fit_value, gram_hadamard
from repro.kernels.ops import make_planned_cp_als, make_planned_mttkrp


def low_rank_tensor(shape=(20, 15, 18), rank=4, seed=0) -> SparseTensor:
    """Exactly-low-rank tensor with FULL support in COO form.  (Sampling a
    low-rank tensor at sparse coordinates does NOT give a low-rank sparse
    tensor — CP-ALS fits the implicit zeros too — so the recovery test needs
    every entry present.)"""
    rng = np.random.default_rng(seed)
    facs = [rng.standard_normal((s, rank)) for s in shape]
    grids = np.meshgrid(*[np.arange(s) for s in shape], indexing="ij")
    idx = np.stack([g.ravel() for g in grids], axis=1).astype(np.int32)
    vals = np.einsum("zr,zr,zr->z", facs[0][idx[:, 0]], facs[1][idx[:, 1]], facs[2][idx[:, 2]])
    return SparseTensor(idx, vals.astype(np.float32), shape)


def test_fit_improves_and_converges():
    """Exact recovery of a rank-4 tensor (decomposed at rank 5: ALS at the
    exact rank can stall in the classic swamp; slight over-parameterization
    is the standard fix and recovers fit = 1)."""
    st_t = low_rank_tensor()
    state = cp_als(st_t, rank=5, iters=25, seed=2)
    fits = state.fit_history
    assert fits[-1] > 0.95, fits
    assert fits[-1] >= fits[0]


def test_methods_agree():
    """Approach 1 and Approach 2 drive identical ALS trajectories (same
    math, different memory schedule — the paper's central claim)."""
    st_t = low_rank_tensor(seed=3)
    s1 = cp_als(st_t, rank=4, iters=5, method="approach1", seed=0)
    s2 = cp_als(st_t, rank=4, iters=5, method="approach2", seed=0)
    np.testing.assert_allclose(s1.fit_history, s2.fit_history, rtol=1e-4, atol=1e-5)
    for f1, f2 in zip(s1.factors, s2.factors):
        np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-3, atol=1e-4)


def test_layouts_agree():
    """'remap' (single stream re-sorted per mode, Alg. 5) == 'copies'
    (per-mode sorted copies) — the trade the paper discusses in Sec. 3."""
    st_t = low_rank_tensor(seed=4)
    s1 = cp_als(st_t, rank=3, iters=4, layout="remap", seed=0)
    s2 = cp_als(st_t, rank=3, iters=4, layout="copies", seed=0)
    np.testing.assert_allclose(s1.fit_history, s2.fit_history, rtol=1e-4, atol=1e-5)


def test_pallas_backed_cp_als():
    """CP-ALS with the Pallas kernel (interpret mode) as the MTTKRP engine."""
    st_t = low_rank_tensor(shape=(16, 12, 20), seed=5)

    ops = {m: make_planned_mttkrp(st_t.sorted_by(m), m, 4, interpret=True) for m in range(3)}

    def mttkrp_fn(indices, values, factors, mode, out_rows):
        return ops[mode].output(factors, out_rows)

    s_k = cp_als(st_t, rank=4, iters=5, layout="copies", mttkrp_fn=mttkrp_fn, seed=0)
    s_j = cp_als(st_t, rank=4, iters=5, layout="copies", seed=0)
    np.testing.assert_allclose(s_k.fit_history, s_j.fit_history, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("source", ["tiny", "tensor4d", "tensor5d"])
def test_planned_cp_als_matches_pure_jax(request, source):
    """Acceptance: cp_als(method='pallas') — the PlannedCPALS workspace — and
    pure-JAX approach1 drive matching fit histories on 3-, 4- and 5-mode
    tensors (the whole ALS loop runs on the memory controller)."""
    st_t = frostt_like("tiny") if source == "tiny" else request.getfixturevalue(source)
    s_p = cp_als(st_t, rank=4, iters=3, method="pallas", seed=0)
    s_1 = cp_als(st_t, rank=4, iters=3, method="approach1", layout="copies", seed=0)
    np.testing.assert_allclose(s_p.fit_history, s_1.fit_history, atol=1e-4)


def test_planned_cp_als_plans_built_once(monkeypatch):
    """Plan amortization (paper: layout generation is per-mode, not
    per-iteration): plan_blocks runs exactly once per output mode regardless
    of the iteration count, and a prebuilt workspace skips planning
    entirely."""
    calls = []
    orig = ops_mod.plan_blocks

    def counting(*a, **k):
        calls.append(a)
        return orig(*a, **k)

    monkeypatch.setattr(ops_mod, "plan_blocks", counting)
    st_t = frostt_like("tiny")
    cp_als(st_t, rank=4, iters=4, method="pallas", seed=0)
    assert len(calls) == st_t.nmodes

    planned = make_planned_cp_als(st_t, 4, interpret=True)
    calls.clear()
    s = cp_als(st_t, rank=4, iters=2, method="pallas", planned=planned, seed=0)
    assert calls == []
    assert len(s.fit_history) == 2


@pytest.mark.parametrize("source", ["tiny", "tensor4d", "tensor5d"])
def test_jitted_sweep_matches_eager_pallas(request, source):
    """Acceptance: the jitted ALS sweep (rank-padded, device-resident factors,
    one compiled function per iteration) reproduces the eager per-mode pallas
    dispatch loop to 1e-5 on 3/4/5-mode tensors."""
    st_t = frostt_like("tiny") if source == "tiny" else request.getfixturevalue(source)
    s_jit = cp_als(st_t, rank=4, iters=3, method="pallas", seed=0)
    s_eag = cp_als(st_t, rank=4, iters=3, method="pallas", seed=0, jit_sweep=False)
    np.testing.assert_allclose(s_jit.fit_history, s_eag.fit_history, atol=1e-5)
    for fj, fe in zip(s_jit.factors, s_eag.factors):
        assert fj.shape == fe.shape  # sliced back to true (I_m, R)
        np.testing.assert_allclose(np.asarray(fj), np.asarray(fe), atol=1e-4)


@pytest.mark.parametrize("layout", ["copies", "remap"])
def test_jitted_sweep_matches_eager_pure_jax(layout):
    """The pure-JAX layouts get the same treatment: one jitted sweep per
    iteration must match the eager dispatch loop."""
    st_t = low_rank_tensor(seed=6)
    s_jit = cp_als(st_t, rank=3, iters=4, layout=layout, seed=0)
    s_eag = cp_als(st_t, rank=3, iters=4, layout=layout, seed=0, jit_sweep=False)
    np.testing.assert_allclose(s_jit.fit_history, s_eag.fit_history, atol=1e-5)


def test_planned_cp_als_pads_once_per_mode(monkeypatch):
    """Regression (fast-path contract): a full cp_als(method='pallas') run
    pads each factor exactly once — in the shared PlannedWorkspace.pad_factors
    (kernels/workspace.py) — instead of N x iters eager pad_factor calls;
    iterations update factors in padded space."""
    import repro.kernels.workspace as workspace_mod

    calls = []
    orig = workspace_mod.pad_factor

    def counting(*a, **k):
        calls.append(a)
        return orig(*a, **k)

    monkeypatch.setattr(workspace_mod, "pad_factor", counting)
    st_t = frostt_like("tiny")
    cp_als(st_t, rank=4, iters=3, method="pallas", seed=0)
    assert len(calls) == st_t.nmodes


def test_cp_als_tol_early_exit_jitted():
    """tol moved to a host check on the per-iteration fit scalar: the loop
    must stop once successive fits are within tol, in fewer than `iters`
    iterations on an exactly-recoverable tensor."""
    st_t = low_rank_tensor(seed=8)
    state = cp_als(st_t, rank=5, iters=40, tol=1e-6, seed=2)
    assert len(state.fit_history) < 40
    assert state.fit_history[-1] > 0.9


def test_cp_als_rejects_unknown_layout():
    """'planned' is an internal sentinel of the pallas path: reaching it via
    the public `layout` arg would feed an unsorted stream to approach1 with
    its sorted_by_mode=True promise, so it must be rejected up front."""
    st_t = frostt_like("tiny")
    with pytest.raises(ValueError, match="layout"):
        cp_als(st_t, rank=4, iters=1, layout="planned")


def test_normalize_first_iteration_convention():
    """Regression: _normalize must apply the documented first-iteration
    max(norm, 1) convention (it used to ignore `it` entirely) — sub-unit
    columns are left unscaled on iteration 0, divided exactly afterwards."""
    f = jnp.array([[0.3, 3.0], [0.4, 4.0]], jnp.float32)  # col norms 0.5, 5.0
    f0, n0 = _normalize(f, 0)
    np.testing.assert_allclose(np.asarray(n0), [1.0, 5.0], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(f0[:, 0]), [0.3, 0.4], rtol=1e-6)
    f1, n1 = _normalize(f, 1)
    np.testing.assert_allclose(np.asarray(n1), [0.5, 5.0], rtol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(f1), axis=0), [1.0, 1.0], rtol=1e-6
    )


def test_poorly_scaled_fit_trajectory():
    """Fit-trajectory regression for the max(norm,1) convention: on a badly
    down-scaled tensor (tiny first-iteration column norms) the trajectory
    stays finite and still recovers the decomposition."""
    base = low_rank_tensor(seed=7)
    scaled = SparseTensor(base.indices, base.values * 1e-4, base.shape)
    state = cp_als(scaled, rank=5, iters=25, seed=2)
    fits = np.array(state.fit_history)
    assert np.all(np.isfinite(fits))
    assert fits[-1] > 0.95, fits
    assert all(np.isfinite(np.asarray(f)).all() for f in state.factors)


def test_gram_hadamard():
    key = jax.random.PRNGKey(0)
    facs = [jax.random.normal(k, (10, 4)) for k in jax.random.split(key, 3)]
    g = gram_hadamard(facs, 0)
    want = (facs[1].T @ facs[1]) * (facs[2].T @ facs[2])
    np.testing.assert_allclose(np.asarray(g), np.asarray(want), rtol=1e-5)


def test_higher_order_cp_als(tensor4d):
    state = cp_als(tensor4d, rank=3, iters=3, seed=0)
    assert len(state.factors) == 4
    assert all(np.isfinite(f).all() for f in map(np.asarray, state.factors))
