"""Tensor Remapper (paper Alg. 5 / Sec. 3.1): the device sort must implement
exactly the paper's pointer-machine mapping, and the block plan must satisfy
the 'ideal memory layout' invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coo import SparseTensor, synthetic_tensor
from repro.core.remap import (
    group_key,
    plan_blocks,
    plan_blocks_reference,
    pointer_table,
    radix_digits,
    remap_pointer_machine,
    remap_radix,
    remap_stable,
)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_remap_stable_equals_pointer_machine(tiny_tensor, mode):
    """The XLA stable sort is bit-identical to the paper's address-pointer
    streaming remap (weak-consistency FIFO property preserved)."""
    idx, val = jnp.asarray(tiny_tensor.indices), jnp.asarray(tiny_tensor.values)
    si, sv, _ = remap_stable(idx, val, mode)
    pi, pv = remap_pointer_machine(
        tiny_tensor.indices, tiny_tensor.values, mode, tiny_tensor.shape[mode]
    )
    np.testing.assert_array_equal(np.asarray(si), pi)
    np.testing.assert_array_equal(np.asarray(sv), pv)


@pytest.mark.parametrize("budget", [4, 16, 64])
def test_remap_radix_matches_stable(tiny_tensor, budget):
    """Hierarchical (pointer-budget-bounded) remap produces the same order
    as the unbounded sort — the paper's 'pointers don't fit in BRAM' case."""
    idx, val = jnp.asarray(tiny_tensor.indices), jnp.asarray(tiny_tensor.values)
    si, sv, _ = remap_stable(idx, val, 1)
    ri, rv, _ = remap_radix(idx, val, 1, tiny_tensor.shape[1], budget)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))


def test_pointer_table_offsets(tiny_tensor):
    coords = jnp.asarray(tiny_tensor.indices[:, 0])
    offsets, counts = pointer_table(coords, tiny_tensor.shape[0])
    h = np.bincount(tiny_tensor.indices[:, 0], minlength=tiny_tensor.shape[0])
    np.testing.assert_array_equal(np.asarray(counts), h)
    np.testing.assert_array_equal(
        np.asarray(offsets), np.concatenate([[0], np.cumsum(h)[:-1]])
    )


@settings(max_examples=25, deadline=None)
@given(
    nnz=st.integers(1, 400),
    shape=st.tuples(st.integers(2, 40), st.integers(2, 40), st.integers(2, 40)),
    mode=st.integers(0, 2),
    seed=st.integers(0, 10_000),
)
def test_remap_is_stable_sort_property(nnz, shape, mode, seed):
    """Property: remap output is (a) a permutation of the input multiset and
    (b) sorted by the mode coordinate with original order preserved within
    equal coordinates."""
    st_t = synthetic_tensor(shape, nnz, seed=seed, skew=0.5)
    idx, val = jnp.asarray(st_t.indices), jnp.asarray(st_t.values)
    si, sv, perm = remap_stable(idx, val, mode)
    si, sv, perm = np.asarray(si), np.asarray(sv), np.asarray(perm)
    # permutation property
    assert sorted(perm.tolist()) == list(range(st_t.nnz))
    # sortedness
    c = si[:, mode]
    assert np.all(c[1:] >= c[:-1])
    # stability: within equal coords, perm increasing
    for v in np.unique(c):
        assert np.all(np.diff(perm[c == v]) > 0)


@pytest.mark.parametrize("budget", [2, 4, 16])
@pytest.mark.parametrize("power", [1, 2, 3])
def test_radix_digits_exact_powers(budget, power):
    """Regression: digit count at nbins == budget**k must be exactly k — the
    float-log formulation (ceil(log(nbins)/log(budget))) returned k+1 at some
    exact powers (log(64)/log(4) = 3.0000000000000004)."""
    nbins = budget**power
    assert radix_digits(nbins, budget) == power
    assert radix_digits(nbins + 1, budget) == power + 1
    assert radix_digits(max(nbins - 1, 1), budget) <= power


def test_remap_radix_exact_power_of_budget():
    """remap_radix at nbins == budget**k (the former float-log off-by-one
    point) still reproduces the unbounded stable sort."""
    st_t = synthetic_tensor((70, 64, 50), 3_000, seed=11, skew=0.7)  # 64 = 4**3
    idx, val = jnp.asarray(st_t.indices), jnp.asarray(st_t.values)
    si, sv, _ = remap_stable(idx, val, 1)
    ri, rv, _ = remap_radix(idx, val, 1, 64, 4)
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(sv), np.asarray(rv))


_PARITY_SHAPES = {
    3: (40, 30, 50),
    4: (20, 15, 25, 10),
    5: (12, 10, 14, 8, 9),
}


@settings(max_examples=30, deadline=None)
@given(
    nmodes=st.sampled_from([3, 4, 5]),
    nnz=st.integers(1, 300),
    seed=st.integers(0, 10_000),
    tiles=st.sampled_from([(8, 8, 8, 16), (16, 8, 4, 8), (32, 16, 16, 32), (7, 5, 3, 4)]),
)
def test_plan_blocks_matches_reference_property(nmodes, nnz, seed, tiles):
    """Parity property: the vectorized scatter build is bit-identical to the
    per-group loop reference — every stream array, the block order, the tile
    metadata, and the locality statistics — on random 3/4/5-mode tensors."""
    shape = _PARITY_SHAPES[nmodes]
    mode = seed % nmodes
    st_t = synthetic_tensor(shape, nnz, seed=seed, skew=0.7)
    ti, tj, tk, blk = tiles
    kw = dict(tile_i=ti, tile_j=tj, tile_k=tk, blk=blk)
    a = plan_blocks(st_t, mode, **kw)
    b = plan_blocks_reference(st_t, mode, **kw)
    np.testing.assert_array_equal(a.vals, b.vals)
    np.testing.assert_array_equal(a.iloc, b.iloc)
    np.testing.assert_array_equal(a.block_it, b.block_it)
    assert len(a.in_locs) == len(b.in_locs) and len(a.block_in) == len(b.block_in)
    for x, y in zip(a.in_locs, b.in_locs):
        np.testing.assert_array_equal(x, y)
    for x, y in zip(a.block_in, b.block_in):
        np.testing.assert_array_equal(x, y)
    assert a.vals.dtype == b.vals.dtype and a.iloc.dtype == b.iloc.dtype
    assert a.block_it.dtype == b.block_it.dtype
    assert (a.tile_i, a.in_tiles, a.blk) == (b.tile_i, b.in_tiles, b.blk)
    assert (a.out_rows, a.in_rows, a.mode, a.in_modes, a.nnz) == (
        b.out_rows, b.in_rows, b.mode, b.in_modes, b.nnz)
    assert a.tile_fills() == b.tile_fills()


@pytest.mark.parametrize("tiles", [(8, 8, 8, 16), (16, 32, 8, 8), (64, 64, 64, 128)])
def test_plan_blocks_reference_invariants(tiny_tensor, tiles):
    """The loop reference satisfies the same layout invariants as the
    production build (it is the executable spec, not dead code)."""
    ti, tj, tk, blk = tiles
    plan = plan_blocks_reference(tiny_tensor, 0, tile_i=ti, tile_j=tj, tile_k=tk, blk=blk)
    assert plan.a_tile_single_flush()
    assert plan.vals.shape[0] == plan.nblocks * blk
    assert np.isclose(plan.vals.sum(), tiny_tensor.values.sum(), atol=1e-3)


@pytest.mark.parametrize("tiles", [(8, 8, 8, 16), (16, 32, 8, 8), (64, 64, 64, 128)])
def test_plan_blocks_invariants(tiny_tensor, tiles):
    ti, tj, tk, blk = tiles
    plan = plan_blocks(tiny_tensor, 0, tile_i=ti, tile_j=tj, tile_k=tk, blk=blk)
    # (1) Approach-1 invariant: each output tile's blocks contiguous
    assert plan.a_tile_single_flush()
    # (2) equal-sized partitions: every block exactly `blk` slots
    assert plan.vals.shape[0] == plan.nblocks * blk
    # (3) multiset of non-zeros preserved (padding adds zeros only)
    assert np.isclose(plan.vals.sum(), tiny_tensor.values.sum(), atol=1e-3)
    assert (plan.vals != 0).sum() <= tiny_tensor.nnz
    # (4) local indices within tile bounds
    assert plan.iloc.max() < ti and plan.jloc.max() < tj and plan.kloc.max() < tk
    # (5) fills >= number of distinct occupied tiles
    fills = plan.tile_fills()
    it_occ = np.unique(tiny_tensor.indices[:, 0] // ti).size
    assert fills["A"] >= it_occ


@settings(max_examples=40, deadline=None)
@given(
    shape=st.tuples(st.integers(2, 500), st.integers(2, 500), st.integers(2, 500)),
    tiles=st.tuples(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64)),
    seed=st.integers(0, 10_000),
)
def test_group_key_no_adjacent_collisions(shape, tiles, seed):
    """Property (regression for the inconsistent floor-division multipliers):
    distinct (it, jt, kt) tile-id triples never collide in the group key.
    The old key mixed `max(shape // tile, 0) + 2` and `shape // tile + 2`
    multipliers; the new one uses explicit per-mode tile counts."""
    rng = np.random.default_rng(seed)
    n_tiles = [max(1, (s + t - 1) // t) for s, t in zip(shape, tiles)]
    cols = [rng.integers(0, n, 64, dtype=np.int64) for n in n_tiles]
    key = group_key(cols, n_tiles)
    triples = list(zip(*(c.tolist() for c in cols)))
    for a in range(len(triples) - 1):
        b = a + 1  # adjacency in the lexsorted stream is what bounds groups
        if triples[a] != triples[b]:
            assert key[a] != key[b], (triples[a], triples[b])
        else:
            assert key[a] == key[b]
    # stronger: the key is globally injective on tile-id tuples
    assert len({k: t for k, t in zip(key.tolist(), triples)}) == len(set(triples))


@pytest.mark.parametrize("fixture,mode", [("tensor4d", 0), ("tensor4d", 2), ("tensor5d", 4)])
def test_plan_blocks_higher_order_invariants(request, fixture, mode):
    """N-mode plans keep the 3-mode invariants: per-input-mode streams, the
    Approach-1 contiguity property, and multiset preservation."""
    st_t = request.getfixturevalue(fixture)
    plan = plan_blocks(st_t, mode, tile_i=16, tile_j=16, tile_k=16, blk=32)
    n_in = st_t.nmodes - 1
    assert plan.n_in == n_in
    assert len(plan.block_in) == len(plan.in_locs) == len(plan.in_tiles) == n_in
    assert plan.a_tile_single_flush()
    assert plan.vals.shape[0] == plan.nblocks * plan.blk
    assert np.isclose(plan.vals.sum(), st_t.values.sum(), atol=1e-3)
    # reconstruct the non-zero multiset from (tile id, local idx)
    gi = np.repeat(plan.block_it, plan.blk) * plan.tile_i + plan.iloc
    gins = [
        np.repeat(t, plan.blk) * tile + loc
        for t, loc, tile in zip(plan.block_in, plan.in_locs, plan.in_tiles)
    ]
    mask = plan.vals != 0
    got = sorted(zip(gi[mask], *(g[mask] for g in gins), plan.vals[mask]))
    cols = [st_t.indices[:, mode]] + [st_t.indices[:, m] for m in plan.in_modes]
    want = sorted(zip(*cols, st_t.values))
    np.testing.assert_array_equal(
        np.array([g[:-1] for g in got]), np.array([w[:-1] for w in want])
    )
    np.testing.assert_allclose(
        np.array([g[-1] for g in got]), np.array([w[-1] for w in want]), rtol=1e-6
    )


def test_plan_blocks_reconstructs_tensor(tiny_tensor):
    """Global coordinates reconstructed from (block tile id, local idx) must
    reproduce the original non-zero multiset."""
    plan = plan_blocks(tiny_tensor, 1, tile_i=16, tile_j=16, tile_k=16, blk=32)
    blk = plan.blk
    git = np.repeat(plan.block_it, blk) * plan.tile_i + plan.iloc
    gjt = np.repeat(plan.block_jt, blk) * plan.tile_j + plan.jloc
    gkt = np.repeat(plan.block_kt, blk) * plan.tile_k + plan.kloc
    mask = plan.vals != 0
    got = sorted(zip(git[mask], gjt[mask], gkt[mask], plan.vals[mask]))
    # original, keyed the same way (mode 1 is the output mode here)
    i = tiny_tensor.indices[:, 1]
    j = tiny_tensor.indices[:, 0]
    k = tiny_tensor.indices[:, 2]
    want = sorted(zip(i, j, k, tiny_tensor.values))
    got_arr = np.array([g[:3] for g in got])
    want_arr = np.array([w[:3] for w in want])
    np.testing.assert_array_equal(got_arr, want_arr)
    np.testing.assert_allclose(
        np.array([g[3] for g in got]), np.array([w[3] for w in want]), rtol=1e-6
    )
