"""Training substrate: optimizer math, LR schedule, microbatch-accumulation
equivalence, gradient compression, end-to-end loss descent."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.dist.compression import (
    compress_decompress,
    dequantize_int8,
    quantize_int8,
)
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, global_norm, lr_at
from repro.train.train_step import init_train_state, make_train_step


def test_adamw_converges_quadratic():
    """AdamW drives a quadratic to its minimum."""
    target = jnp.array([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=300, weight_decay=0.0)
    state = adamw_init(params, cfg)
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, g, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.array(0))) == 0.0
    assert float(lr_at(cfg, jnp.array(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr_at(cfg, jnp.array(100))) == pytest.approx(1e-4, rel=1e-3)
    # monotone decay after warmup
    lrs = [float(lr_at(cfg, jnp.array(s))) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(lrs, lrs[1:]))


def test_grad_clipping():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(params, g, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


def test_sequential_vs_parallel_updates_identical():
    """optimization_barrier chaining is a scheduling hint only."""
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (8, 8)), "b": jax.random.normal(key, (4,))}
    grads = jax.tree.map(lambda p: p * 0.1, params)
    s1 = adamw_init(params, AdamWConfig(sequential_updates=True))
    s2 = adamw_init(params, AdamWConfig(sequential_updates=False))
    p1, _, _ = adamw_update(params, grads, s1, AdamWConfig(sequential_updates=True))
    p2, _, _ = adamw_update(params, grads, s2, AdamWConfig(sequential_updates=False))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_microbatch_equals_full_batch():
    """Grad accumulation (fp32) over k microbatches == one big batch, up to
    the CE-mean nonlinearity (equal microbatch token counts here)."""
    cfg = get_config("qwen3-0.6b").reduced()
    opt = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    key = jax.random.PRNGKey(0)
    state1 = init_train_state(key, cfg, opt)
    state2 = init_train_state(key, cfg, opt)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=0)
    batch = jax.tree.map(jnp.asarray, pipe.batch(0))
    s_full = make_train_step(cfg, opt, num_microbatches=1, attn_chunk=8, accum_dtype="float32")
    s_mb = make_train_step(cfg, opt, num_microbatches=4, attn_chunk=8, accum_dtype="float32")
    n1, m1 = s_full(state1, batch)
    n2, m2 = s_mb(state2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(n1.params), jax.tree.leaves(n2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_loss_decreases_over_steps():
    cfg = get_config("qwen3-0.6b").reduced()
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, num_microbatches=1, attn_chunk=8), donate_argnums=(0,))
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i in range(20):
        state, metrics = step(state, jax.tree.map(jnp.asarray, pipe.batch(i)))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_int8_quantization_roundtrip():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 64)) * 3.0
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(deq), np.asarray(x), atol=float(s) * 0.51)


def test_error_feedback_accumulates():
    """With error feedback, repeated compression of a constant gradient has
    bounded bias: |mean(deq) - g| <= e_max / N, where e_max is half an int8
    quantum (~max|g|/254)."""
    g = {"w": jnp.array([1e-4, 5e-3, -2e-3, 1.0])}  # wide dynamic range
    opt_state: dict = {}
    total = jnp.zeros(4)
    n = 400
    for _ in range(n):
        deq, opt_state = compress_decompress(g, opt_state)
        total = total + deq["w"]
    bound = (1.0 / 127) / n * 2  # quantum / N with slack
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g["w"]), atol=bound)


def test_compressed_training_still_converges():
    cfg = get_config("qwen3-0.6b").reduced()
    opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, compress_grads=True)
    assert "ef" in state.opt  # residual pre-seeded: stable structure from step 0
    step = jax.jit(
        make_train_step(cfg, opt, num_microbatches=1, attn_chunk=8, compress_grads=True),
        donate_argnums=(0,),
    )
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i in range(20):
        state, metrics = step(state, jax.tree.map(jnp.asarray, pipe.batch(i)))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses
