"""Dry-run machinery unit tests (no 512-device init): cell policy, HLO
collective parsing, shape-byte accounting, microbatch defaults."""
import jax
import pytest

from repro.configs import SHAPES, get_config, list_configs
from repro.launch.dryrun import (
    _shape_bytes,
    auto_remat_group,
    default_microbatches,
    parse_collectives,
    skip_reason,
)


def test_skip_matrix_policy():
    skipped = [a for a in list_configs() if skip_reason(a, "long_500k")]
    assert len(skipped) == 8  # all pure full-attention archs
    assert skip_reason("mamba2-370m", "long_500k") is None
    assert skip_reason("jamba-v0.1-52b", "long_500k") is None
    for a in list_configs():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert skip_reason(a, s) is None


def test_shape_bytes():
    assert _shape_bytes("f32[64,256]") == 64 * 256 * 4
    assert _shape_bytes("bf16[2,3,4]") == 48
    assert _shape_bytes("(f32[8], bf16[8])") == 32 + 16
    assert _shape_bytes("pred[10]") == 10


def test_parse_collectives():
    hlo = """
  %ag = f32[64,256]{1,0} all-gather(f32[4,256] %x), replica_groups={}
  %ar.1 = bf16[128]{0} all-reduce(bf16[128] %y), to_apply=%sum
  %t = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(f32[16,16] %a, f32[16,16] %b)
  %cp = f32[8]{0} collective-permute(f32[8] %z), source_target_pairs={{0,1}}
  %rs = f32[2,8]{1,0} reduce-scatter(f32[16,8] %w), dimensions={0}
  %d = f32[4,4]{1,0} dot(f32[4,4] %p, f32[4,4] %q)
"""
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 64 * 256 * 4
    assert out["all-reduce"]["bytes"] == 256
    assert out["all-to-all"]["count"] == 1
    assert out["all-to-all"]["bytes"] == 2 * 16 * 16 * 4
    assert out["collective-permute"]["bytes"] == 32
    assert out["reduce-scatter"]["bytes"] == 64
    assert "dot" not in out


def test_auto_remat_group():
    assert auto_remat_group(64) == 8
    assert auto_remat_group(28) == 4  # divisors of 28 <= 5.29: 1,2,4
    assert auto_remat_group(32) == 4
    assert auto_remat_group(4) == 0  # too shallow to bother


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_default_microbatches():
    cfg_small = get_config("qwen3-0.6b")
    cfg_big = get_config("grok-1-314b")
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert default_microbatches(cfg_big, SHAPES["train_4k"], mesh) == 16
    assert default_microbatches(cfg_small, SHAPES["train_4k"], mesh) == 4
    assert default_microbatches(cfg_big, SHAPES["decode_32k"], mesh) == 1
    mesh2 = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert default_microbatches(cfg_big, SHAPES["train_4k"], mesh2) == 8
