"""Distributed planned decomposition (repro.dist.planned).

Three layers of coverage:
  * host-side partitioner properties — `partition_stream` must cover the
    stream exactly (no dropped/duplicated non-zeros at tile boundaries),
    keep boundaries tile-aligned, and reassemble the original order;
  * in-process single-shard checks — the sharded machinery runs on a 1-device
    `shard` mesh in this very process (shard_map over one device), so the
    whole path is exercised without subprocesses; plus API error contracts
    and the sharded PMS;
  * subprocess parity — `pallas_sharded` vs single-device `pallas` fit match
    to 1e-5 on 3/4/5-mode tensors under forced 2- and 4-device host
    platforms (the host device count locks at first jax init, hence the
    `_run` pattern shared with test_mttkrp_sharded / test_dist).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.core.coo import synthetic_tensor
from repro.core.memctrl import (
    CacheEngineConfig,
    DMAEngineConfig,
    MemoryControllerConfig,
)
from repro.dist.sharding import partition_stream

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL_CFG = MemoryControllerConfig(
    cache=CacheEngineConfig(tile_i=16, tile_j=16, tile_k=16),
    dma=DMAEngineConfig(blk=32),
)


# ---------------------------------------------------------------------------
# partitioner properties (host-side numpy, no devices involved)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    dims=hst.tuples(hst.integers(4, 70), hst.integers(4, 70), hst.integers(4, 70)),
    nnz=hst.integers(1, 1_500),
    nshards=hst.integers(1, 6),
    tile=hst.sampled_from([1, 7, 16, 64]),
    mode=hst.integers(0, 2),
    seed=hst.integers(0, 99),
)
def test_partition_reassembles_exact_stream(dims, nnz, nshards, tile, mode, seed):
    """No dropped or duplicated non-zeros at tile boundaries: the shards are
    a disjoint cover and scatter back to the exact original stream, order
    included."""
    st = synthetic_tensor(dims, nnz, seed=seed, skew=0.7)
    part = partition_stream(st, mode, nshards, tile=tile)
    assert part.nshards == nshards
    assert sum(part.shard_nnz) == st.nnz
    re = part.reassemble()
    np.testing.assert_array_equal(re.indices, st.indices)
    np.testing.assert_array_equal(re.values, st.values)
    # tile-aligned disjoint ownership + original relative order per shard
    for (a, b), sh, pos in zip(part.row_ranges(), part.shards, part.positions):
        assert a % tile == 0 or a == st.shape[mode]
        if sh.nnz:
            c = sh.indices[:, mode]
            assert a <= c.min() and c.max() < b
            assert np.all(np.diff(pos) > 0)  # stable within shard


@settings(max_examples=10, deadline=None)
@given(
    nnz=hst.integers(64, 2_000),
    nshards=hst.sampled_from([2, 4]),
    seed=hst.integers(0, 20),
)
def test_partition_balances_when_tiles_allow(nnz, nshards, seed):
    """With many more tiles than shards and mild skew, the greedy prefix
    split must stay within 2x of a perfect balance (it can only miss the
    quantile by one tile's worth of nnz)."""
    st = synthetic_tensor((256, 64, 64), nnz, seed=seed, skew=0.3)
    part = partition_stream(st, 0, nshards, tile=4)
    assert part.imbalance() < 2.0


def test_partition_validates_arguments():
    st = synthetic_tensor((8, 8, 8), 64, seed=0)
    with pytest.raises(ValueError, match="nshards"):
        partition_stream(st, 0, 0)
    with pytest.raises(ValueError, match="mode"):
        partition_stream(st, 3, 2)
    with pytest.raises(ValueError, match="tile"):
        partition_stream(st, 0, 2, tile=0)


def test_partition_more_shards_than_tiles():
    """Degenerate regime: empty shards appear, coverage still exact."""
    st = synthetic_tensor((8, 8, 8), 100, seed=1)
    part = partition_stream(st, 0, 5, tile=8)  # one tile, five shards
    assert sum(part.shard_nnz) == st.nnz
    assert sum(1 for n in part.shard_nnz if n == 0) >= 4
    re = part.reassemble()
    np.testing.assert_array_equal(re.indices, st.indices)


# ---------------------------------------------------------------------------
# sharded PMS
# ---------------------------------------------------------------------------


def test_predict_sharded_is_makespan(small_tensor):
    from repro.core.pms import predict_sharded

    est = predict_sharded(small_tensor, 0, 16, 4, MemoryControllerConfig())
    assert est.nshards == 4
    assert est.t_total == max(e.t_total for e in est.per_shard)
    assert est.per_shard[est.critical_shard].t_total == est.t_total
    assert est.imbalance >= 1.0
    assert est.vmem_bytes == est.per_shard[0].vmem_bytes


def test_search_sharded_ranks_by_worst_shard(small_tensor):
    from repro.core.pms import search_sharded

    spec_kw = dict(top_k=4)
    best = search_sharded(small_tensor, 0, 16, 2, **spec_kw)
    assert best, "no VMEM-feasible sharded configuration"
    makespans = [e.t_total for e in best]
    assert makespans == sorted(makespans)
    # ttmc kernel needs the full core-rank tuple
    with pytest.raises(ValueError, match="core_ranks"):
        search_sharded(small_tensor, 0, 16, 2, kernel="ttmc")
    bt = search_sharded(
        small_tensor, 0, 16, 2, kernel="ttmc", core_ranks=(8, 8, 8), top_k=2
    )
    assert bt and bt[0].t_total <= bt[-1].t_total


def test_predict_sharded_handles_empty_shards():
    from repro.core.pms import predict_sharded

    st = synthetic_tensor((8, 8, 8), 50, seed=0)
    est = predict_sharded(st, 0, 8, 4, MemoryControllerConfig())  # 1 tile, 4 shards
    assert est.t_total > 0.0
    assert sum(1 for e in est.per_shard if e.t_total == 0.0) >= 3


# ---------------------------------------------------------------------------
# in-process single-shard path + API contracts
# ---------------------------------------------------------------------------


def test_sharded_path_on_one_device_matches_pallas(tiny_tensor):
    """devices=1 runs the full sharded machinery (partition, stack,
    shard_map, psum, masked tiles) on the lone CPU device — fit must match
    the single-device planned path to 1e-5."""
    from repro.core.cp_als import cp_als

    ref = cp_als(tiny_tensor, 8, iters=2, method="pallas", cfg=SMALL_CFG)
    sh = cp_als(tiny_tensor, 8, iters=2, method="pallas_sharded", devices=1,
                cfg=SMALL_CFG)
    np.testing.assert_allclose(sh.fit_history, ref.fit_history, rtol=1e-5, atol=1e-5)


def test_sharded_tucker_on_one_device_matches_pallas(tiny_tensor):
    from repro.tucker import tucker_hooi

    ref = tucker_hooi(tiny_tensor, (4, 4, 4), iters=2, method="pallas", cfg=SMALL_CFG)
    sh = tucker_hooi(tiny_tensor, (4, 4, 4), iters=2, method="pallas_sharded",
                     devices=1, cfg=SMALL_CFG)
    np.testing.assert_allclose(sh.fit_history, ref.fit_history, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(sh.core), np.asarray(ref.core), rtol=2e-4, atol=2e-4
    )


def test_empty_intra_range_tiles_are_zero_not_nan():
    """Regression: an output tile with NO non-zeros inside a plan's range is
    never visited by the kernel, so its rows keep the uninitialized output
    buffer (NaN in interpret mode) unless masked.  Both the single-device
    planned path and the sharded path must return exact zeros there."""
    import jax

    from repro.core.coo import SparseTensor, random_factors
    from repro.core.cp_als import cp_als
    from repro.kernels import ops

    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=8, tile_j=16, tile_k=16),
        dma=DMAEngineConfig(blk=32),
    )
    st0 = synthetic_tensor((64, 48, 80), 3000, seed=5, skew=0.5)
    keep = (st0.indices[:, 0] < 16) | (st0.indices[:, 0] >= 24)
    st = SparseTensor(st0.indices[keep], st0.values[keep], st0.shape)  # tile 2 empty
    facs = random_factors(jax.random.PRNGKey(0), st.shape, 8)

    ref = np.asarray(ops.mttkrp_auto(st, facs, 0, method="approach1"))
    got = np.asarray(ops.mttkrp_auto(st, facs, 0, cfg=cfg))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)
    assert np.all(got[16:24] == 0.0)

    # whole decompositions stay finite and match across paths
    s_ref = cp_als(st, 8, iters=2, method="pallas", cfg=cfg)
    assert np.isfinite(s_ref.fit_history).all()
    s_sh = cp_als(st, 8, iters=2, method="pallas_sharded", devices=1, cfg=cfg)
    np.testing.assert_allclose(s_sh.fit_history, s_ref.fit_history, rtol=1e-5, atol=1e-5)


def test_sharded_mttkrp_route_and_cache_keys(tiny_tensor):
    """mttkrp_sharded(method='pallas') matches mttkrp_auto; per-shard plans
    land in the shared cache under shard-aware keys (kind counters move)."""
    import jax

    from repro.core.coo import random_factors
    from repro.core.mttkrp import mttkrp_sharded
    from repro.dist.planned import shard_plan
    from repro.kernels import ops

    facs = random_factors(jax.random.PRNGKey(0), tiny_tensor.shape, 8)
    ref = ops.mttkrp_auto(tiny_tensor, facs, 0, cfg=SMALL_CFG)
    ops.plan_cache_clear()
    plan = shard_plan(1)
    fn = mttkrp_sharded(plan, 0, tiny_tensor.shape[0], method="pallas",
                        st=tiny_tensor, rank=8, cfg=SMALL_CFG)
    got = fn(None, None, facs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)
    stats = ops.plan_cache_stats()
    assert stats["by_kind"]["mttkrp"]["misses"] >= 1
    # identical rebuild hits the shard-keyed entries instead of re-remapping
    fn2 = mttkrp_sharded(plan, 0, tiny_tensor.shape[0], method="pallas",
                         st=tiny_tensor, rank=8, cfg=SMALL_CFG)
    stats2 = ops.plan_cache_stats()
    assert stats2["by_kind"]["mttkrp"]["hits"] > stats["by_kind"]["mttkrp"]["hits"]
    # shard entries cache raw BlockPlans, which don't depend on rank — a
    # rebuild at another rank must hit, not repay the Tensor Remapper
    mttkrp_sharded(plan, 0, tiny_tensor.shape[0], method="pallas",
                   st=tiny_tensor, rank=4, cfg=SMALL_CFG)
    stats3 = ops.plan_cache_stats()
    assert stats3["by_kind"]["mttkrp"]["hits"] > stats2["by_kind"]["mttkrp"]["hits"]
    assert stats3["by_kind"]["mttkrp"]["misses"] == stats2["by_kind"]["mttkrp"]["misses"]
    # shard layouts are kernel-agnostic BlockPlans: a Tucker workspace on
    # the same (tensor, cfg) reuses the CP build's mode-0 shard layout
    # (stats attributed to the ttmc kind, key shared)
    from repro.kernels.ops import make_sharded_planned_tucker

    before = ops.plan_cache_stats()["by_kind"]["ttmc"]
    make_sharded_planned_tucker(tiny_tensor, (4, 4, 4), dist=plan, cfg=SMALL_CFG)
    after = ops.plan_cache_stats()["by_kind"]["ttmc"]
    assert after["hits"] >= before["hits"] + 1


def test_sharded_api_contracts(tiny_tensor):
    from repro.core.cp_als import cp_als
    from repro.core.mttkrp import mttkrp_sharded
    from repro.dist.planned import shard_plan
    from repro.tucker import tucker_hooi

    with pytest.raises(ValueError, match="sweep-only|jitted shard_map"):
        cp_als(tiny_tensor, 4, iters=1, method="pallas_sharded", devices=1,
               jit_sweep=False)
    with pytest.raises(ValueError, match="sweep-only|jitted shard_map"):
        tucker_hooi(tiny_tensor, (2, 2, 2), iters=1, method="pallas_sharded",
                    devices=1, jit_sweep=False)
    with pytest.raises(ValueError, match="st="):
        mttkrp_sharded(shard_plan(1), 0, tiny_tensor.shape[0], method="pallas")
    with pytest.raises(ValueError, match="devices"):
        shard_plan(0)
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        shard_plan(4096)
    # a single-device workspace cannot be passed to the sharded method
    from repro.kernels.ops import make_planned_cp_als

    ws = make_planned_cp_als(tiny_tensor, 4, cfg=SMALL_CFG)
    with pytest.raises(ValueError, match="ShardedPlannedCPALS"):
        cp_als(tiny_tensor, 4, iters=1, method="pallas_sharded", planned=ws)


def test_bench_fast_refuses_baseline_path():
    """The non-clobber contract is enforced in code, not by path convention:
    a --fast run pointed at the committed baseline must die loudly."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.bench_e2e import BASELINE_PATH, _resolve_out

        with pytest.raises(SystemExit, match="refusing to overwrite"):
            _resolve_out(None, fast=True)
        with pytest.raises(SystemExit, match="refusing to overwrite"):
            _resolve_out(str(BASELINE_PATH), fast=True)
        assert _resolve_out("/tmp/scratch.json", fast=True).name == "scratch.json"
        assert _resolve_out(None, fast=False) == BASELINE_PATH
    finally:
        sys.path.remove(ROOT)


# ---------------------------------------------------------------------------
# multi-device parity (subprocess: the host device count locks at jax init)
# ---------------------------------------------------------------------------


def _run(code: str, devices: int, timeout: int = 540) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=timeout, cwd=ROOT,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


_PARITY_CODE = """
import jax, numpy as np
from repro.core.coo import synthetic_tensor
from repro.core.cp_als import cp_als
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.tucker import tucker_hooi

DEV = {devices}
assert jax.device_count() == DEV, jax.devices()
cfg = MemoryControllerConfig(cache=CacheEngineConfig(tile_i=16, tile_j=16, tile_k=16),
                             dma=DMAEngineConfig(blk=32))

tensors = {{
    3: synthetic_tensor((64, 48, 80), 2000, seed=0, skew=0.8),
    4: synthetic_tensor((40, 32, 48, 24), 1800, seed=2, skew=0.5),
    5: synthetic_tensor((20, 25, 30, 15, 18), 1500, seed=3, skew=0.3),
}}
for nmodes, st in tensors.items():
    ref = cp_als(st, 8, iters=2, method="pallas", cfg=cfg)
    sh = cp_als(st, 8, iters=2, method="pallas_sharded", devices=DEV, cfg=cfg)
    np.testing.assert_allclose(sh.fit_history, ref.fit_history, rtol=1e-5, atol=1e-5)
    print(f"CP_MATCH modes={{nmodes}}")

st = tensors[{tucker_modes}]
ranks = (3,) * {tucker_modes}
t_ref = tucker_hooi(st, ranks, iters=2, method="pallas", cfg=cfg)
t_sh = tucker_hooi(st, ranks, iters=2, method="pallas_sharded", devices=DEV, cfg=cfg)
np.testing.assert_allclose(t_sh.fit_history, t_ref.fit_history, rtol=1e-5, atol=1e-5)
print("TUCKER_MATCH")
print("OK")
"""


@pytest.mark.slow
def test_sharded_parity_2_devices():
    """pallas_sharded == pallas to 1e-5 on 3/4/5-mode tensors, 2 devices,
    plus Tucker HOOI on the 3-mode tensor."""
    out = _run(_PARITY_CODE.format(devices=2, tucker_modes=3), devices=2)
    assert out.count("CP_MATCH") == 3
    assert "TUCKER_MATCH" in out and "OK" in out


@pytest.mark.slow
def test_sharded_parity_4_devices():
    """Same parity under 4 forced host devices; Tucker rides on the 4-mode
    tensor to cover the N-mode TTMc kernel under sharding."""
    out = _run(_PARITY_CODE.format(devices=4, tucker_modes=4), devices=4)
    assert out.count("CP_MATCH") == 3
    assert "TUCKER_MATCH" in out and "OK" in out
