"""PMS property tests (hypothesis): the paper Sec. 5.3 simulator must (a)
never propose a configuration that violates the VMEM budget, (b) keep its
roofline identity t_total == max(t_mem, t_compute), and (c) classify the
bottleneck the same way whether fed a built BlockPlan (measured fills) or
the analytic balls-in-bins estimate — whenever either model says the cell is
decisively one-sided, the other must not flip it."""
from hypothesis import assume, given, settings, strategies as st

from repro.core.coo import synthetic_tensor
from repro.core.hypergraph import stats as hg_stats
from repro.core.memctrl import MemoryControllerConfig, TPUSpec
from repro.core.pms import predict_analytic, predict_from_plan, search
from repro.core.remap import plan_blocks


def _rank_padded(rank: int) -> int:
    return max(128, ((rank + 127) // 128) * 128)


@settings(max_examples=10, deadline=None)
@given(
    dims=st.tuples(st.integers(8, 80), st.integers(8, 80), st.integers(8, 80)),
    nnz=st.integers(64, 2_000),
    rank=st.sampled_from([8, 64, 130]),
    mode=st.integers(0, 2),
    seed=st.integers(0, 99),
)
def test_search_results_fit_and_keep_roofline_identity(dims, nnz, rank, mode, seed):
    spec = TPUSpec()
    tensor = synthetic_tensor(dims, nnz, seed=seed, skew=0.5)
    results = search(tensor, mode, rank, spec=spec, top_k=20)
    assert results, "search returned no VMEM-feasible configuration"
    rp = _rank_padded(rank)
    for est in results:
        assert est.cfg.fits(spec, rp), (est.cfg, rp)
        assert est.vmem_bytes == est.cfg.vmem_bytes(rp)
        assert est.t_total == max(est.t_mem, est.t_compute)
        assert est.t_mem == est.t_stream + est.t_factor + est.t_out
        assert est.t_compute >= 0 and est.t_stream >= 0
        assert 0.0 <= est.padding_fraction < 1.0
        assert est.nblocks >= 1


@settings(max_examples=10, deadline=None)
@given(
    dims=st.tuples(st.integers(8, 64), st.integers(8, 64), st.integers(8, 64)),
    nnz=st.integers(64, 1_500),
    rank=st.sampled_from([8, 32, 64]),
    mode=st.integers(0, 2),
    seed=st.integers(0, 99),
)
def test_analytic_and_plan_agree_on_bottleneck(dims, nnz, rank, mode, seed):
    """The analytic occupancy model may miss exact fill counts, but it must
    not flip a decisive memory-bound cell to compute-bound or vice versa.
    Knife-edge cells (either model within 25% of the crossover) are skipped —
    there the classification is legitimately sensitive to fill estimates."""
    cfg = MemoryControllerConfig()
    tensor = synthetic_tensor(dims, nnz, seed=seed, skew=0.5)
    plan = plan_blocks(
        tensor, mode,
        tile_i=cfg.cache.tile_i, tile_j=cfg.cache.tile_j,
        tile_k=cfg.cache.tile_k, blk=cfg.dma.blk,
    )
    exact = predict_from_plan(plan, rank, cfg)
    approx = predict_analytic(hg_stats(tensor), mode, rank, cfg)
    for est in (exact, approx):
        assume(abs(est.t_mem - est.t_compute) > 0.25 * est.t_total)
    assert exact.bottleneck == approx.bottleneck, (
        exact.t_mem, exact.t_compute, approx.t_mem, approx.t_compute,
    )
