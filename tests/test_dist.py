"""Multi-device integration tests.  These spawn subprocesses because the
host device count must be fixed before jax initializes (the main pytest
process stays at 1 device, per the brief)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=timeout, cwd=ROOT,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_runs_and_learns():
    """2x4 mesh: sharded+microbatched train step must run and reduce loss;
    DP+TP numerics must track the single-device run."""
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.dist.sharding import make_plan, param_pspecs, valid_spec
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainState, init_train_state, make_train_step
from repro.data.pipeline import TokenPipeline

cfg = get_config("qwen3-0.6b").reduced()
opt = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20)
pipe = TokenPipeline(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)

# single-device reference
state_r = init_train_state(jax.random.PRNGKey(0), cfg, opt)
step_r = jax.jit(make_train_step(cfg, opt, num_microbatches=2, attn_chunk=8, accum_dtype="float32"))
losses_r = []
for i in range(6):
    state_r, m = step_r(state_r, jax.tree.map(jnp.asarray, pipe.batch(i)))
    losses_r.append(float(m["loss"]))

mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = make_plan(mesh, cfg)
with mesh:
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    specs = param_pspecs(state.params, plan)
    named = jax.tree.map(lambda a, s: NamedSharding(mesh, valid_spec(a.shape, s, mesh)),
                         state.params, specs, is_leaf=lambda x: isinstance(x, P))
    rep = NamedSharding(mesh, P())
    state = jax.device_put(state, TrainState(params=named,
        opt={"m": named, "v": named, "step": rep}, rng=rep))
    step = jax.jit(make_train_step(cfg, opt, plan, num_microbatches=2, attn_chunk=8,
                                   accum_dtype="float32"), donate_argnums=(0,))
    losses = []
    for i in range(6):
        state, m = step(state, jax.tree.map(jnp.asarray, pipe.batch(i)))
        losses.append(float(m["loss"]))
print("REF ", [round(l, 4) for l in losses_r])
print("MESH", [round(l, 4) for l in losses])
assert losses[-1] < losses[0], losses
for a, b in zip(losses_r, losses):
    assert abs(a - b) < 0.05, (losses_r, losses)
print("OK")
""",
    )
    assert "OK" in out


@pytest.mark.slow
def test_supervisor_recovers_from_injected_failure(tmp_path):
    """launch/train.py: injected failure -> restart from checkpoint -> done."""
    out = _run(
        f"""
from repro.launch.train import main
rc = main(["--arch", "qwen3-0.6b", "--reduced", "--steps", "12", "--batch", "4",
           "--seq", "32", "--ckpt-dir", r"{tmp_path}", "--ckpt-every", "4",
           "--fail-at-step", "6", "--max-restarts", "1", "--attn-chunk", "32",
           "--log-every", "50"])
assert rc == 0, rc
print("SUPERVISOR_OK")
""",
        devices=1,
    )
    assert "SUPERVISOR_OK" in out
    assert "injected node failure" in out


@pytest.mark.slow
def test_dryrun_single_cell_small_device_count():
    """The dry-run machinery end-to-end on an 8-device fake mesh is covered
    by the production matrix; here we only smoke the collective parser on a
    reduced sharded module."""
    out = _run(
        """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.dryrun import parse_collectives
mesh = jax.make_mesh((2, 4), ("data", "model"))
x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
f = jax.jit(lambda x, w: x @ w,
            in_shardings=(NamedSharding(mesh, P("data", None)), NamedSharding(mesh, P(None, "model"))),
            out_shardings=NamedSharding(mesh, P("data", None)))
hlo = f.lower(x, w).compile().as_text()
colls = parse_collectives(hlo)
assert colls, hlo[:500]
print("PARSED", sorted(colls))
""",
    )
    assert "PARSED" in out
