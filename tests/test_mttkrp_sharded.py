"""Distributed MTTKRP: the shard_map'd kernel on an 8-device host mesh must
match the single-device reference.  Subprocess-spawned (same `_run` pattern
as test_dist.py) because the host device count locks at first jax init."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=timeout, cwd=ROOT,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_mttkrp_sharded_matches_single_device():
    """8-way stream-sharded MTTKRP == mttkrp_approach1 on one device, both
    methods, every mode.  The stream is globally sorted by the output-mode
    coordinate first (the remap posture approach1's local shards rely on)."""
    out = _run(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.coo import random_factors, synthetic_tensor
from repro.core.mttkrp import mttkrp_approach1, mttkrp_sharded
from repro.dist.sharding import make_plan

assert jax.device_count() == 8, jax.devices()
st = synthetic_tensor((40, 30, 50), 4096, seed=0, skew=0.7)
n = st.nnz - st.nnz % 8  # shard_map needs the stream to divide the mesh
factors = random_factors(jax.random.PRNGKey(0), st.shape, 16)
mesh = jax.make_mesh((8,), ("data",))
plan = make_plan(mesh)
assert plan.data_axes() == ("data",) and plan.tp is None

for mode in range(3):
    order = np.argsort(st.indices[:n, mode], kind="stable")
    idx = jnp.asarray(st.indices[:n][order])
    vals = jnp.asarray(st.values[:n][order])
    ref = mttkrp_approach1(idx, vals, factors, mode, st.shape[mode],
                           sorted_by_mode=True)
    for method in ("approach1", "approach2"):
        fn = mttkrp_sharded(plan, mode, st.shape[mode], method=method,
                            sorted_by_mode=True)
        with mesh:
            got = fn(idx, vals, factors)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print(f"MATCH mode={mode} method={method}")
print("OK")
""",
    )
    assert out.count("MATCH") == 6
    assert "OK" in out
