"""Resilience layer (repro.resilience + repro.testing.faults): numerical
guards in the planned drive loop, plan integrity validation, HBM admission
control with the graceful-degradation ladder, checkpoint/resume of a killed
sweep, and the bounded plan cache.

Every injected fault from the harness must be DETECTED by the guard built
for it, and every recovery policy must land within tolerance of the clean
run — that pairing is the contract this file asserts."""
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.api import decompose
from repro.core.loop import GuardConfig, GuardState, finish_iter
from repro.core.remap import plan_blocks
from repro.kernels import ops
from repro.kernels.ops import make_planned_cp_als
from repro.resilience import (
    AdmissionError,
    DecompositionDiverged,
    PlanValidationError,
    admission_bytes,
    admit,
    plan_with_budget,
    plans_validated,
    reference_footprint_bytes,
    validate_plan,
)
from repro.testing import faults

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ITERS = 5


def _clean(st, rank=8, **kw):
    return decompose(st, rank, iters=ITERS, seed=0, **kw)


# ---------------------------------------------------------------------------
# finish_iter NaN semantics (guards off)
# ---------------------------------------------------------------------------


def test_finish_iter_nonfinite_stops_and_warns():
    fits: list = []
    with pytest.warns(RuntimeWarning, match="non-finite fit"):
        stop = finish_iter(fits, float("nan"), 0, None, False, "unit")
    assert stop is True
    assert len(fits) == 1 and not np.isfinite(fits[0])


def test_guards_off_nan_terminates_loop(tiny_tensor):
    """A NaN fit must stop the loop and surface even without guards — the
    pre-fix behavior silently looped to `iters` on NaN."""
    ws = make_planned_cp_als(tiny_tensor, 8)
    faults.inject_nan_factor(ws, at_iter=1)
    with pytest.warns(RuntimeWarning, match="non-finite fit"):
        out = decompose(tiny_tensor, 8, iters=ITERS, seed=0, planned=ws)
    assert len(out.fit_history) < ITERS
    assert not np.isfinite(out.fit_history[-1])


# ---------------------------------------------------------------------------
# GuardConfig / drive-extras contracts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "bad",
    [
        dict(policy="retry"),
        dict(divergence_patience=0),
        dict(max_restarts=-1),
        dict(check_factors_every=-1),
    ],
)
def test_guard_config_validation(bad):
    with pytest.raises(ValueError):
        GuardConfig(**bad)


def test_guard_state_regression_patience():
    gs = GuardState(GuardConfig(divergence_patience=2))
    assert gs.observe_fit(0.5) is None
    assert gs.observe_fit(0.3) is None          # streak 1
    reason = gs.observe_fit(0.2)                # streak 2 -> fires
    assert reason is not None and "regressed" in reason
    gs.reset()
    assert gs.observe_fit(0.1) is None


def test_guards_rejected_on_reference_methods(tiny_tensor):
    with pytest.raises(ValueError, match="guards"):
        decompose(tiny_tensor, 8, iters=2, method="approach1",
                  guards=GuardConfig())


def test_checkpoint_every_requires_path(tiny_tensor):
    with pytest.raises(ValueError, match="checkpoint"):
        decompose(tiny_tensor, 8, iters=2, checkpoint_every=2)


# ---------------------------------------------------------------------------
# Guard policies: detect and recover
# ---------------------------------------------------------------------------


def test_raise_policy_detects_nan(tiny_tensor):
    ws = make_planned_cp_als(tiny_tensor, 8)
    faults.inject_nan_factor(ws, at_iter=1)
    with pytest.raises(DecompositionDiverged) as ei:
        decompose(tiny_tensor, 8, iters=ITERS, seed=0, planned=ws,
                  guards=GuardConfig(policy="raise"))
    assert "non-finite fit" in str(ei.value)
    assert ei.value.fit_history  # diagnostic payload present


def test_factor_cadence_check_fires_at_injection_iter(tiny_tensor):
    """check_factors_every=1 catches the poison in the iteration it lands,
    one iteration earlier than the free fit guard."""
    ws = make_planned_cp_als(tiny_tensor, 8)
    faults.inject_nan_factor(ws, at_iter=1)
    with pytest.raises(DecompositionDiverged) as ei:
        decompose(tiny_tensor, 8, iters=ITERS, seed=0, planned=ws,
                  guards=GuardConfig(policy="raise", check_factors_every=1))
    assert ei.value.iteration == 1
    assert "factor" in ei.value.reason


@pytest.mark.parametrize("policy", ["restart", "fallback"])
@pytest.mark.parametrize("fixture", ["tiny_tensor", "tensor4d", "tensor5d"])
def test_recovery_matches_clean_run(request, fixture, policy):
    """Acceptance: restart and fallback recover to a final fit within 1e-5
    of the uninjected run on the 3/4/5-mode presets."""
    st = request.getfixturevalue(fixture)
    clean = _clean(st)
    ws = make_planned_cp_als(st, 8)
    faults.inject_nan_factor(ws, at_iter=1)
    out = decompose(st, 8, iters=ITERS, seed=0, planned=ws,
                    guards=GuardConfig(policy=policy))
    assert abs(out.fit_history[-1] - clean.fit_history[-1]) < 1e-5


@pytest.mark.parametrize("policy", ["restart", "fallback"])
@pytest.mark.parametrize("format,rank", [("tucker", (4, 4, 4)), ("tt", (4, 3))])
def test_recovery_other_formats(tiny_tensor, format, rank, policy):
    clean = decompose(tiny_tensor, rank, format=format, iters=ITERS, seed=0)
    if format == "tucker":
        from repro.tucker.hooi import make_planned_tucker as make
    else:
        from repro.tt.als import make_planned_tt as make
    ws = make(tiny_tensor, rank)
    faults.inject_nan_factor(ws, at_iter=1)
    out = decompose(tiny_tensor, rank, format=format, iters=ITERS, seed=0,
                    planned=ws, guards=GuardConfig(policy=policy))
    assert abs(out.fit_history[-1] - clean.fit_history[-1]) < 1e-5


def test_restart_budget_exhausted(tiny_tensor):
    """A fault that re-fires on every attempt must exhaust max_restarts and
    escalate instead of looping forever."""
    ws = make_planned_cp_als(tiny_tensor, 8)
    inner = ws._sweep_call

    def always_poisoned(facs, *args, it):
        import jax.numpy as jnp

        facs, aux, fit = inner(facs, *args, it=it)
        return facs, aux, fit * jnp.nan

    ws._sweep_call = always_poisoned
    with pytest.raises(DecompositionDiverged, match="restart budget"):
        decompose(tiny_tensor, 8, iters=ITERS, seed=0, planned=ws,
                  guards=GuardConfig(policy="restart", max_restarts=1))


def test_dead_shard_detected_by_regression_guard(tiny_tensor):
    """A silently dead shard loses its contribution to every psum'd update;
    the fit collapses and the regression guard fires."""
    from repro.dist.planned import make_sharded_planned_cp_als, shard_plan

    ws = make_sharded_planned_cp_als(tiny_tensor, 8, dist=shard_plan(1))
    faults.deaden_shard(ws, shard=0, at_iter=1)
    with pytest.raises(DecompositionDiverged, match="regressed"):
        decompose(tiny_tensor, 8, iters=10, seed=0, method="pallas_sharded",
                  planned=ws,
                  guards=GuardConfig(policy="raise", divergence_patience=2))


# ---------------------------------------------------------------------------
# Plan integrity validation
# ---------------------------------------------------------------------------


def _tiny_plan(st):
    return plan_blocks(st, 0, tile_i=256, blk=64, in_tiles=(256, 256))


def test_validate_plan_passes_good_plan(tiny_tensor):
    validate_plan(_tiny_plan(tiny_tensor))  # must not raise


def test_validate_plan_catches_corrupted_iloc(tiny_tensor):
    bad = faults.corrupt_plan(_tiny_plan(tiny_tensor))
    with pytest.raises(PlanValidationError, match="iloc"):
        validate_plan(bad)


def test_plans_validated_env_gate(monkeypatch):
    monkeypatch.delenv("REPRO_VALIDATE_PLANS", raising=False)
    assert not plans_validated()
    for v in ("1", "true", "YES", "on"):
        monkeypatch.setenv("REPRO_VALIDATE_PLANS", v)
        assert plans_validated()
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "0")
    assert not plans_validated()


def test_build_time_validation_accepts_real_plans(tiny_tensor, monkeypatch):
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "1")
    _tiny_plan(tiny_tensor)  # validated inside _assemble_plan; must not raise


def test_cache_hit_revalidates_resident_plan(tiny_tensor, monkeypatch):
    """REPRO_VALIDATE_PLANS=1 must catch a plan corrupted AFTER it entered
    the cache — the hit path revalidates, not just the build path."""
    ops.plan_cache_clear()
    args = ("mttkrp", tiny_tensor, 0, 8, None, True)
    op = ops._planned_cached(
        *args, lambda: ops.make_planned_mttkrp(tiny_tensor, 0, 8)
    )
    op.plan = faults.corrupt_plan(op.plan)  # corrupt the resident layout
    monkeypatch.setenv("REPRO_VALIDATE_PLANS", "1")
    with pytest.raises(PlanValidationError):
        ops._planned_cached(*args, lambda: pytest.fail("must be a cache hit"))
    ops.plan_cache_clear()


# ---------------------------------------------------------------------------
# Bounded plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_config_rejects_nonpositive():
    with pytest.raises(ValueError):
        ops.plan_cache_config(0)


def test_plan_cache_churn_is_bounded(tiny_tensor):
    old = ops.plan_cache_config()
    ops.plan_cache_clear()
    try:
        ops.plan_cache_config(4)
        for mode in range(10):  # 10 distinct keys through a 4-entry cache
            ops._planned_cached(
                "mttkrp", tiny_tensor, mode, 8, None, True, lambda: object()
            )
        stats = ops.plan_cache_stats()
        assert stats["size"] <= 4
        assert stats["maxsize"] == 4
        assert stats["evictions"] >= 6
    finally:
        ops.plan_cache_config(old)
        ops.plan_cache_clear()


def test_plan_cache_config_evicts_down(tiny_tensor):
    old = ops.plan_cache_config()
    ops.plan_cache_clear()
    try:
        for mode in range(6):
            ops._planned_cached(
                "mttkrp", tiny_tensor, mode, 8, None, True, lambda: object()
            )
        ops.plan_cache_config(2)
        assert ops.plan_cache_stats()["size"] <= 2
    finally:
        ops.plan_cache_config(old)
        ops.plan_cache_clear()


# ---------------------------------------------------------------------------
# HBM admission control
# ---------------------------------------------------------------------------


def test_admission_bytes_report(tiny_tensor):
    ws = make_planned_cp_als(tiny_tensor, 8)
    rep = admission_bytes(ws)
    assert set(rep) == {"plan_bytes", "factor_bytes", "vmem_bytes",
                        "total_bytes"}
    assert rep["total_bytes"] == (
        rep["plan_bytes"] + rep["factor_bytes"] + rep["vmem_bytes"]
    )
    assert all(v > 0 for v in rep.values())


def test_admit_rejects_shrunk_budget(tiny_tensor):
    ws = make_planned_cp_als(tiny_tensor, 8)
    budget = faults.shrunk_budget(ws)
    with pytest.raises(AdmissionError) as ei:
        admit(ws, budget)
    assert ei.value.budget_bytes == budget
    admit(ws, admission_bytes(ws)["total_bytes"])  # exact fit admits


def test_ladder_steps_down_blk(tiny_tensor):
    """One byte under the default-blk footprint must admit at a smaller blk
    (smaller DMA blocks -> less per-group padding -> smaller plans)."""
    from repro.core.memctrl import MemoryControllerConfig

    build = lambda c: make_planned_cp_als(tiny_tensor, 8, cfg=c)
    top_blk = MemoryControllerConfig().dma.blk
    top_total = admission_bytes(build(None))["total_bytes"]
    ws, decision = plan_with_budget(build, top_total - 1)
    assert ws is not None
    assert decision["admitted"] == "pallas"
    assert decision["blk"] < top_blk
    assert len(decision["ladder"]) >= 2


def test_ladder_degrades_to_reference(tiny_tensor):
    """A budget below every pallas rung but above the raw-stream footprint
    routes decompose() to the reference method and still returns a state."""
    ref = reference_footprint_bytes(tiny_tensor, (8, 8, 8))
    budget = ref + 10_000  # far below the ~1.3 MB pallas rungs
    out = decompose(tiny_tensor, 8, iters=3, seed=0, hbm_budget=budget)
    want = decompose(tiny_tensor, 8, iters=3, seed=0, method="approach1")
    assert abs(out.fit_history[-1] - want.fit_history[-1]) < 1e-5


def test_impossible_budget_raises_with_ladder(tiny_tensor):
    with pytest.raises(AdmissionError) as ei:
        decompose(tiny_tensor, 8, iters=3, hbm_budget=1_000)
    assert ei.value.ladder  # every attempted rung is in the diagnostic
    assert ei.value.reference_bytes > 1_000


def test_budget_incompatible_with_auto_tune(tiny_tensor):
    with pytest.raises(ValueError, match="auto_tune"):
        decompose(tiny_tensor, 8, iters=2, hbm_budget=10**9, auto_tune=True)


# ---------------------------------------------------------------------------
# Checkpoint/resume: kill a sweep, resume bit-for-bit
# ---------------------------------------------------------------------------

_KILLED_SWEEP = """
import sys
sys.path.insert(0, {src!r})
from repro.api import decompose
from repro.core.coo import synthetic_tensor
from repro.testing import faults
{make_import}
st = synthetic_tensor((64, 48, 80), 2_000, seed=0, skew=0.8)
ws = {make_call}
faults.kill_at(ws, at_iter=3)
decompose(st, {rank}, format={format!r}, iters=5, seed=0, planned=ws,
          checkpoint_path={ckpt!r})
"""

_FORMAT_BUILDERS = {
    "cp": ("from repro.kernels.ops import make_planned_cp_als",
           "make_planned_cp_als(st, 8)", 8),
    "tucker": ("from repro.tucker.hooi import make_planned_tucker",
               "make_planned_tucker(st, (4, 4, 4))", (4, 4, 4)),
    "tt": ("from repro.tt.als import make_planned_tt",
           "make_planned_tt(st, (4, 3))", (4, 3)),
}


@pytest.mark.parametrize("format", ["cp", "tucker", "tt"])
def test_killed_sweep_resumes_to_clean_parity(tiny_tensor, tmp_path, format):
    """Kill the sweep dead (os._exit) before iteration 3, resume from the
    surviving checkpoints, and require the full fit history to match the
    uninterrupted run to 1e-6."""
    make_import, make_call, rank = _FORMAT_BUILDERS[format]
    code = _KILLED_SWEEP.format(
        src=os.path.join(ROOT, "src"), make_import=make_import,
        make_call=make_call, rank=rank, format=format, ckpt=str(tmp_path),
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=520, cwd=ROOT,
    )
    assert proc.returncode == 17, (
        f"expected the kill_at exit code, got {proc.returncode}\n"
        f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    )
    assert os.listdir(str(tmp_path)), "no checkpoint survived the kill"

    resumed = decompose(tiny_tensor, rank, format=format, iters=ITERS,
                        seed=0, checkpoint_path=str(tmp_path))
    clean = decompose(tiny_tensor, rank, format=format, iters=ITERS, seed=0)
    assert len(resumed.fit_history) == len(clean.fit_history)
    deltas = [abs(a - b)
              for a, b in zip(resumed.fit_history, clean.fit_history)]
    assert max(deltas) < 1e-6, deltas


def test_resume_rejects_mismatched_shapes(tiny_tensor, tmp_path):
    """A checkpoint from a different rank must fail loudly, not silently
    corrupt the resumed run."""
    decompose(tiny_tensor, 8, iters=2, seed=0, checkpoint_path=str(tmp_path))
    with pytest.raises(ValueError, match="checkpoint"):
        decompose(tiny_tensor, 4, iters=4, seed=0,
                  checkpoint_path=str(tmp_path))


def test_checkpoint_every_cadence(tiny_tensor, tmp_path):
    """checkpoint_every=2 writes at iterations 1, 3 and at the final stop."""
    from repro.train.checkpoint import CheckpointManager

    decompose(tiny_tensor, 8, iters=5, seed=0, checkpoint_path=str(tmp_path),
              checkpoint_every=2)
    steps = CheckpointManager(str(tmp_path), keep=2).all_steps()
    assert steps and steps[-1] == 4
