"""Observability layer (repro.obs): span/event tracing, the metrics
registry, and the predicted-vs-achieved PMS join.

The contract under test: tracing OFF is free (the drive loop with the obs
calls compiled to no-ops stays within 2% of the same loop with the obs
modules monkeypatched inert), tracing ON records the spans every layer
promises (decompose -> drive -> sweep, plan_build, plan-cache events), and
the calibrate join reproduces achieved_pct from a trace alone."""
import json
import math
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import decompose
from repro.core.coo import random_factors
from repro.core.loop import finish_iter
from repro.kernels import ops
from repro.obs import Tracer, metrics, trace
from repro.obs.calibrate import (
    CalibrationRow,
    accuracy_records,
    calibration_row,
    format_table,
    join_trace,
    predicted_sweep_seconds,
)


@pytest.fixture(autouse=True)
def _clean_obs_state():
    """Every test starts and ends with tracing off and a fresh registry —
    obs state is process-global by design, so tests must not leak it."""
    trace.disable()
    metrics.reset()
    yield
    trace.disable()
    metrics.reset()


# ---------------------------------------------------------------------------
# trace: spans, nesting, export round-trips
# ---------------------------------------------------------------------------


def test_span_nesting_and_roundtrip(tmp_path):
    tr = Tracer()
    trace.install(tr)
    with trace.span("outer", layer="a"):
        with trace.span("inner", layer="b"):
            trace.event("ping", n=1)
        with trace.span("inner", layer="c"):
            pass
    assert len(tr.spans("outer")) == 1
    assert len(tr.spans("inner")) == 2
    outer = tr.spans("outer")[0]
    assert outer["parent"] is None
    for rec in tr.spans("inner"):
        assert rec["parent"] == outer["id"]
        assert rec["dur"] >= 0
    (ping,) = tr.events("ping")
    assert ping["args"] == {"n": 1}
    # events nest under the span that was open when they fired
    inner_b = [r for r in tr.spans("inner") if r["args"]["layer"] == "b"][0]
    assert ping["parent"] == inner_b["id"]

    path = tmp_path / "t.jsonl"
    assert tr.export_jsonl(path) == 4
    loaded = trace.load_jsonl(path)
    assert loaded == tr.records

    chrome = tmp_path / "t.json"
    assert tr.export_chrome(chrome) == 4
    doc = json.loads(chrome.read_text())
    assert {e["ph"] for e in doc["traceEvents"]} == {"X", "i"}
    x = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert all("dur" in e and "ts" in e for e in x)


def test_span_set_attaches_mid_span():
    tr = Tracer()
    trace.install(tr)
    with trace.span("s") as sp:
        sp.set(fit=0.5)
    assert tr.spans("s")[0]["args"]["fit"] == 0.5


def test_disabled_calls_are_noops():
    assert trace.active() is None
    sp = trace.span("x", a=1)
    assert sp is trace.span("y")  # the shared null span, no allocation
    with sp as s:
        s.set(b=2)
    trace.event("never")


def test_tracing_scope_restores_previous_tracer(tmp_path):
    outer = trace.enable()
    path = tmp_path / "scoped.jsonl"
    with trace.tracing(str(path)) as tr:
        assert trace.active() is tr
        with trace.span("scoped"):
            pass
    assert trace.active() is outer
    recs = trace.load_jsonl(path)
    assert [r["name"] for r in recs] == ["scoped"]
    assert outer.records == []  # scoped work never leaked into the global


def test_load_jsonl_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"ph": "X", "name": "ok", "ts": 1}\nnot json\n')
    with pytest.raises(ValueError, match="not valid JSON"):
        trace.load_jsonl(bad)
    bad.write_text('{"name": "missing ph", "ts": 1}\n')
    with pytest.raises(ValueError, match="missing field"):
        trace.load_jsonl(bad)


def test_configure_from_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    tr = trace.configure_from_env()
    assert trace.active() is tr
    trace.disable()
    out = tmp_path / "env.jsonl"
    monkeypatch.setenv("REPRO_TRACE", str(out))
    tr = trace.configure_from_env()
    with trace.span("from_env"):
        pass
    trace._export_at_exit()
    assert [r["name"] for r in trace.load_jsonl(out)] == ["from_env"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_counter_gauge_histogram():
    c = metrics.counter("c", kind="x")
    c.inc()
    c.inc(2)
    assert metrics.counter("c", kind="x") is c  # get-or-create
    g = metrics.gauge("g")
    g.set(7.5)
    h = metrics.histogram("h")
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        h.observe(v)
    snap = metrics.snapshot()
    assert snap["counters"]["c{kind=x}"] == 3
    assert snap["gauges"]["g"] == 7.5
    hs = snap["histograms"]["h"]
    assert hs["count"] == 5 and hs["min"] == 1.0 and hs["max"] == 5.0
    assert hs["mean"] == 3.0
    assert h.percentile(50) == 3.0
    with pytest.raises(TypeError):
        metrics.gauge("c", kind="x")  # same series name, different type
    metrics.reset()
    assert metrics.snapshot() == {"counters": {}, "gauges": {},
                                  "histograms": {}}


# ---------------------------------------------------------------------------
# engine integration: decompose -> drive -> sweep spans + metrics
# ---------------------------------------------------------------------------


def test_decompose_trace_records_engine_spans(tiny_tensor, tmp_path):
    path = tmp_path / "cp.jsonl"
    out = decompose(tiny_tensor, 4, iters=3, trace=str(path))
    assert trace.active() is None  # restored after the call
    recs = trace.load_jsonl(path)
    names = [r["name"] for r in recs if r["ph"] == "X"]
    assert names.count("decompose") == 1
    assert names.count("drive") == 1
    assert names.count("sweep") == 3
    assert names.count("plan_build") == tiny_tensor.nmodes
    # nesting: sweep under drive under decompose
    by_id = {r["id"]: r for r in recs}
    sweep = [r for r in recs if r["name"] == "sweep"][0]
    drive = by_id[sweep["parent"]]
    assert drive["name"] == "drive"
    assert by_id[drive["parent"]]["name"] == "decompose"
    # the sweep spans carry the PMS prediction for the offline join
    assert sweep["args"]["predicted_s"] == pytest.approx(
        sum(e.t_total for e in
            ops.make_planned_cp_als(tiny_tensor, 4).pms_estimates().values()),
        rel=1e-6,
    )
    assert len(out.fit_history) == 3
    # the always-on metrics saw the iterations even though trace was scoped
    snap = metrics.snapshot()
    assert snap["counters"]["drive.iterations{label=cp_als}"] == 3
    assert snap["histograms"]["drive.iter_seconds{label=cp_als}"]["count"] == 3


def test_plan_build_metrics_recorded(tiny_tensor):
    from repro.core.remap import plan_blocks

    plan_blocks(tiny_tensor, 0)
    snap = metrics.snapshot()
    assert snap["histograms"]["plan.build_seconds{builder=vectorized}"]["count"] == 1
    pad = snap["histograms"]["plan.padding_fraction"]
    occ = snap["histograms"]["plan.occupancy"]
    assert pad["count"] == occ["count"] == 1
    assert pad["mean"] + occ["mean"] == pytest.approx(1.0)


def test_plan_cache_counters_match_stats(tiny_tensor):
    facs = random_factors(jax.random.PRNGKey(0), tiny_tensor.shape, 4)
    ops.plan_cache_clear()
    metrics.reset()
    tr = trace.enable()
    try:
        ops.mttkrp_auto(tiny_tensor, facs, 0)   # miss
        ops.mttkrp_auto(tiny_tensor, facs, 0)   # hit
        ops.mttkrp_auto(tiny_tensor, facs, 1)   # miss
    finally:
        trace.disable()
    stats = ops.plan_cache_stats()["by_kind"]["mttkrp"]
    snap = metrics.snapshot()
    assert snap["counters"]["plan_cache.misses{kind=mttkrp}"] == stats["misses"] == 2
    assert snap["counters"]["plan_cache.hits{kind=mttkrp}"] == stats["hits"] == 1
    assert snap["histograms"]["plan_cache.miss_build_seconds{kind=mttkrp}"]["count"] == 2
    assert snap["histograms"]["plan_cache.hit_seconds{kind=mttkrp}"]["count"] == 1
    assert len(tr.events("plan_cache_hit")) == 1
    assert len(tr.spans("plan_cache_build")) == 2


def test_plan_cache_eviction_counter(tiny_tensor):
    facs = random_factors(jax.random.PRNGKey(0), tiny_tensor.shape, 4)
    old_cap = ops.plan_cache_config()
    ops.plan_cache_clear()
    metrics.reset()
    try:
        ops.plan_cache_config(1)
        ops.mttkrp_auto(tiny_tensor, facs, 0)
        ops.mttkrp_auto(tiny_tensor, facs, 1)  # evicts mode 0's plan
        ops.mttkrp_auto(tiny_tensor, facs, 0)  # miss again (was evicted)
    finally:
        ops.plan_cache_config(old_cap)
        ops.plan_cache_clear()
    snap = metrics.snapshot()
    assert snap["counters"]["plan_cache.evictions"] == 2
    assert snap["counters"]["plan_cache.misses{kind=mttkrp}"] == 3
    assert "plan_cache.hits{kind=mttkrp}" not in snap["counters"]


def test_nonfinite_fit_event_and_counter():
    tr = trace.enable()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            stop = finish_iter([], float("nan"), 3, None, False, "unit")
    finally:
        trace.disable()
    assert stop is True
    assert metrics.snapshot()["counters"]["resilience.nonfinite_fit{label=unit}"] == 1
    (ev,) = tr.events("nonfinite_fit")
    assert ev["args"]["it"] == 3 and ev["args"]["label"] == "unit"


def test_guard_restart_counted(tiny_tensor):
    from repro.resilience import GuardConfig
    from repro.testing import faults

    ws = ops.make_planned_cp_als(tiny_tensor, 4)
    faults.inject_nan_factor(ws, at_iter=1)
    tr = trace.enable()
    try:
        decompose(tiny_tensor, 4, iters=4, seed=0, planned=ws,
                  guards=GuardConfig(policy="restart", max_restarts=1))
    finally:
        trace.disable()
    snap = metrics.snapshot()
    assert snap["counters"]["resilience.restarts{label=cp_als}"] == 1
    assert len(tr.events("guard_restart")) == 1


def test_admission_metrics(tiny_tensor):
    from repro.resilience import admit, admission_bytes

    ws = ops.make_planned_cp_als(tiny_tensor, 4)
    admit(ws, admission_bytes(ws)["total_bytes"] + 1)
    snap = metrics.snapshot()
    assert snap["counters"]["admission.admitted{outcome=pallas}"] == 1


# ---------------------------------------------------------------------------
# calibrate: the PMS join
# ---------------------------------------------------------------------------


def test_pms_estimates_hooks(tiny_tensor):
    from repro.tt.als import make_planned_tt
    from repro.tucker.hooi import make_planned_tucker

    for ws in (
        ops.make_planned_cp_als(tiny_tensor, 4),
        make_planned_tucker(tiny_tensor, (3, 3, 3)),
        make_planned_tt(tiny_tensor, (2, 2)),
    ):
        pred = predicted_sweep_seconds(ws)
        assert pred > 0 and math.isfinite(pred)
        ests = ws.pms_estimates()
        assert set(ests) == set(range(tiny_tensor.nmodes))


def test_calibration_row_and_records():
    row = CalibrationRow("cp", "small", predicted_s=0.02, measured_s=4.0)
    assert row.achieved_pct == pytest.approx(0.5)
    recs = accuracy_records([row])
    assert [r["metric"] for r in recs] == [
        "predicted_s", "measured_s", "achieved_pct"]
    assert all(r["name"] == "pms_accuracy_cp" and r["preset"] == "small"
               for r in recs)
    with pytest.raises(ValueError):
        calibration_row(object(), 0.0, format="cp", preset="x")


def test_join_trace_on_fixed_fixture(tmp_path):
    """The offline join on a hand-built trace: 1 compile sweep + 3 steady
    sweeps; measured = median of the steady three, achieved = pred/measured."""
    recs = [
        {"ph": "X", "name": "sweep", "ts": i * 100.0, "dur": dur,
         "args": {"label": "cp_als", "preset": "small",
                  "predicted_s": 0.002, "it": i}}
        for i, dur in enumerate([900_000.0, 110_000.0, 100_000.0, 90_000.0])
    ]
    recs.append({"ph": "X", "name": "plan_build", "ts": 0.0, "dur": 5.0,
                 "args": {}})
    path = tmp_path / "fixture.jsonl"
    path.write_text("".join(json.dumps(r) + "\n" for r in recs))
    rows = join_trace(path)
    assert len(rows) == 1
    r = rows[0]
    assert r["label"] == "cp_als" and r["preset"] == "small"
    assert r["n_sweeps"] == 4
    assert r["measured_s"] == pytest.approx(0.1)   # median excl. first
    assert r["achieved_pct"] == pytest.approx(2.0)  # 100 * 0.002 / 0.1
    table = format_table(rows)
    assert "cp_als" in table and "2.00%" in table


def test_join_trace_without_predictions():
    recs = [{"ph": "X", "name": "sweep", "ts": 0.0, "dur": 50_000.0,
             "args": {"label": "tt_als"}}]
    (row,) = join_trace(recs)
    assert row["predicted_s"] is None and row["achieved_pct"] is None


# ---------------------------------------------------------------------------
# the traced-off overhead bound
# ---------------------------------------------------------------------------


def test_traced_off_drive_overhead_under_2pct(small_tensor):
    """ISSUE acceptance: with tracing disabled, the instrumented drive loop
    must stay within 2% of the same loop with the obs modules monkeypatched
    inert — the no-op path is one global read per call site."""
    from repro.kernels import workspace as wsmod

    rank = 8
    ws = ops.make_planned_cp_als(small_tensor, rank)
    f0 = random_factors(jax.random.PRNGKey(0), small_tensor.shape, rank)
    idx = jnp.asarray(small_tensor.indices)
    val = jnp.asarray(small_tensor.values)
    nxs = jnp.asarray(
        float(np.sum(small_tensor.values.astype(np.float64) ** 2)))
    args = (idx, val, nxs)
    iters = 2

    class _InertMetrics:
        def counter(self, *a, **kw):
            return self

        histogram = gauge = counter

        def inc(self, *a):
            pass

        def observe(self, *a):
            pass

        def set(self, *a):
            pass

    class _InertTrace:
        @staticmethod
        def active():
            return None

        @staticmethod
        def span(*a, **kw):
            return trace._NULL_SPAN

        @staticmethod
        def event(*a, **kw):
            pass

    def best_of(n):
        best = math.inf
        for _ in range(n):
            t0 = time.perf_counter()
            ws.drive(f0, args, iters=iters)
            best = min(best, time.perf_counter() - t0)
        return best

    assert trace.active() is None
    ws.drive(f0, args, iters=iters)  # compile both sweep variants
    t_instrumented = best_of(4)
    real_metrics, real_trace = wsmod._metrics, wsmod._trace
    try:
        wsmod._metrics, wsmod._trace = _InertMetrics(), _InertTrace()
        t_inert = best_of(4)
    finally:
        wsmod._metrics, wsmod._trace = real_metrics, real_trace
    overhead = (t_instrumented - t_inert) / t_inert
    assert overhead < 0.02, (
        f"traced-off drive overhead {overhead:+.2%} exceeds 2% "
        f"(instrumented {t_instrumented:.4f}s vs inert {t_inert:.4f}s)"
    )


# ---------------------------------------------------------------------------
# the sharded makespan report
# ---------------------------------------------------------------------------


def test_shard_makespan_report_shape():
    from repro.dist.planned import shard_makespan_report

    class _Stack:
        def __init__(self, mode, nblocks, nnz):
            self.mode = mode
            self.shard_nblocks = nblocks
            self.shard_nnz = nnz

    class _WS:
        stacks = {0: _Stack(0, (4, 2), (100, 50)),
                  1: _Stack(1, (3, 3), (75, 75))}

    rep = shard_makespan_report(_WS())
    assert rep["nshards"] == 2
    m0 = rep["modes"][0]
    assert m0["makespan_blocks"] == 4
    assert m0["block_imbalance"] == pytest.approx(4 * 2 / 6)
    assert m0["busy_fraction"] == (1.0, 0.5)
    assert rep["modes"][1]["block_imbalance"] == pytest.approx(1.0)
    assert rep["worst_block_imbalance"] == pytest.approx(4 * 2 / 6)
    snap = metrics.snapshot()
    assert snap["histograms"]["sharded.block_imbalance{mode=0}"]["count"] == 1
    with pytest.raises(TypeError):
        shard_makespan_report(object())
