"""spMTTKRP compute patterns (paper Sec. 3): both approaches must agree with
two independent oracles, for any mode, order, and dtype."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coo import random_factors, synthetic_tensor
from repro.core.mttkrp import hadamard_rows, mttkrp, mttkrp_approach1, mttkrp_approach2
from repro.core.remap import remap_stable
from repro.kernels.ref import mttkrp_ref, mttkrp_ref_dense


def _run(st_t, rank, mode, method):
    facs = random_factors(jax.random.PRNGKey(7), st_t.shape, rank)
    idx, val = jnp.asarray(st_t.indices), jnp.asarray(st_t.values)
    if method == "approach1":  # stream must be in output-mode order (Alg. 3)
        idx, val, _ = remap_stable(idx, val, mode)
    out = mttkrp(idx, val, facs, mode, st_t.shape[mode], method=method)
    ref = mttkrp_ref(jnp.asarray(st_t.indices), jnp.asarray(st_t.values), facs, mode, st_t.shape[mode])
    return np.asarray(out), np.asarray(ref)


@pytest.mark.parametrize("mode", [0, 1, 2])
@pytest.mark.parametrize("method", ["approach1", "approach2"])
def test_approaches_agree_3mode(tiny_tensor, mode, method):
    out, ref = _run(tiny_tensor, 16, mode, method)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("fixture", ["tensor4d", "tensor5d"])
@pytest.mark.parametrize("method", ["approach1", "approach2"])
def test_approaches_agree_higher_order(request, fixture, method):
    st_t = request.getfixturevalue(fixture)
    for mode in range(st_t.nmodes):
        out, ref = _run(st_t, 8, mode, method)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


def test_against_dense_oracle(tiny_tensor):
    """Sparse reference cross-checked against densify+einsum."""
    facs = random_factors(jax.random.PRNGKey(3), tiny_tensor.shape, 8)
    for mode in range(3):
        ref = mttkrp_ref(
            jnp.asarray(tiny_tensor.indices), jnp.asarray(tiny_tensor.values),
            facs, mode, tiny_tensor.shape[mode],
        )
        dense = mttkrp_ref_dense(
            tiny_tensor.indices, tiny_tensor.values,
            [np.asarray(f) for f in facs], mode, tiny_tensor.shape[mode],
        )
        np.testing.assert_allclose(np.asarray(ref), dense, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(tiny_tensor, dtype):
    facs = [f.astype(dtype) for f in random_factors(jax.random.PRNGKey(1), tiny_tensor.shape, 16)]
    idx = jnp.asarray(tiny_tensor.indices)
    val = jnp.asarray(tiny_tensor.values, dtype)
    a2 = mttkrp_approach2(idx, val, facs, 0, tiny_tensor.shape[0])
    f32 = mttkrp_approach2(idx, val.astype(jnp.float32),
                           [f.astype(jnp.float32) for f in facs], 0, tiny_tensor.shape[0])
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(a2, np.float32), np.asarray(f32), rtol=tol, atol=tol)


def test_hadamard_rows_is_khatri_rao_gather(tiny_tensor):
    """hadamard_rows == rows of the Khatri-Rao product selected by indices."""
    facs = random_factors(jax.random.PRNGKey(2), tiny_tensor.shape, 4)
    idx = jnp.asarray(tiny_tensor.indices[:50])
    val = jnp.asarray(tiny_tensor.values[:50])
    got = hadamard_rows(idx, val, facs, 0)
    b, c = np.asarray(facs[1]), np.asarray(facs[2])
    for z in range(50):
        want = tiny_tensor.values[z] * b[tiny_tensor.indices[z, 1]] * c[tiny_tensor.indices[z, 2]]
        np.testing.assert_allclose(np.asarray(got[z]), want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    nnz=st.integers(1, 300),
    dims=st.tuples(st.integers(2, 30), st.integers(2, 30), st.integers(2, 30)),
    rank=st.sampled_from([1, 4, 16]),
    mode=st.integers(0, 2),
    seed=st.integers(0, 999),
)
def test_property_approaches_equal(nnz, dims, rank, mode, seed):
    """Property: for random tensors, Approach 1 (sorted segment-sum) and
    Approach 2 (scatter-add) compute identical MTTKRP."""
    st_t = synthetic_tensor(dims, nnz, seed=seed, skew=0.7)
    o1, r1 = _run(st_t, rank, mode, "approach1")
    o2, r2 = _run(st_t, rank, mode, "approach2")
    np.testing.assert_allclose(o1, r1, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(o2, r2, rtol=2e-4, atol=2e-4)
