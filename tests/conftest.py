"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
try:  # property tests prefer the real hypothesis; hermetic containers may
    import hypothesis  # noqa: F401 — lack it, so fall back to the repo stub
except ImportError:
    from repro._compat import hypothesis_stub

    hypothesis_stub.install()

import jax
import numpy as np
import pytest

from repro.core.coo import SparseTensor, synthetic_tensor, random_factors


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_tensor() -> SparseTensor:
    return synthetic_tensor((64, 48, 80), 2_000, seed=0, skew=0.8)


@pytest.fixture(scope="session")
def small_tensor() -> SparseTensor:
    return synthetic_tensor((600, 500, 700), 20_000, seed=1, skew=1.0)


@pytest.fixture(scope="session")
def tensor4d() -> SparseTensor:
    return synthetic_tensor((50, 40, 60, 30), 4_000, seed=2, skew=0.5)


@pytest.fixture(scope="session")
def tensor5d() -> SparseTensor:
    return synthetic_tensor((20, 25, 30, 15, 18), 3_000, seed=3, skew=0.3)
