"""Checkpointing: roundtrip, atomicity, keep-k pruning, async writes, and
elastic (resharded) restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


@pytest.fixture()
def tree():
    key = jax.random.PRNGKey(0)
    return {
        "params": {"w": jax.random.normal(key, (8, 4)), "b": jnp.zeros(4)},
        "opt": {"m": jnp.ones((8, 4)), "step": jnp.array(7, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, tree)
    step, restored = mgr.restore()
    assert step == 5
    _assert_tree_equal(tree, restored)


def test_async_save(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_keep_last_k(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_atomicity_tmp_dirs_ignored(tmp_path, tree):
    """A crash mid-write (simulated: leftover .tmp dir) must be invisible."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, tree)
    crashed = os.path.join(str(tmp_path), "step_00000009.tmp")
    os.makedirs(crashed)
    with open(os.path.join(crashed, "arr_0.npy"), "w") as f:
        f.write("garbage")
    assert mgr.latest_step() == 1
    step, restored = mgr.restore()
    assert step == 1
    _assert_tree_equal(tree, restored)


def test_corrupt_unpublished_manifest_ignored(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(2, tree)
    empty = os.path.join(str(tmp_path), "step_00000005")
    os.makedirs(empty)  # published dir without manifest = unreadable
    assert mgr.latest_step() == 2


def test_restore_specific_step(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, tree)
    tree2 = jax.tree.map(lambda x: x + 1, tree)
    mgr.save(2, tree2)
    step, restored = mgr.restore(step=1)
    assert step == 1
    _assert_tree_equal(tree, restored)


def test_elastic_restore_with_shardings(tmp_path, tree):
    """Restore with explicit (single-device here) shardings — the reshard
    path used by grow/shrink restarts."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(3, tree)
    dev = jax.devices()[0]
    shardings = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    step, restored = mgr.restore(shardings=shardings)
    assert step == 3
    _assert_tree_equal(tree, restored)
    for leaf in jax.tree.leaves(restored):
        assert leaf.sharding == jax.sharding.SingleDeviceSharding(dev)


def test_missing_dir_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "empty"), keep=1)
    with pytest.raises(FileNotFoundError):
        mgr.restore()
