"""repro.tune test suite (ISSUE 10): PMS predictor properties, the
measured-roofline fit, the persistent autotune cache's robustness contract,
and warm-cache decompose parity.

Four pillars:
  * predictor properties (hypothesis): every PMS predictor
    (predict_from_plan / predict_ttmc / predict_tt / predict_sharded) is
    non-negative and non-increasing in `hbm_bw` and `peak_flops_f32` — a
    faster machine can never be predicted slower;
  * fit recovery: synthetic samples generated from known constants recover
    (hbm_bw, peak_flops_f32) through `tune.fit_spec` to <1%;
  * cache robustness: bit-for-bit round-trips, corrupt/truncated/
    version-bumped files degrade to a clean re-search (never a crash),
    cross-backend and cross-kernel keys never collide, concurrent writers
    keep the file valid JSON (atomic rename);
  * parity: `decompose(auto_tune="cached")` on a warm cache is bit-for-bit
    identical to the fresh `auto_tune=True` search path for cp/tucker/tt,
    with ZERO `pms.configs_evaluated` on the hit (obs.metrics).

Plus the ISSUE's drift fix: benchmarks/roofline.py constants are pinned to
`memctrl.TPUSpec`.
"""
import dataclasses
import json
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.core.coo import frostt_like, synthetic_tensor
from repro.core.memctrl import (
    CacheEngineConfig,
    DMAEngineConfig,
    MemoryControllerConfig,
    TPUSpec,
    config_from_dict,
    config_to_dict,
    spec_from_dict,
    spec_to_dict,
)
from repro.core.pms import (
    predict_from_plan,
    predict_sharded,
    predict_tt,
    predict_ttmc,
)
from repro.core.remap import plan_blocks
from repro.obs import metrics
from repro.tune import (
    AutotuneCache,
    CalibSample,
    cache_path,
    config_key,
    current_backend,
    fit_spec,
    predicted_seconds,
    resolve_spec,
    roofline_counts,
    sweep_sample,
)
from repro.tune.cache import SCHEMA_VERSION, cached_config


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets its own on-disk cache and a clean metrics registry —
    the autotune cache is process-global state by design."""
    monkeypatch.setenv("REPRO_AUTOTUNE_DIR", str(tmp_path / "autotune"))
    metrics.reset()
    yield
    metrics.reset()


def _counters():
    return metrics.snapshot()["counters"]


# ---------------------------------------------------------------------------
# Predictor properties: monotone in the hardware constants, non-negative
# ---------------------------------------------------------------------------

_CFG = MemoryControllerConfig()


def _spec_scaled(bw_x: float, pf_x: float) -> TPUSpec:
    base = TPUSpec()
    return dataclasses.replace(
        base,
        hbm_bw=base.hbm_bw * bw_x,
        peak_flops_f32=base.peak_flops_f32 * pf_x,
        peak_flops=base.peak_flops * pf_x,
    )


def _term_estimates(est):
    """The per-term PMSEstimates of `est`: itself, or — for the sharded
    makespan wrapper — every shard's estimate."""
    return est.per_shard if hasattr(est, "per_shard") else (est,)


def _assert_monotone(predict, bw_x: float, pf_x: float):
    """Faster hardware (either constant scaled up) never predicts slower,
    and every roofline term stays non-negative."""
    lo, hi = min(bw_x, 1.0), max(bw_x, 1.0)
    base = predict(_spec_scaled(lo, 1.0))
    fast = predict(_spec_scaled(hi, 1.0))
    assert fast.t_total <= base.t_total + 1e-12
    base = predict(_spec_scaled(1.0, min(pf_x, 1.0)))
    fast = predict(_spec_scaled(1.0, max(pf_x, 1.0)))
    assert fast.t_total <= base.t_total + 1e-12
    for est in (base, fast):
        assert est.t_total >= 0
        for term in _term_estimates(est):
            assert term.t_stream >= 0 and term.t_factor >= 0
            assert term.t_out >= 0 and term.t_compute >= 0


@settings(max_examples=8, deadline=None)
@given(
    bw_x=hst.floats(0.01, 100.0),
    pf_x=hst.floats(0.01, 100.0),
    mode=hst.integers(0, 2),
    seed=hst.integers(0, 20),
)
def test_predict_from_plan_monotone_in_spec(bw_x, pf_x, mode, seed):
    tensor = synthetic_tensor((40, 30, 50), 800, seed=seed)
    plan = plan_blocks(tensor, mode, blk=_CFG.dma.blk)
    _assert_monotone(lambda s: predict_from_plan(plan, 8, _CFG, s), bw_x, pf_x)


@settings(max_examples=8, deadline=None)
@given(bw_x=hst.floats(0.01, 100.0), pf_x=hst.floats(0.01, 100.0),
       mode=hst.integers(0, 2))
def test_predict_ttmc_monotone_in_spec(bw_x, pf_x, mode):
    tensor = synthetic_tensor((40, 30, 50), 800, seed=3)
    plan = plan_blocks(tensor, mode, blk=_CFG.dma.blk)
    _assert_monotone(
        lambda s: predict_ttmc(plan, (4, 4, 4), _CFG, s), bw_x, pf_x
    )


@settings(max_examples=8, deadline=None)
@given(bw_x=hst.floats(0.01, 100.0), pf_x=hst.floats(0.01, 100.0),
       mode=hst.integers(0, 2))
def test_predict_tt_monotone_in_spec(bw_x, pf_x, mode):
    tensor = synthetic_tensor((40, 30, 50), 800, seed=5)
    plan = plan_blocks(tensor, mode, blk=_CFG.dma.blk)
    _assert_monotone(lambda s: predict_tt(plan, (4, 4), _CFG, s), bw_x, pf_x)


@settings(max_examples=6, deadline=None)
@given(bw_x=hst.floats(0.01, 100.0), pf_x=hst.floats(0.01, 100.0),
       nshards=hst.sampled_from([2, 4]))
def test_predict_sharded_monotone_in_spec(bw_x, pf_x, nshards):
    tensor = synthetic_tensor((64, 40, 48), 1200, seed=7)
    _assert_monotone(
        lambda s: predict_sharded(tensor, 0, 8, nshards, _CFG, spec=s),
        bw_x, pf_x,
    )


# ---------------------------------------------------------------------------
# Fit recovery: known constants come back through the least squares to <1%
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    bw=hst.floats(1e8, 1e12),
    pf=hst.floats(1e9, 1e14),
    seed=hst.integers(0, 1000),
)
def test_fit_spec_recovers_known_constants(bw, pf, seed):
    """Samples priced exactly by the sum-form roofline at known (bw, pf)
    must recover both constants to <1% — the calibration loop is only
    trustworthy if the solver is."""
    rng = np.random.default_rng(seed)
    samples = []
    for i in range(6):
        # Byte/FLOP mixes spanning memory-bound to compute-bound cells so
        # the least-squares system is well conditioned.
        b = float(rng.uniform(0.5, 8.0) * bw)          # ~0.5-8 s of memory
        f = float(rng.uniform(0.05, 4.0) * pf)         # ~0.05-4 s of compute
        t = b / bw + f / pf
        samples.append(CalibSample(label=f"s{i}", per_mode=((b, f),), measured_s=t))
    fitted = fit_spec(samples)
    assert abs(fitted.hbm_bw - bw) / bw < 0.01
    assert abs(fitted.peak_flops_f32 - pf) / pf < 0.01


def test_fit_spec_through_real_sweep_counts():
    """End-to-end through `tune` plumbing: price real workspaces' exact
    byte/FLOP counts (roofline_counts) under known constants, fit, recover
    to <1% — the synthetic-plan variant of the ISSUE's acceptance."""
    st = frostt_like("tiny")
    bw_true, pf_true = 3.7e9, 5.2e10
    cfgs = (
        MemoryControllerConfig(
            cache=CacheEngineConfig(tile_i=128, tile_j=128, tile_k=128),
            dma=DMAEngineConfig(blk=128),
        ),
        MemoryControllerConfig(),
        MemoryControllerConfig(
            cache=CacheEngineConfig(tile_i=512, tile_j=512, tile_k=512),
            dma=DMAEngineConfig(blk=512),
        ),
    )
    from repro.kernels.ops import make_planned_cp_als

    samples = []
    for cfg in cfgs:
        per_mode = roofline_counts(make_planned_cp_als(st, 8, cfg=cfg))
        t = sum(b / bw_true + f / pf_true for b, f in per_mode)
        samples.append(CalibSample(label=str(cfg.dma.blk), per_mode=per_mode,
                                   measured_s=t))
    fitted = fit_spec(samples)
    assert abs(fitted.hbm_bw - bw_true) / bw_true < 0.01
    assert abs(fitted.peak_flops_f32 - pf_true) / pf_true < 0.01
    # predicted_seconds re-prices with the max-form model: bounded above by
    # the sum-form measurement it was fit to.
    for s in samples:
        assert predicted_seconds(s.per_mode, fitted) <= s.measured_s * 1.001


def test_sweep_sample_counts_match_unit_spec():
    """sweep_sample's stored counts are exactly the unit-spec PMS estimates
    of the workspace it timed."""
    st = frostt_like("tiny")
    s = sweep_sample(st, 8, MemoryControllerConfig(), reps=1)
    assert s.measured_s > 0
    assert s.mem_bytes > 0 and s.flops > 0
    assert len(s.per_mode) == st.nmodes


# ---------------------------------------------------------------------------
# Cache robustness
# ---------------------------------------------------------------------------


def _cfg_variants():
    return (
        MemoryControllerConfig(),
        MemoryControllerConfig(
            cache=CacheEngineConfig(tile_i=512, tile_j=128, tile_k=256,
                                    resident_tiles=2),
            dma=DMAEngineConfig(blk=512, buffers=3),
        ),
    )


def test_spec_and_config_round_trip_bit_for_bit():
    spec = dataclasses.replace(TPUSpec(), hbm_bw=123.456e9,
                               peak_flops_f32=7.89e12)
    assert spec_from_dict(json.loads(json.dumps(spec_to_dict(spec)))) == spec
    for cfg in _cfg_variants():
        rt = config_from_dict(json.loads(json.dumps(config_to_dict(cfg))))
        assert rt == cfg


def test_cache_round_trip_on_disk():
    cache = AutotuneCache()
    spec = dataclasses.replace(TPUSpec(), hbm_bw=42e9)
    cache.put_spec("cpu", spec, note="test")
    assert cache.get_spec("cpu") == spec
    cfg = _cfg_variants()[1]
    key = config_key("mttkrp", "f" * 12, 0, 8, backend="cpu", spec=spec)
    cache.put_config(key, cfg)
    assert cache.get_config(key) == cfg
    # A second process would read the same file: a fresh handle agrees.
    assert AutotuneCache().get_spec("cpu") == spec
    assert AutotuneCache().get_config(key) == cfg


@pytest.mark.parametrize("payload", [
    "",                                    # empty file
    "{not json",                           # invalid JSON
    '{"schema_version": 1, "specs": {}, "configs"',  # truncated
    '"a bare string"',                     # valid JSON, wrong shape
    '{"schema_version": 9999, "specs": {}, "configs": {}}',  # version bump
    '{"schema_version": 1, "specs": [], "configs": {}}',     # bad section
])
def test_corrupt_cache_degrades_to_clean_miss(payload):
    """Any defective on-disk state reads as empty: get_* return None, a
    cached_config falls through to the search, and the next write repairs
    the file — never a crash."""
    path = cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(payload)
    cache = AutotuneCache()
    assert cache.get_spec("cpu") is None
    key = config_key("mttkrp", "a" * 12, 0, 8, backend="cpu", spec=TPUSpec())
    assert cache.get_config(key) is None
    ran = []
    cfg = cached_config("mttkrp", "a" * 12, 0, 8, TPUSpec(),
                        lambda: ran.append(1) or MemoryControllerConfig())
    assert ran == [1] and cfg == MemoryControllerConfig()
    # The miss-path write-back replaced the defective file with a valid one.
    assert json.loads(path.read_text())["schema_version"] == SCHEMA_VERSION
    assert cache.get_config(key) == MemoryControllerConfig()


def test_unknown_entry_fields_read_as_miss():
    """An entry written by a *future* code version (extra fields) is a miss,
    not a crash and not a silently misread config."""
    cache = AutotuneCache()
    cache.put_spec("cpu", TPUSpec())
    data = cache.load()
    data["specs"]["cpu"]["spec"]["new_field_from_the_future"] = 1.0
    key = config_key("mttkrp", "b" * 12, 0, 8, backend="cpu", spec=TPUSpec())
    data["configs"][key] = {"cfg": {"cache": {}, "dma": {}, "remapper": {},
                                    "extra_engine": {}}}
    cache._write(data)
    assert cache.get_spec("cpu") is None
    assert cache.get_config(key) is None


def test_keys_never_collide_across_backend_kind_rank_spec_shards():
    fp = "c" * 12
    spec, spec2 = TPUSpec(), dataclasses.replace(TPUSpec(), hbm_bw=1e9)
    keys = [
        config_key("mttkrp", fp, 0, 8, backend="cpu", spec=spec),
        config_key("ttmc", fp, 0, 8, backend="cpu", spec=spec),
        config_key("tt", fp, 0, 8, backend="cpu", spec=spec),
        config_key("mttkrp", fp, 0, 8, backend="tpu", spec=spec),
        config_key("mttkrp", fp, 1, 8, backend="cpu", spec=spec),
        config_key("mttkrp", fp, 0, 16, backend="cpu", spec=spec),
        config_key("mttkrp", fp, 0, (8, 8, 8), backend="cpu", spec=spec),
        config_key("mttkrp", fp, 0, 8, backend="cpu", spec=spec2),
        config_key("mttkrp", fp, 0, 8, backend="cpu", spec=spec, nshards=2),
        config_key("mttkrp", fp, 0, 8, backend="cpu", spec=spec, nshards=4),
        config_key("mttkrp", "d" * 12, 0, 8, backend="cpu", spec=spec),
    ]
    assert len(set(keys)) == len(keys)
    # rank payloads that differ only in type must not alias either
    assert config_key("tt", fp, 0, (4, 4), backend="cpu", spec=spec) != \
        config_key("tt", fp, 0, "(4, 4)", backend="cpu", spec=spec)


def test_concurrent_writers_keep_file_valid():
    """N threads hammering put_config interleave arbitrarily, but the atomic
    rename means the file is always complete, valid JSON and every writer's
    entry survives (distinct keys, last-writer-wins per key)."""
    cache = AutotuneCache()
    spec = TPUSpec()
    errors = []

    def writer(i):
        try:
            for j in range(5):
                key = config_key("mttkrp", f"{i:012d}", j, 8,
                                 backend="cpu", spec=spec)
                cache.put_config(key, MemoryControllerConfig())
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    data = json.loads(cache_path().read_text())  # parses == never torn
    assert len(data["configs"]) == 8 * 5
    for i in range(8):
        for j in range(5):
            key = config_key("mttkrp", f"{i:012d}", j, 8,
                             backend="cpu", spec=spec)
            assert cache.get_config(key) == MemoryControllerConfig()


def test_cached_config_hit_miss_metrics():
    ran = []

    def search():
        ran.append(1)
        return MemoryControllerConfig()

    cfg1 = cached_config("mttkrp", "e" * 12, 0, 8, TPUSpec(), search)
    cfg2 = cached_config("mttkrp", "e" * 12, 0, 8, TPUSpec(), search)
    assert cfg1 == cfg2 and ran == [1]
    counters = _counters()
    assert counters.get("autotune_cache.misses{kind=mttkrp}") == 1
    assert counters.get("autotune_cache.hits{kind=mttkrp}") == 1


def test_resolve_spec_contract():
    assert resolve_spec("default") == TPUSpec()
    custom = dataclasses.replace(TPUSpec(), hbm_bw=1.0)
    assert resolve_spec(custom) is custom
    with pytest.raises(ValueError, match="unknown spec"):
        resolve_spec("warp-speed")
    # Cold cache without auto-calibration is an explicit, actionable error.
    with pytest.raises(ValueError, match="no fitted spec"):
        resolve_spec("measured", calibrate_on_miss=False)
    # A stored spec resolves without calibrating.
    stored = dataclasses.replace(TPUSpec(), hbm_bw=9.9e9)
    AutotuneCache().put_spec(current_backend(), stored)
    assert resolve_spec("measured", calibrate_on_miss=False) == stored


# ---------------------------------------------------------------------------
# Warm-cache decompose parity (cp / tucker / tt)
# ---------------------------------------------------------------------------


def _tree_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


@pytest.mark.parametrize("format,rank,state_arrays", [
    ("cp", 4, lambda s: tuple(s.factors)),
    ("tucker", (3, 3, 3), lambda s: tuple(s.factors) + (s.core,)),
    ("tt", (3, 3), lambda s: tuple(s.cores)),
])
def test_decompose_cached_warm_parity_zero_search(format, rank, state_arrays):
    """The ISSUE's acceptance: a warm `auto_tune="cached"` decompose is
    bit-for-bit the fresh `auto_tune=True` search path, and the hit
    evaluates ZERO search configs (obs.metrics)."""
    from repro.api import decompose

    st = frostt_like("tiny")
    fresh = decompose(st, rank, format=format, iters=2, auto_tune=True)
    cold = decompose(st, rank, format=format, iters=2, auto_tune="cached")
    metrics.reset()
    warm = decompose(st, rank, format=format, iters=2, auto_tune="cached")
    counters = _counters()
    assert not any(k.startswith("pms.configs_evaluated") for k in counters), counters
    assert not any(k.startswith("pms.searches") for k in counters), counters
    hits = [v for k, v in counters.items()
            if k.startswith("autotune_cache.hits")]
    assert sum(hits) == st.nmodes
    assert _tree_equal(state_arrays(fresh), state_arrays(cold))
    assert _tree_equal(state_arrays(fresh), state_arrays(warm))
    assert fresh.fit_history == warm.fit_history


def test_decompose_rejects_bad_auto_tune():
    from repro.api import decompose

    with pytest.raises(ValueError, match="auto_tune"):
        decompose(frostt_like("tiny"), 4, auto_tune="always")


def test_recalibration_invalidates_stale_winners():
    """The spec fingerprint is part of the config key: a recalibration that
    moves the constants must re-search, not serve a winner tuned for
    different hardware."""
    ran = []

    def search():
        ran.append(1)
        return MemoryControllerConfig()

    spec_a = TPUSpec()
    spec_b = dataclasses.replace(TPUSpec(), hbm_bw=1e9)
    cached_config("mttkrp", "f" * 12, 0, 8, spec_a, search)
    cached_config("mttkrp", "f" * 12, 0, 8, spec_b, search)
    assert ran == [1, 1]


# ---------------------------------------------------------------------------
# ISSUE drift fix: roofline constants pinned to TPUSpec
# ---------------------------------------------------------------------------


def test_roofline_constants_match_tpuspec():
    from benchmarks import roofline

    spec = TPUSpec()
    assert roofline.PEAK_FLOPS == spec.peak_flops
    assert roofline.HBM_BW == spec.hbm_bw
    assert roofline.ICI_BW == spec.ici_bw_per_link
    assert roofline.HBM_BYTES == spec.hbm_bytes
