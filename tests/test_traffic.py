"""Paper Table 1 + Sec. 3.1 analytical claims, validated against measured
access counts."""
import numpy as np
import pytest

from repro.core.coo import synthetic_tensor
from repro.core.hypergraph import (
    approach1_traffic,
    approach2_traffic,
    remap_overhead,
    stats,
)


def test_table1_formulas(tiny_tensor):
    """Exact Table 1 element counts."""
    st_t, R = tiny_tensor, 16
    t1 = approach1_traffic(st_t, 0, R)
    t2 = approach2_traffic(st_t, 0, R, in_mode=1)
    T, N = st_t.nnz, st_t.nmodes
    assert t1.total_elems == T + (N - 1) * T * R + st_t.shape[0] * R
    assert t2.total_elems == T + N * T * R + st_t.shape[1] * R + T * R
    assert t1.partial_sum_elems == 0
    assert t2.partial_sum_elems == T * R
    # identical compute (paper: N*|T|*R per mode)
    assert t1.compute_ops == t2.compute_ops == N * T * R


def test_approach1_always_less_traffic(tiny_tensor, tensor4d, tensor5d):
    """Approach 1 strictly beats Approach 2 whenever |T| dominates I_out
    (real sparse tensors; the paper's premise)."""
    for st_t in (tiny_tensor, tensor4d, tensor5d):
        for mode in range(st_t.nmodes):
            for r in (8, 16, 32, 64):
                a1 = approach1_traffic(st_t, mode, r).total_elems
                a2 = approach2_traffic(st_t, mode, r).total_elems
                assert a1 < a2


@pytest.mark.parametrize("n_modes,rank", [(3, 16), (4, 16), (5, 16), (3, 64), (5, 64)])
def test_remap_overhead_below_6pct(n_modes, rank):
    """Sec. 3.1: 2|T| / (|T| + (N-1)|T|R + I_out*R) ~< 6% for N=3-5, R=16-64.
    (The paper rounds: the worst case N=3, R=16 is exactly 2/33 = 6.06%.)"""
    shape = tuple([200] * n_modes)
    st_t = synthetic_tensor(shape, 20_000, seed=0, skew=0.5)
    ov = remap_overhead(st_t, 0, rank)
    assert ov < 0.0607
    # and matches the paper's closed-form approximation within 10% rel.
    approx = 2.0 / (1.0 + (n_modes - 1) * rank)
    assert abs(ov - approx) / approx < 0.1


def test_remap_overhead_formula_exact(tiny_tensor):
    t1 = approach1_traffic(tiny_tensor, 0, 16)
    assert remap_overhead(tiny_tensor, 0, 16) == pytest.approx(
        2 * tiny_tensor.nnz / t1.total_elems
    )


def test_hypergraph_stats(tiny_tensor):
    hs = stats(tiny_tensor)
    assert hs.nnz == tiny_tensor.nnz
    assert hs.nmodes == 3
    for m in range(3):
        h = tiny_tensor.mode_histogram(m)
        assert hs.degree_max[m] == h.max()
        assert hs.occupied_frac[m] == pytest.approx((h > 0).mean(), rel=1e-6)
    # zipf skew should show up as cv > 0.5 on a skewed tensor
    assert max(hs.degree_cv) > 0.5
