"""Performance Model Simulator (paper Sec. 5.3): fit constraint, search
ordering, and exact-vs-analytic agreement."""
import numpy as np
import pytest

from repro.core.memctrl import (
    CacheEngineConfig,
    DMAEngineConfig,
    MemoryControllerConfig,
    TPUSpec,
)
from repro.core.pms import (
    predict_analytic,
    predict_from_plan,
    predict_ttmc,
    predict_ttmc_analytic,
    search,
)
from repro.core.remap import plan_blocks
from repro.core.hypergraph import stats


def test_vmem_model_counts_all_engines():
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=512, tile_k=128),
        dma=DMAEngineConfig(blk=256, buffers=2),
    )
    rp = 128
    want = 2 * ((256 + 512 + 128) * rp * 4 + 256 * (4 + 12))
    assert cfg.vmem_bytes(rp) == want


def test_search_respects_vmem_budget(small_tensor):
    spec = TPUSpec()
    res = search(small_tensor, 0, 64, spec=spec, top_k=50)
    assert res, "search returned nothing"
    for e in res:
        assert e.vmem_bytes <= spec.vmem_bytes * spec.vmem_usable_frac
    # sorted by predicted total time
    times = [e.t_total for e in res]
    assert times == sorted(times)


def test_search_excludes_oversized_configs(small_tensor):
    """A tile choice that cannot fit VMEM must never be returned."""
    res = search(
        small_tensor, 0, 2048,  # R_pad 2048 x 8192-row tiles >> 64 MiB budget
        tile_choices=(8192,), blk_choices=(1024,), top_k=10,
    )
    assert res == []


def test_exact_prediction_uses_measured_fills(small_tensor):
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=256, tile_k=256),
        dma=DMAEngineConfig(blk=256),
    )
    plan = plan_blocks(small_tensor, 0, tile_i=256, tile_j=256, tile_k=256, blk=256)
    est = predict_from_plan(plan, 16, cfg)
    fills = plan.tile_fills()
    spec = TPUSpec()
    rp = 128
    assert est.t_factor == pytest.approx(
        (fills["B"] * 256 + fills["C"] * 256) * rp * 4 / spec.hbm_bw
    )
    assert est.t_out == pytest.approx(fills["A"] * 256 * rp * 4 / spec.hbm_bw)
    assert est.nblocks == plan.nblocks
    assert est.bottleneck in ("memory", "compute")


def test_analytic_within_factor_of_exact(small_tensor):
    """The occupancy model should land within ~3x of the measured layout for
    a moderately skewed tensor (it is intentionally conservative)."""
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=256, tile_k=256),
        dma=DMAEngineConfig(blk=256),
    )
    plan = plan_blocks(small_tensor, 0, tile_i=256, tile_j=256, tile_k=256, blk=256)
    exact = predict_from_plan(plan, 16, cfg)
    approx = predict_analytic(stats(small_tensor), 0, 16, cfg)
    assert approx.t_total / exact.t_total < 3.0
    assert exact.t_total / approx.t_total < 3.0


def test_vmem_model_ttmc_counts_core_tile():
    """The TTMc VMEM model pays the core-tensor slice width (Pp lanes) on
    the accumulator tile and each input factor's own lane padding."""
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=512, tile_k=128),
        dma=DMAEngineConfig(blk=256, buffers=2),
    )
    pp, in_rps = 256, (128, 128)
    want = 2 * ((256 * 256 + (512 + 128) * 128) * 4 + 256 * (4 + 12))
    assert cfg.vmem_bytes_ttmc(pp, in_rps) == want
    # the kron widening makes TTMc strictly hungrier than MTTKRP at equal rank
    assert cfg.vmem_bytes_ttmc(256, (128, 128)) > cfg.vmem_bytes(128)


def test_predict_ttmc_uses_measured_fills(small_tensor):
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=256, tile_k=256),
        dma=DMAEngineConfig(blk=256),
    )
    plan = plan_blocks(small_tensor, 0, tile_i=256, tile_j=256, tile_k=256, blk=256)
    core_ranks = (8, 8, 8)
    est = predict_ttmc(plan, core_ranks, cfg)
    fills = plan.tile_fills()
    spec = TPUSpec()
    # input factors each pad their own rank to 128; the output pays Pp=128
    assert est.t_factor == pytest.approx(
        (fills["B"] * 256 + fills["C"] * 256) * 128 * 4 / spec.hbm_bw
    )
    assert est.t_out == pytest.approx(fills["A"] * 256 * 128 * 4 / spec.hbm_bw)
    assert est.nblocks == plan.nblocks
    # stream term identical to the MTTKRP model: the layout is shared
    assert est.t_stream == pytest.approx(predict_from_plan(plan, 8, cfg).t_stream)


def test_ttmc_analytic_within_factor_of_exact(small_tensor):
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=256, tile_k=256),
        dma=DMAEngineConfig(blk=256),
    )
    plan = plan_blocks(small_tensor, 0, tile_i=256, tile_j=256, tile_k=256, blk=256)
    exact = predict_ttmc(plan, (8, 8, 8), cfg)
    approx = predict_ttmc_analytic(stats(small_tensor), 0, (8, 8, 8), cfg)
    assert approx.t_total / exact.t_total < 3.0
    assert exact.t_total / approx.t_total < 3.0


def test_search_kernel_ttmc(small_tensor):
    """The per-kernel search: TTMc candidates respect the TTMc VMEM fit, and
    a core-rank tuple whose Kronecker width blows the budget prunes configs
    that MTTKRP at the same per-mode rank would keep."""
    spec = TPUSpec()
    res = search(small_tensor, 0, 16, kernel="ttmc", core_ranks=(16, 16, 16), top_k=20)
    assert res, "ttmc search returned nothing"
    for e in res:
        assert e.vmem_bytes <= spec.vmem_bytes * spec.vmem_usable_frac
    times = [e.t_total for e in res]
    assert times == sorted(times)
    # kron width 64*64=4096 lanes on an 8192-row output tile >> budget
    wide = search(
        small_tensor, 0, 16, kernel="ttmc", core_ranks=(64, 64, 64),
        tile_choices=(8192,), blk_choices=(1024,), top_k=10,
    )
    assert wide == []


def test_search_validates_kernel_args(small_tensor):
    with pytest.raises(ValueError, match="kernel"):
        search(small_tensor, 0, 16, kernel="ttm")
    with pytest.raises(ValueError, match="core_ranks"):
        search(small_tensor, 0, 16, kernel="ttmc")
    with pytest.raises(ValueError, match="N-tuple"):
        # natural mistake: the N-1 input ranks instead of the full N-tuple
        search(small_tensor, 0, 16, kernel="ttmc", core_ranks=(8, 8))


def test_mttkrp_is_memory_bound_at_paper_scale(small_tensor):
    """The paper's premise: spMTTKRP on real tensors is memory-bound.  At
    the ALGORITHMIC level (Table 1 traffic vs N*|T|*R MACs on v5e numbers)
    the memory term dominates by orders of magnitude.  (Note: the *kernel*
    may still become MXU-compute-bound because the one-hot segment matmul
    trades FLOPs for streaming — that trade is measured in bench_kernel.)"""
    from repro.core.hypergraph import approach1_traffic

    spec = TPUSpec()
    t = approach1_traffic(small_tensor, 0, 16)
    t_mem = t.bytes() / spec.hbm_bw
    t_cmp = 2 * t.compute_ops / spec.peak_flops
    assert t_mem > 10 * t_cmp
