"""Performance Model Simulator (paper Sec. 5.3): fit constraint, search
ordering, and exact-vs-analytic agreement."""
import numpy as np
import pytest

from repro.core.memctrl import (
    CacheEngineConfig,
    DMAEngineConfig,
    MemoryControllerConfig,
    TPUSpec,
)
from repro.core.pms import predict_analytic, predict_from_plan, search
from repro.core.remap import plan_blocks
from repro.core.hypergraph import stats


def test_vmem_model_counts_all_engines():
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=512, tile_k=128),
        dma=DMAEngineConfig(blk=256, buffers=2),
    )
    rp = 128
    want = 2 * ((256 + 512 + 128) * rp * 4 + 256 * (4 + 12))
    assert cfg.vmem_bytes(rp) == want


def test_search_respects_vmem_budget(small_tensor):
    spec = TPUSpec()
    res = search(small_tensor, 0, 64, spec=spec, top_k=50)
    assert res, "search returned nothing"
    for e in res:
        assert e.vmem_bytes <= spec.vmem_bytes * spec.vmem_usable_frac
    # sorted by predicted total time
    times = [e.t_total for e in res]
    assert times == sorted(times)


def test_search_excludes_oversized_configs(small_tensor):
    """A tile choice that cannot fit VMEM must never be returned."""
    res = search(
        small_tensor, 0, 2048,  # R_pad 2048 x 8192-row tiles >> 64 MiB budget
        tile_choices=(8192,), blk_choices=(1024,), top_k=10,
    )
    assert res == []


def test_exact_prediction_uses_measured_fills(small_tensor):
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=256, tile_k=256),
        dma=DMAEngineConfig(blk=256),
    )
    plan = plan_blocks(small_tensor, 0, tile_i=256, tile_j=256, tile_k=256, blk=256)
    est = predict_from_plan(plan, 16, cfg)
    fills = plan.tile_fills()
    spec = TPUSpec()
    rp = 128
    assert est.t_factor == pytest.approx(
        (fills["B"] * 256 + fills["C"] * 256) * rp * 4 / spec.hbm_bw
    )
    assert est.t_out == pytest.approx(fills["A"] * 256 * rp * 4 / spec.hbm_bw)
    assert est.nblocks == plan.nblocks
    assert est.bottleneck in ("memory", "compute")


def test_analytic_within_factor_of_exact(small_tensor):
    """The occupancy model should land within ~3x of the measured layout for
    a moderately skewed tensor (it is intentionally conservative)."""
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=256, tile_j=256, tile_k=256),
        dma=DMAEngineConfig(blk=256),
    )
    plan = plan_blocks(small_tensor, 0, tile_i=256, tile_j=256, tile_k=256, blk=256)
    exact = predict_from_plan(plan, 16, cfg)
    approx = predict_analytic(stats(small_tensor), 0, 16, cfg)
    assert approx.t_total / exact.t_total < 3.0
    assert exact.t_total / approx.t_total < 3.0


def test_mttkrp_is_memory_bound_at_paper_scale(small_tensor):
    """The paper's premise: spMTTKRP on real tensors is memory-bound.  At
    the ALGORITHMIC level (Table 1 traffic vs N*|T|*R MACs on v5e numbers)
    the memory term dominates by orders of magnitude.  (Note: the *kernel*
    may still become MXU-compute-bound because the one-hot segment matmul
    trades FLOPs for streaming — that trade is measured in bench_kernel.)"""
    from repro.core.hypergraph import approach1_traffic

    spec = TPUSpec()
    t = approach1_traffic(small_tensor, 0, 16)
    t_mem = t.bytes() / spec.hbm_bw
    t_cmp = 2 * t.compute_ops / spec.peak_flops
    assert t_mem > 10 * t_cmp
