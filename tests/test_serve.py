"""Serving engine: generation loop, cache specs, greedy consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve.engine import cache_specs, generate, make_decode_step, make_prefill_step


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m", "jamba-v0.1-52b"])
def test_generate_matches_stepwise_forward(arch, key):
    """Greedy generate() == argmax over repeated full forwards (teacher
    forcing with its own outputs)."""
    cfg = get_config(arch).reduced()
    B, S, NEW = 1, 12, 4
    params = T.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)}
    out = generate(params, batch, cfg, max_new_tokens=NEW, attn_chunk=4)
    assert out.shape == (B, NEW)

    toks = batch["tokens"]
    want = []
    for _ in range(NEW):
        h, _ = T.forward_hidden(params, {**batch, "tokens": toks}, cfg, attn_chunk=1)
        nxt = jnp.argmax(T.lm_logits(params, h[:, -1:], cfg)[:, 0], -1).astype(jnp.int32)
        want.append(nxt)
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(jnp.stack(want, 1)[0]))


def test_cache_specs_match_real_caches(key):
    cfg = get_config("jamba-v0.1-52b").reduced()
    specs = cache_specs(cfg, batch=2, cache_len=32)
    real = T.init_caches(cfg, batch=2, cache_len=32)
    sl, rl = jax.tree.leaves(specs), jax.tree.leaves(real)
    assert len(sl) == len(rl)
    for s, r in zip(sl, rl):
        assert s.shape == r.shape and s.dtype == r.dtype


def test_decode_step_builder(key):
    cfg = get_config("qwen3-0.6b").reduced()
    B, S = 2, 8
    params = T.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)}
    prefill = make_prefill_step(cfg, cache_len=S + 2, attn_chunk=4)
    decode = make_decode_step(cfg)
    logits, caches = prefill(params, batch)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    tok2, logits2, caches = decode(params, nxt, jnp.full((B,), S, jnp.int32), caches, batch)
    assert tok2.shape == (B, 1) and logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()
