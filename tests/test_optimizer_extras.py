"""Optimizer variants: factored second moment, sequential/sliced updates,
state dtype — numerics and convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, opt_pspecs


def _train_quadratic(cfg, steps=300, shape=(4, 6)):
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    params = {"w": jnp.zeros(shape)}
    state = adamw_init(params, cfg)
    for _ in range(steps):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(params, g, state, cfg)
    return float(jnp.abs(params["w"] - target).max())


def test_factored_v_converges():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=300, weight_decay=0.0, factored_v=True)
    assert _train_quadratic(cfg) < 5e-2


def test_factored_v_state_is_small():
    cfg = AdamWConfig(factored_v=True)
    params = {"w": jnp.zeros((64, 8, 512, 1024))}
    st = adamw_init(params, cfg)
    v = st["v"]["w"]
    assert set(v) == {"r", "c"}
    assert v["r"].shape == (64, 8, 512)
    assert v["c"].shape == (64, 8, 1024)
    full = 64 * 8 * 512 * 1024
    assert (v["r"].size + v["c"].size) < full / 300


def test_factored_vs_full_similar_trajectory():
    """On a well-conditioned problem the factored approximation tracks full
    Adam closely (it is exact when |g| is rank-one)."""
    cfg_full = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=200, weight_decay=0.0)
    cfg_fact = AdamWConfig(lr=0.05, warmup_steps=0, total_steps=200, weight_decay=0.0, factored_v=True)
    e1 = _train_quadratic(cfg_full, steps=200)
    e2 = _train_quadratic(cfg_fact, steps=200)
    assert abs(e1 - e2) < 0.1


def test_update_slices_identical():
    cfg_a = AdamWConfig(update_slices=1, warmup_steps=0)
    cfg_b = AdamWConfig(update_slices=4, warmup_steps=0)
    key = jax.random.PRNGKey(0)
    # big enough to trip the slicing threshold (>= 2^26 elements)
    params = {"w": jax.random.normal(key, (8, 1024, 8192))}
    grads = jax.tree.map(lambda p: p * 0.01, params)
    sa = adamw_init(params, cfg_a)
    sb = adamw_init(params, cfg_b)
    pa, _, _ = adamw_update(params, grads, sa, cfg_a)
    pb, _, _ = adamw_update(params, grads, sb, cfg_b)
    np.testing.assert_allclose(np.asarray(pa["w"]), np.asarray(pb["w"]), rtol=1e-6)


def test_bf16_state_converges():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=300, weight_decay=0.0, state_dtype="bfloat16")
    assert _train_quadratic(cfg) < 5e-2


def test_opt_pspecs_structure_matches_state():
    from jax.sharding import PartitionSpec as P

    cfg = AdamWConfig(factored_v=True)
    params = {"w": jnp.zeros((4, 8, 16)), "b": jnp.zeros((16,))}
    state = adamw_init(params, cfg)
    specs = opt_pspecs(params, {"w": P(None, "data", "model"), "b": P(None)}, cfg)
    # identical tree structure (required for jit in_shardings)
    a = jax.tree_util.tree_structure(
        {k: state[k] for k in ("m", "v")}, is_leaf=lambda x: isinstance(x, jax.Array)
    )
    b = jax.tree_util.tree_structure(
        {k: specs[k] for k in ("m", "v")}, is_leaf=lambda x: isinstance(x, P)
    )
    assert a == b
    assert specs["v"]["w"]["r"] == P(None, "data")
    assert specs["v"]["w"]["c"] == P(None, "model")
