"""Benchmark-trajectory schema (repro/bench.py): the CI smoke job validates
the freshly emitted BENCH_kernel.json with exactly these helpers, so schema
drift must fail loudly here first."""
import json
from pathlib import Path

import pytest

from repro.bench import make_report, result_record, validate_file, validate_report, write_report


def _results():
    return [
        result_record("plan_build_blk32", "medium", "speedup_x", 18.3, "x"),
        result_record("als_iter_pallas", "small", "iter_s", 4.2, "s"),
        result_record("plan_cache", "tiny", "hits", 2, "count"),
    ]


def test_make_report_valid():
    report = make_report(_results())
    validate_report(report)  # must not raise
    assert isinstance(report["commit"], str) and report["commit"]
    assert "T" in report["timestamp"]
    assert len(report["results"]) == 3


def test_result_record_rejects_bad_values():
    with pytest.raises(ValueError, match="value"):
        result_record("n", "p", "m", float("nan"), "s")
    with pytest.raises(ValueError, match="value"):
        result_record("n", "p", "m", float("inf"), "s")


@pytest.mark.parametrize(
    "mutate,match",
    [
        (lambda r: r.pop("commit"), "commit"),
        (lambda r: r.update(commit=""), "commit"),
        (lambda r: r.update(timestamp=7), "timestamp"),
        (lambda r: r.update(results={}), "list"),
        (lambda r: r.update(results=[]), "empty"),
        (lambda r: r["results"].append({"name": "x"}), "missing field"),
        (lambda r: r["results"][0].pop("unit"), "unit"),
        (lambda r: r["results"][0].update(value="fast"), "number"),
        (lambda r: r["results"][0].update(extra=1), "unknown"),
    ],
)
def test_validate_report_rejects(mutate, match):
    report = make_report(_results())
    mutate(report)
    with pytest.raises(ValueError, match=match):
        validate_report(report)


def test_write_and_validate_file_roundtrip(tmp_path):
    path = tmp_path / "BENCH_kernel.json"
    report = write_report(path, _results())
    loaded = validate_file(path)
    assert loaded == report
    assert json.loads(path.read_text())["results"][0]["name"] == "plan_build_blk32"


def test_validate_file_rejects_corrupt(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"commit": "abc", "results": []}))
    with pytest.raises(ValueError):
        validate_file(path)


def test_validate_file_expect_commit(tmp_path):
    """The CI freshness check: a trajectory file whose commit field does not
    match the expected sha is a stale artifact and must fail validation."""
    path = tmp_path / "BENCH_kernel.json"
    write_report(path, _results())
    report = json.loads(path.read_text())
    validate_file(path, expect_commit=report["commit"])  # matching sha passes
    with pytest.raises(ValueError, match="stale"):
        validate_file(path, expect_commit="f" * 40)


def test_validate_file_expect_commit_head(tmp_path):
    """expect_commit='HEAD' resolves the checkout next to the file: inside a
    repo a fresh report passes; outside any repo the sentinel itself errors
    (there is nothing meaningful to compare against)."""
    from repro.bench import git_commit

    here = Path(__file__).resolve().parent
    path = here / "_bench_expect_commit_tmp.json"
    try:
        write_report(path, _results())
        if git_commit(here) != "unknown":
            validate_file(path, expect_commit="HEAD")
            stale = json.loads(path.read_text())
            stale["commit"] = "0" * 40
            path.write_text(json.dumps(stale))
            with pytest.raises(ValueError, match="stale"):
                validate_file(path, expect_commit="HEAD")
    finally:
        path.unlink(missing_ok=True)

    outside = tmp_path / "r.json"
    write_report(outside, _results())
    if git_commit(tmp_path) == "unknown":
        with pytest.raises(ValueError, match="HEAD"):
            validate_file(outside, expect_commit="HEAD")
