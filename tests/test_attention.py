"""Attention: chunked-causal == dense-masked; GQA; rope; decode == train."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    causal_attention,
    decode_attention,
    full_attention,
)
from repro.models.layers import apply_rope, rope_angles


def _qkv(key, B, S, H, KVH, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    return q, k, v


@pytest.mark.parametrize("chunk", [1, 4, 16, 64])
@pytest.mark.parametrize("H,KVH", [(4, 4), (8, 2), (6, 1)])
def test_chunked_causal_equals_masked_full(key, chunk, H, KVH):
    B, S, hd = 2, 64, 16
    q, k, v = _qkv(key, B, S, H, KVH, hd)
    got = causal_attention(q, k, v, chunk=chunk)
    mask = jnp.tril(jnp.ones((S, S), bool))
    want = full_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_decode_equals_last_position(key):
    """decode_attention over a cache == causal attention's last row."""
    B, S, H, KVH, hd = 2, 32, 8, 2, 16
    q, k, v = _qkv(key, B, S, H, KVH, hd)
    full = causal_attention(q, k, v, chunk=8)
    pos = jnp.full((B,), S - 1, jnp.int32)
    dec = decode_attention(q[:, -1:], k, v, pos)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_decode_masks_future(key):
    """Cache positions beyond pos must not contribute."""
    B, S, H, KVH, hd = 1, 16, 2, 2, 8
    q, k, v = _qkv(key, B, S, H, KVH, hd)
    pos = jnp.array([7], jnp.int32)
    base = decode_attention(q[:, 7:8], k, v, pos)
    k2 = k.at[:, 8:].set(1e3)  # poison the future
    v2 = v.at[:, 8:].set(-1e3)
    poisoned = decode_attention(q[:, 7:8], k2, v2, pos)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned), rtol=1e-5)


def test_gqa_grouping_semantics(key):
    """GQA == MHA with KV heads repeated."""
    B, S, H, KVH, hd = 1, 16, 8, 2, 8
    q, k, v = _qkv(key, B, S, H, KVH, hd)
    got = causal_attention(q, k, v, chunk=4)
    k_rep = jnp.repeat(k, H // KVH, axis=2)
    v_rep = jnp.repeat(v, H // KVH, axis=2)
    want = causal_attention(q, k_rep, v_rep, chunk=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_rope_properties(key):
    """Relative-position property: <rope(q,m), rope(k,n)> depends on m-n."""
    hd = 32
    q = jax.random.normal(key, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def dot_at(m, n):
        cm, sm = rope_angles(jnp.array([m]), hd, 10_000.0)
        cn, sn = rope_angles(jnp.array([n]), hd, 10_000.0)
        qr = apply_rope(q, cm[None], sm[None])
        kr = apply_rope(k, cn[None], sn[None])
        return float(jnp.sum(qr * kr))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)
    assert dot_at(5, 5) == pytest.approx(float(jnp.sum(q * k)), rel=1e-4)
    # norm preservation
    cm, sm = rope_angles(jnp.array([9]), hd, 10_000.0)
    qr = apply_rope(q, cm[None], sm[None])
    assert float(jnp.linalg.norm(qr)) == pytest.approx(float(jnp.linalg.norm(q)), rel=1e-5)
