"""Tensor-train (repro.tt) on the memory controller: TT-core kernel/oracle
parity, TT-SVD init, pallas-vs-reference TT-ALS fit match on 3/4/5-mode
tensors, exact low-TT-rank recovery, workspace validation contracts, and the
2-device sharded parity subprocess."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

from repro.core.coo import SparseTensor, synthetic_tensor
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.kernels.mttkrp_pallas import pad_factor, rank_padded
from repro.kernels.ops import make_planned_ttcore, tt_auto
from repro.kernels.ref import ttcore_plan_ref, ttcore_ref, ttcore_ref_dense
from repro.tt import (
    PlannedTT,
    TTState,
    core_to_matrix,
    init_tt_cores,
    make_planned_tt,
    tt_als,
    tt_svd,
)
from repro.tt.als import _TT_SVD_DENSE_LIMIT, _validated_tt_ranks

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SMALL_CFG = MemoryControllerConfig(
    cache=CacheEngineConfig(tile_i=16, tile_j=16, tile_k=16),
    dma=DMAEngineConfig(blk=32),
)


def _bond_pairs(tt_ranks, nmodes):
    bounds = (1,) + tuple(tt_ranks) + (1,)
    return [(bounds[k], bounds[k + 1]) for k in range(nmodes)]


def random_cores(shape, tt_ranks, seed=0):
    rng = np.random.default_rng(seed)
    return [
        jnp.asarray(rng.standard_normal((rl, s, rr)), jnp.float32)
        for s, (rl, rr) in zip(shape, _bond_pairs(tt_ranks, len(shape)))
    ]


def low_tt_rank_tensor(shape=(9, 8, 7), tt_ranks=(2, 3), seed=0) -> SparseTensor:
    """Exactly-low-TT-rank tensor with FULL support in COO form (the implicit
    zeros are fitted too, so the recovery test needs every entry)."""
    cores = random_cores(shape, tt_ranks, seed=seed)
    dense = np.asarray(TTState(cores=cores, fit_history=[]).full(), np.float64)
    grids = np.meshgrid(*[np.arange(s) for s in shape], indexing="ij")
    idx = np.stack([g.ravel() for g in grids], axis=1).astype(np.int32)
    return SparseTensor(idx, dense.ravel().astype(np.float32), shape)


# ---------------------------------------------------------------------------
# TT-core oracle + kernel
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    nnz=hst.integers(1, 200),
    base=hst.tuples(hst.integers(4, 16), hst.integers(4, 16), hst.integers(4, 16)),
    extra=hst.sampled_from([(), (7,), (7, 6)]),
    mode_pick=hst.integers(0, 4),
    rank=hst.integers(1, 3),
    seed=hst.integers(0, 99),
)
def test_ttcore_ref_matches_dense_einsum(nnz, base, extra, mode_pick, rank, seed):
    """Property (stub-compatible): the sparse gather/interface-chain TT-core
    oracle equals the densify-and-einsum cross-check on 3/4/5-mode tensors,
    for every output mode and interior bond rank drawn."""
    dims = base + extra
    mode = mode_pick % len(dims)
    st_t = synthetic_tensor(dims, nnz, seed=seed, skew=0.5)
    cores = random_cores(dims, (rank,) * (len(dims) - 1), seed=seed + 1)
    out = ttcore_ref(
        jnp.asarray(st_t.indices),
        jnp.asarray(st_t.values),
        cores,
        mode,
        st_t.shape[mode],
    )
    ref = ttcore_ref_dense(
        st_t.indices,
        st_t.values,
        [np.asarray(c) for c in cores],
        mode,
        st_t.shape[mode],
    )
    rl, rr = _bond_pairs((rank,) * (len(dims) - 1), len(dims))[mode]
    assert out.shape == (st_t.shape[mode], rl * rr)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_ttcore_pallas_all_modes(tiny_tensor, mode):
    """The planned Pallas TT-core kernel (interpret mode) == the jnp oracle
    on every output mode, asymmetric bond ranks to catch (rl, rr) swaps."""
    tt_ranks = (3, 5)
    cores = random_cores(tiny_tensor.shape, tt_ranks, seed=7)
    op = make_planned_ttcore(
        tiny_tensor, mode, tt_ranks, cfg=SMALL_CFG, interpret=True
    )
    mats = [core_to_matrix(c) for c in cores]
    out = op.output(mats, tiny_tensor.shape[mode])
    ref = ttcore_ref(
        jnp.asarray(tiny_tensor.indices),
        jnp.asarray(tiny_tensor.values),
        cores,
        mode,
        tiny_tensor.shape[mode],
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_ttcore_pallas_4d(tensor4d):
    """N-mode kernel: three chained input interfaces on a 4-mode tensor."""
    tt_ranks = (2, 4, 3)
    cores = random_cores(tensor4d.shape, tt_ranks, seed=9)
    for mode in (0, 2, 3):
        op = make_planned_ttcore(
            tensor4d, mode, tt_ranks, cfg=SMALL_CFG, interpret=True
        )
        out = op.output([core_to_matrix(c) for c in cores], tensor4d.shape[mode])
        ref = ttcore_ref(
            jnp.asarray(tensor4d.indices),
            jnp.asarray(tensor4d.values),
            cores,
            mode,
            tensor4d.shape[mode],
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4
        )


def test_ttcore_plan_ref_matches_pallas(tiny_tensor):
    """The BlockPlan-layout oracle reproduces the Pallas output bit-exactly
    in padded space (same gather order, same segment reduction)."""
    tt_ranks = (4, 3)
    cores = random_cores(tiny_tensor.shape, tt_ranks, seed=3)
    op = make_planned_ttcore(tiny_tensor, 1, tt_ranks, cfg=SMALL_CFG, interpret=True)
    p = op.plan
    pads = tuple(
        pad_factor(core_to_matrix(cores[im]), rows, rank_padded(a * b))
        for im, rows, (a, b) in zip(p.in_modes, p.in_rows, op.in_rank_pairs)
    )
    out = op.call_padded(pads)
    ref = ttcore_plan_ref(p, pads, op.in_rank_pairs, op.n_left)
    np.testing.assert_allclose(
        np.asarray(out[:, : op.out_cols]),
        np.asarray(ref[:, : op.out_cols]),
        rtol=1e-5,
        atol=1e-5,
    )


def test_tt_auto_pallas_matches_reference(tiny_tensor):
    """The one-shot dispatcher: pallas == reference for every output mode."""
    cores = random_cores(tiny_tensor.shape, (3, 4), seed=5)
    for mode in range(3):
        out = tt_auto(tiny_tensor, cores, mode, method="pallas", cfg=SMALL_CFG)
        ref = tt_auto(tiny_tensor, cores, mode, method="reference")
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4
        )
    with pytest.raises(ValueError, match="expected 'pallas' or 'reference'"):
        tt_auto(tiny_tensor, cores, 0, method="einsum")


# ---------------------------------------------------------------------------
# TT-SVD init
# ---------------------------------------------------------------------------


def test_tt_svd_exact_at_true_ranks():
    """TT-SVD at the generating bond ranks reconstructs the tensor exactly
    (the unfolding ranks are <= the requested bonds, so no truncation)."""
    st = low_tt_rank_tensor(shape=(9, 8, 7), tt_ranks=(2, 3), seed=1)
    cores = tt_svd(st, (2, 3))
    dense = np.zeros(st.shape, np.float64)
    dense[tuple(st.indices[:, m] for m in range(3))] = st.values
    full = np.asarray(TTState(cores=cores, fit_history=[]).full(), np.float64)
    np.testing.assert_allclose(full, dense, rtol=1e-4, atol=1e-4)


def test_tt_svd_pads_rank_deficient_bonds():
    """Requesting bonds above the unfolding rank zero-pads the cores instead
    of failing — the shapes honour the request, the reconstruction is still
    exact."""
    st = low_tt_rank_tensor(shape=(8, 7, 6), tt_ranks=(2, 2), seed=2)
    cores = tt_svd(st, (5, 5))
    assert [c.shape for c in cores] == [(1, 8, 5), (5, 7, 5), (5, 6, 1)]
    dense = np.zeros(st.shape, np.float64)
    dense[tuple(st.indices[:, m] for m in range(3))] = st.values
    full = np.asarray(TTState(cores=cores, fit_history=[]).full(), np.float64)
    np.testing.assert_allclose(full, dense, rtol=1e-4, atol=1e-4)


def test_tt_svd_dense_guard(small_tensor):
    """prod(shape) past the densification guard is rejected with the
    init='random' hint, and init='auto' silently takes the random path."""
    assert np.prod(small_tensor.shape) > _TT_SVD_DENSE_LIMIT
    with pytest.raises(ValueError, match="use init='random'"):
        tt_svd(small_tensor, (2, 2))
    # init='auto' must not densify: just resolving the init path should work.
    state = tt_als(small_tensor, 2, iters=1, method="reference", init="auto")
    assert len(state.fit_history) == 1


def test_init_tt_cores_left_orthogonal():
    cores = init_tt_cores(jax.random.PRNGKey(0), (10, 9, 8), (3, 4))
    assert [c.shape for c in cores] == [(1, 10, 3), (3, 9, 4), (4, 8, 1)]
    for c in cores[:-1]:
        m = np.asarray(c.reshape(c.shape[0] * c.shape[1], c.shape[2]))
        np.testing.assert_allclose(m.T @ m, np.eye(m.shape[1]), atol=1e-5)


# ---------------------------------------------------------------------------
# rank validation
# ---------------------------------------------------------------------------


def test_validated_tt_ranks_contracts(tiny_tensor):
    assert _validated_tt_ranks(tiny_tensor, 4) == (4, 4)
    assert _validated_tt_ranks(tiny_tensor, (2, 5)) == (2, 5)
    with pytest.raises(ValueError, match="3 entries for a 3-mode tensor"):
        _validated_tt_ranks(tiny_tensor, (2, 2, 2))
    with pytest.raises(ValueError, match="out of range"):
        _validated_tt_ranks(tiny_tensor, (0, 2))
    with pytest.raises(ValueError, match="out of range"):
        # bond 0's bound is min(64, 48*80) = 64
        _validated_tt_ranks(tiny_tensor, (65, 2))


# ---------------------------------------------------------------------------
# TT-ALS: pallas vs reference, recovery, workspace contracts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "fixture,tt_ranks",
    [("tiny_tensor", (4, 4)), ("tensor4d", (3, 3, 3)), ("tensor5d", (2, 2, 2, 2))],
)
def test_tt_als_pallas_matches_reference(request, fixture, tt_ranks):
    """Acceptance: the planned Pallas TT-ALS fit history matches the pure-jnp
    reference to 1e-5 on 3/4/5-mode tensors (single device; the 2-device
    case is the sharded subprocess below)."""
    st = request.getfixturevalue(fixture)
    ref = tt_als(st, tt_ranks, iters=3, method="reference", init="random", seed=0)
    pal = tt_als(
        st, tt_ranks, iters=3, method="pallas", init="random", seed=0, cfg=SMALL_CFG
    )
    np.testing.assert_allclose(pal.fit_history, ref.fit_history, rtol=1e-5, atol=1e-5)
    assert pal.tt_ranks == tuple(tt_ranks)


def test_tt_als_eager_matches_jit_sweep(tiny_tensor):
    """jit_sweep=False (eager per-mode dispatch) is the parity baseline for
    the fused sweep, for both methods."""
    for method in ("pallas", "reference"):
        fused = tt_als(
            tiny_tensor, (3, 3), iters=2, method=method, init="random",
            seed=1, cfg=SMALL_CFG if method == "pallas" else None,
        )
        eager = tt_als(
            tiny_tensor, (3, 3), iters=2, method=method, init="random",
            seed=1, jit_sweep=False,
            cfg=SMALL_CFG if method == "pallas" else None,
        )
        np.testing.assert_allclose(
            eager.fit_history, fused.fit_history, rtol=1e-5, atol=1e-5
        )


def test_tt_als_recovers_low_tt_rank():
    """Exact recovery: an exactly-low-TT-rank tensor (full COO support) is
    fitted to ~1.0 at the generating bond ranks — SVD init lands on the
    solution and ALS keeps it."""
    st = low_tt_rank_tensor(shape=(10, 9, 8), tt_ranks=(2, 3), seed=4)
    state = tt_als(st, (2, 3), iters=3, method="pallas", init="svd", cfg=SMALL_CFG)
    assert state.fit_history[-1] > 0.999


def test_tt_als_monotone_and_tol_exit(tiny_tensor):
    """The fit is (near-)monotone over iterations and tol stops the loop
    early."""
    state = tt_als(
        tiny_tensor, (4, 4), iters=5, method="pallas", init="random", cfg=SMALL_CFG
    )
    f = state.fit_history
    assert all(b >= a - 1e-5 for a, b in zip(f, f[1:]))
    stopped = tt_als(
        tiny_tensor, (4, 4), iters=50, method="pallas", init="random",
        cfg=SMALL_CFG, tol=1e-2,
    )
    assert len(stopped.fit_history) < 50


def test_tt_als_workspace_reuse_and_validation(tiny_tensor):
    """A prebuilt PlannedTT is reused across calls; mismatched geometry or
    class is rejected by the shared check_workspace contract."""
    planned = make_planned_tt(tiny_tensor, (3, 3), cfg=SMALL_CFG, interpret=True)
    assert isinstance(planned, PlannedTT)
    assert planned.plan_bytes() > 0
    a = tt_als(tiny_tensor, (3, 3), iters=2, init="random", planned=planned)
    b = tt_als(tiny_tensor, (3, 3), iters=2, init="random", planned=planned)
    np.testing.assert_allclose(a.fit_history, b.fit_history, rtol=0, atol=0)

    with pytest.raises(ValueError, match="was built for"):
        tt_als(tiny_tensor, (4, 4), iters=1, planned=planned)
    with pytest.raises(ValueError, match="needs a ShardedPlannedTT"):
        tt_als(
            tiny_tensor, (3, 3), iters=1, method="pallas_sharded",
            planned=planned, devices=1,
        )
    with pytest.raises(ValueError, match="silently ignored"):
        tt_als(tiny_tensor, (3, 3), iters=1, method="reference", planned=planned)
    with pytest.raises(ValueError, match="silently ignored"):
        tt_als(tiny_tensor, (3, 3), iters=1, method="pallas", devices=2)
    with pytest.raises(ValueError, match="eager parity baseline"):
        tt_als(
            tiny_tensor, (3, 3), iters=1, method="pallas_sharded",
            devices=1, jit_sweep=False,
        )
    with pytest.raises(ValueError, match="expected 'auto', 'svd' or 'random'"):
        tt_als(tiny_tensor, (3, 3), iters=1, init="qr")
    with pytest.raises(ValueError, match="unknown method"):
        tt_als(tiny_tensor, (3, 3), iters=1, method="hooi")


# ---------------------------------------------------------------------------
# sharded parity (subprocess: the host device count locks at first jax init)
# ---------------------------------------------------------------------------


def _run(code: str, devices: int, timeout: int = 540) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=timeout, cwd=ROOT,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


_TT_PARITY_CODE = """
import jax, numpy as np
from repro.api import decompose
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.core.coo import synthetic_tensor

DEV = 2
assert jax.device_count() == DEV, jax.devices()
cfg = MemoryControllerConfig(cache=CacheEngineConfig(tile_i=16, tile_j=16, tile_k=16),
                             dma=DMAEngineConfig(blk=32))

tensors = {
    3: (synthetic_tensor((64, 48, 80), 2000, seed=0, skew=0.8), (4, 4)),
    4: (synthetic_tensor((40, 32, 48, 24), 1800, seed=2, skew=0.5), (3, 3, 3)),
    5: (synthetic_tensor((20, 25, 30, 15, 18), 1500, seed=3, skew=0.3), (2, 2, 2, 2)),
}
for nmodes, (st, tr) in tensors.items():
    ref = decompose(st, tr, format="tt", iters=2, method="reference", init="random")
    pal = decompose(st, tr, format="tt", iters=2, method="pallas", init="random", cfg=cfg)
    sh = decompose(st, tr, format="tt", iters=2, method="pallas_sharded",
                   devices=DEV, init="random", cfg=cfg)
    np.testing.assert_allclose(pal.fit_history, ref.fit_history, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(sh.fit_history, ref.fit_history, rtol=1e-5, atol=1e-5)
    print(f"TT_MATCH modes={nmodes}")
print("OK")
"""


@pytest.mark.slow
def test_tt_sharded_parity_2_devices():
    """Acceptance: decompose(format='tt') — pallas AND pallas_sharded — match
    the TT reference fit to 1e-5 on 3/4/5-mode tensors under 2 forced host
    devices."""
    out = _run(_TT_PARITY_CODE, devices=2)
    assert out.count("TT_MATCH") == 3
    assert "OK" in out
