"""Sparse Tucker (HOOI) on the memory controller: TTMc kernel/oracle parity,
pallas-vs-reference HOOI fit match, plan amortization, and the kind-keyed
shared plan cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.kernels.ops as ops_mod
from repro.core.coo import SparseTensor, frostt_like, random_factors, synthetic_tensor
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.kernels.mttkrp_pallas import pad_factor, rank_padded
from repro.kernels.ops import (
    make_planned_ttmc,
    mttkrp_auto,
    plan_cache_clear,
    plan_cache_stats,
    tucker_auto,
)
from repro.kernels.ref import ttmc_plan_ref, ttmc_ref, ttmc_ref_dense
from repro.kernels.ttm_pallas import kron_cols
from repro.tucker import init_tucker_factors, make_planned_tucker, tucker_hooi


def low_multilinear_rank_tensor(shape=(10, 9, 8), ranks=(2, 3, 2), seed=0) -> SparseTensor:
    """Exactly-low-multilinear-rank tensor with FULL support in COO form (the
    implicit zeros are fitted too, so the recovery test needs every entry)."""
    rng = np.random.default_rng(seed)
    core = rng.standard_normal(ranks)
    us = [np.linalg.qr(rng.standard_normal((s, r)))[0] for s, r in zip(shape, ranks)]
    dense = np.einsum("abc,ia,jb,kc->ijk", core, *us)
    grids = np.meshgrid(*[np.arange(s) for s in shape], indexing="ij")
    idx = np.stack([g.ravel() for g in grids], axis=1).astype(np.int32)
    return SparseTensor(idx, dense.ravel().astype(np.float32), shape)


# ---------------------------------------------------------------------------
# TTMc oracle + kernel
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    nnz=st.integers(1, 200),
    base=st.tuples(st.integers(4, 20), st.integers(4, 20), st.integers(4, 20)),
    extra=st.sampled_from([(), (7,), (7, 6)]),
    mode=st.integers(0, 2),
    rank=st.integers(1, 4),
    seed=st.integers(0, 99),
)
def test_ttmc_ref_matches_dense_einsum(nnz, base, extra, mode, rank, seed):
    """Property (stub-compatible): the sparse gather/Kronecker/segment_sum
    TTMc oracle equals a dense np.einsum contraction on 3/4/5-mode tensors,
    for every output mode and rank combination drawn."""
    dims = base + extra
    st_t = synthetic_tensor(dims, nnz, seed=seed, skew=0.5)
    rng = np.random.default_rng(seed + 1)
    facs = [rng.standard_normal((s, rank)).astype(np.float32) for s in dims]
    out = ttmc_ref(
        jnp.asarray(st_t.indices),
        jnp.asarray(st_t.values),
        [jnp.asarray(f) for f in facs],
        mode,
        st_t.shape[mode],
    )
    ref = ttmc_ref_dense(st_t.indices, st_t.values, facs, mode, st_t.shape[mode])
    assert out.shape == (st_t.shape[mode], kron_cols([rank] * (len(dims) - 1)))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_ttmc_pallas_all_modes(tiny_tensor, mode):
    """The planned Pallas TTMc kernel (interpret mode) == the jnp oracle on
    every output mode of the shared BlockPlan layout."""
    facs = random_factors(jax.random.PRNGKey(0), tiny_tensor.shape, 4)
    out = tucker_auto(tiny_tensor, facs, mode, method="pallas", interpret=True)
    ref = tucker_auto(tiny_tensor, facs, mode, method="reference")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_ttmc_pallas_mixed_ranks(tiny_tensor):
    """Input factors with DIFFERENT ranks (the Tucker-specific case MTTKRP
    never exercises): per-factor lane padding + row-major Kronecker order."""
    rng = jax.random.PRNGKey(3)
    ranks = (3, 5, 2)
    facs = [
        jax.random.normal(k, (s, r))
        for k, s, r in zip(jax.random.split(rng, 3), tiny_tensor.shape, ranks)
    ]
    for mode in range(3):
        out = tucker_auto(tiny_tensor, facs, mode, method="pallas", interpret=True)
        ref = tucker_auto(tiny_tensor, facs, mode, method="reference")
        assert out.shape[1] == kron_cols([r for m, r in enumerate(ranks) if m != mode])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("fixture", ["tensor4d", "tensor5d"])
def test_ttmc_pallas_vs_plan_ref_higher_order(request, fixture):
    """N-mode TTMc kernel vs the layout-level oracle, including padded rows."""
    st_t = request.getfixturevalue(fixture)
    mode = 1
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=16, tile_j=16, tile_k=16),
        dma=DMAEngineConfig(blk=32),
    )
    op = make_planned_ttmc(st_t, mode, (3,) * st_t.nmodes, cfg=cfg, interpret=True)
    plan = op.plan
    facs = random_factors(jax.random.PRNGKey(6), st_t.shape, 3)
    pads = tuple(
        pad_factor(facs[m], rows, rank_padded(3))
        for m, rows in zip(plan.in_modes, plan.in_rows)
    )
    ref = ttmc_plan_ref(plan, pads, op.in_ranks)
    out = op.output(facs, st_t.shape[mode])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref)[: st_t.shape[mode]], rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# HOOI loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("source", ["tiny", "tensor4d", "tensor5d"])
def test_hooi_pallas_matches_reference(request, source):
    """Acceptance: tucker_hooi(method='pallas') — the PlannedTucker workspace
    on the TTM-chain kernel — and the pure-jnp reference drive matching fit
    histories on 3-, 4- and 5-mode tensors."""
    st_t = frostt_like("tiny") if source == "tiny" else request.getfixturevalue(source)
    ranks = (3,) * st_t.nmodes
    s_p = tucker_hooi(st_t, ranks, iters=3, method="pallas", seed=0)
    s_r = tucker_hooi(st_t, ranks, iters=3, method="reference", seed=0)
    np.testing.assert_allclose(s_p.fit_history, s_r.fit_history, atol=1e-4)
    assert s_p.core.shape == ranks


def test_hooi_jitted_sweep_matches_eager():
    """The jitted HOOI sweep (rank-padded, device-resident factors, one
    compiled function per iteration) reproduces the eager per-mode pallas
    dispatch loop."""
    st_t = frostt_like("tiny")
    s_jit = tucker_hooi(st_t, (4, 4, 4), iters=3, method="pallas", seed=0)
    s_eag = tucker_hooi(st_t, (4, 4, 4), iters=3, method="pallas", seed=0, jit_sweep=False)
    np.testing.assert_allclose(s_jit.fit_history, s_eag.fit_history, atol=1e-5)
    for fj, fe in zip(s_jit.factors, s_eag.factors):
        assert fj.shape == fe.shape  # sliced back to true (I_m, R_m)
        np.testing.assert_allclose(np.asarray(fj), np.asarray(fe), atol=1e-4)


def test_hooi_recovers_low_multilinear_rank():
    """Exact recovery: a full-support tensor with multilinear rank (2,3,2)
    is recovered to fit ~ 1 at the matching core ranks."""
    st_t = low_multilinear_rank_tensor()
    state = tucker_hooi(st_t, (2, 3, 2), iters=8, method="reference", seed=1)
    assert state.fit_history[-1] > 0.999, state.fit_history
    # HOOI can hit fit ~= 1 on the first sweep; later iterations may wobble
    # by float32 rounding, so only pin against a real regression.
    assert state.fit_history[-1] >= state.fit_history[0] - 1e-3


def test_hooi_factors_orthonormal_and_fit_formula(tiny_tensor):
    """HOOI invariants: factors keep orthonormal columns, and the core-based
    fit equals the explicit reconstruction residual on the non-zero support
    + implicit zeros (checked densely on the tiny shape)."""
    ranks = (4, 4, 4)
    state = tucker_hooi(tiny_tensor, ranks, iters=2, method="pallas", seed=0)
    for f in state.factors:
        np.testing.assert_allclose(
            np.asarray(f.T @ f), np.eye(f.shape[1]), atol=1e-4
        )
    dense = np.zeros(tiny_tensor.shape, np.float64)
    np.add.at(
        dense,
        tuple(tiny_tensor.indices[:, m] for m in range(3)),
        tiny_tensor.values.astype(np.float64),
    )
    us = [np.asarray(f, np.float64) for f in state.factors]
    recon = np.einsum("abc,ia,jb,kc->ijk", np.asarray(state.core, np.float64), *us)
    fit_dense = 1.0 - np.linalg.norm(dense - recon) / np.linalg.norm(dense)
    assert abs(fit_dense - state.fit_history[-1]) < 1e-3


def test_hooi_tol_early_exit():
    st_t = low_multilinear_rank_tensor(seed=3)
    state = tucker_hooi(st_t, (2, 3, 2), iters=40, tol=1e-6, method="reference", seed=1)
    assert len(state.fit_history) < 40
    assert state.fit_history[-1] > 0.99


def test_hooi_validates_core_ranks(tiny_tensor):
    with pytest.raises(ValueError, match="entries"):
        tucker_hooi(tiny_tensor, (4, 4), iters=1)
    with pytest.raises(ValueError, match="out of range"):
        tucker_hooi(tiny_tensor, (0, 4, 4), iters=1)
    with pytest.raises(ValueError, match="out of range"):
        tucker_hooi(tiny_tensor, (4, 4, 1000), iters=1)
    with pytest.raises(ValueError, match="full row rank"):
        # 9 > 2*2: the mode-0 unfolding of the core would be rank-deficient
        tucker_hooi(tiny_tensor, (9, 2, 2), iters=1)
    ws = make_planned_tucker(tiny_tensor, (4, 4, 4), interpret=True)
    with pytest.raises(ValueError, match="workspace"):
        tucker_hooi(tiny_tensor, (3, 3, 3), iters=1, method="pallas", planned=ws)
    with pytest.raises(ValueError, match="ignored"):
        tucker_hooi(tiny_tensor, (4, 4, 4), iters=1, method="reference", planned=ws)


# ---------------------------------------------------------------------------
# Plan amortization + shared kind-keyed plan cache
# ---------------------------------------------------------------------------


def test_planned_tucker_plans_built_once(monkeypatch):
    """Acceptance (plan amortization): plan_blocks runs exactly once per
    output mode across ALL HOOI iterations, and a prebuilt workspace skips
    planning entirely."""
    calls = []
    orig = ops_mod.plan_blocks

    def counting(*a, **k):
        calls.append(a)
        return orig(*a, **k)

    monkeypatch.setattr(ops_mod, "plan_blocks", counting)
    st_t = frostt_like("tiny")
    tucker_hooi(st_t, (4, 4, 4), iters=4, method="pallas", seed=0)
    assert len(calls) == st_t.nmodes

    planned = make_planned_tucker(st_t, (4, 4, 4), interpret=True)
    calls.clear()
    s = tucker_hooi(st_t, (4, 4, 4), iters=2, method="pallas", planned=planned, seed=0)
    assert calls == []
    assert len(s.fit_history) == 2


def test_planned_tucker_plan_bytes_and_padded_rows(tiny_tensor):
    ws = make_planned_tucker(tiny_tensor, (4, 4, 4), interpret=True)
    assert ws.plan_bytes() > 0
    prows = ws.padded_rows
    assert all(
        pr >= s and pr >= ws.ops[m].plan.out_rows
        for m, (pr, s) in enumerate(zip(prows, tiny_tensor.shape))
    )
    assert ws.rank_pads == (128, 128, 128)


def test_tucker_auto_cache_hits(tiny_tensor):
    """Acceptance: repeated tucker_auto calls are served from the shared plan
    cache (nonzero hits), tracked under the 'ttmc' kind."""
    plan_cache_clear()
    facs = random_factors(jax.random.PRNGKey(1), tiny_tensor.shape, 4)
    out1 = tucker_auto(tiny_tensor, facs, 0, method="pallas")
    out2 = tucker_auto(tiny_tensor, facs, 0, method="pallas")
    s = plan_cache_stats()
    assert s["by_kind"]["ttmc"] == {"hits": 1, "misses": 1}
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    tucker_auto(tiny_tensor, facs, 1, method="pallas")  # new mode -> miss
    assert plan_cache_stats()["by_kind"]["ttmc"] == {"hits": 1, "misses": 2}
    plan_cache_clear()


def test_plan_cache_no_cross_kind_collisions(tiny_tensor):
    """Acceptance (the latent collision the kind field fixes): MTTKRP and
    TTMc calls sharing tensor fingerprint + mode + an identical-looking rank
    key must never serve each other's plans."""
    plan_cache_clear()
    rank = 4
    facs = random_factors(jax.random.PRNGKey(1), tiny_tensor.shape, rank)
    mttkrp_auto(tiny_tensor, facs, 0, method="pallas")
    tucker_auto(tiny_tensor, facs, 0, method="pallas")
    s = plan_cache_stats()
    # both kinds missed: the second call did NOT hit the first kind's entry
    assert s["by_kind"]["mttkrp"] == {"hits": 0, "misses": 1}
    assert s["by_kind"]["ttmc"] == {"hits": 0, "misses": 1}
    assert s == {
        "hits": 0,
        "misses": 2,
        "evictions": 0,
        "size": 2,
        "maxsize": s["maxsize"],  # env-configurable (REPRO_PLAN_CACHE_MAX)
        "by_kind": {
            "mttkrp": {"hits": 0, "misses": 1},
            "ttmc": {"hits": 0, "misses": 1},
            "tt": {"hits": 0, "misses": 0},
        },
    }
    # and each kind still hits itself afterwards
    mttkrp_auto(tiny_tensor, facs, 0, method="pallas")
    tucker_auto(tiny_tensor, facs, 0, method="pallas")
    s = plan_cache_stats()
    assert s["by_kind"]["mttkrp"]["hits"] == 1
    assert s["by_kind"]["ttmc"]["hits"] == 1
    plan_cache_clear()


def test_plan_cache_tt_kind_isolated(tiny_tensor):
    """Regression: a 'tt' plan for the same (tensor, mode) never collides
    with the 'mttkrp' or 'ttmc' entries, and vice versa — the TT kernel
    instance carries interface-pair state the other kernels must never
    see."""
    from repro.tt import init_tt_cores, tt_auto

    plan_cache_clear()
    rank = 4
    facs = random_factors(jax.random.PRNGKey(1), tiny_tensor.shape, rank)
    cores = init_tt_cores(jax.random.PRNGKey(2), tiny_tensor.shape, (4, 4))
    mttkrp_auto(tiny_tensor, facs, 0, method="pallas")
    tucker_auto(tiny_tensor, facs, 0, method="pallas")
    tt_auto(tiny_tensor, cores, 0, method="pallas")
    s = plan_cache_stats()
    # three kinds, three misses: nobody served anybody else's plan
    assert s == {
        "hits": 0,
        "misses": 3,
        "evictions": 0,
        "size": 3,
        "maxsize": s["maxsize"],  # env-configurable (REPRO_PLAN_CACHE_MAX)
        "by_kind": {
            "mttkrp": {"hits": 0, "misses": 1},
            "ttmc": {"hits": 0, "misses": 1},
            "tt": {"hits": 0, "misses": 1},
        },
    }
    # tt hits itself afterwards, without disturbing the other kinds
    tt_auto(tiny_tensor, cores, 0, method="pallas")
    s = plan_cache_stats()
    assert s["by_kind"]["tt"] == {"hits": 1, "misses": 1}
    assert s["by_kind"]["mttkrp"] == {"hits": 0, "misses": 1}
    assert s["by_kind"]["ttmc"] == {"hits": 0, "misses": 1}
    plan_cache_clear()


def test_tucker_auto_rejects_unknown_method(tiny_tensor):
    facs = random_factors(jax.random.PRNGKey(0), tiny_tensor.shape, 4)
    with pytest.raises(ValueError, match="method"):
        tucker_auto(tiny_tensor, facs, 0, method="approach1")


def test_init_tucker_factors_orthonormal():
    facs = init_tucker_factors(jax.random.PRNGKey(5), (30, 20, 25), (4, 6, 5))
    for f, (s, r) in zip(facs, [(30, 4), (20, 6), (25, 5)]):
        assert f.shape == (s, r)
        np.testing.assert_allclose(np.asarray(f.T @ f), np.eye(r), atol=1e-5)
