"""MoE dispatch: the paper's Approach 1 (remap/counting-sort) vs Approach 2
(one-hot partial-sum) must agree exactly; drop behaviour must match too."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.moe import (
    capacity,
    dispatch_onehot,
    dispatch_remap,
    moe_apply,
    moe_init,
    router_topk,
)


def _cfg(dispatch="remap", cf=4.0, E=4, k=2):
    return MoEConfig(num_experts=E, top_k=k, d_ff=32, capacity_factor=cf, dispatch=dispatch)


def _run(dispatch, cf, seed=0, G=2, Tg=32, D=16):
    key = jax.random.PRNGKey(seed)
    cfg = _cfg(dispatch, cf)
    p = moe_init(key, D, cfg, "silu")
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (G, Tg, D)) * 0.5
    out, aux = moe_apply(p, x, cfg, "silu")
    return np.asarray(out), aux


@pytest.mark.parametrize("cf", [4.0, 1.0, 0.5])
def test_remap_equals_onehot(cf):
    """Identical outputs at any capacity factor — the stable sort and the
    cumsum priority assign identical slots, so drops match exactly."""
    o1, _ = _run("remap", cf)
    o2, _ = _run("onehot", cf)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-5)


def test_no_drops_at_full_capacity():
    """cf = num_experts makes capacity >= all assignments: every token's
    output is the weighted sum of its top-k expert outputs (nonzero)."""
    out, _ = _run("remap", 4.0)
    assert (np.abs(out).sum(-1) > 0).all()


def test_dispatch_remap_slots():
    """Counting-sort invariant: each kept assignment lands at a unique
    (expert, slot) with slot < capacity, FIFO within expert."""
    Tg, k, E, C = 16, 2, 4, 8
    ids = jax.random.randint(jax.random.PRNGKey(0), (Tg, k), 0, E)
    x = jnp.ones((Tg, 4))
    buffers, meta = dispatch_remap(x, ids, E, C)
    dest = np.asarray(meta["dest"])
    kept = dest[dest < E * C]
    assert len(np.unique(kept)) == len(kept)  # no slot collisions


def test_router_topk_normalized():
    key = jax.random.PRNGKey(0)
    cfg = _cfg()
    p = moe_init(key, 16, cfg, "silu")
    x = jax.random.normal(key, (3, 8, 16))
    ids, w, probs, aux = router_topk(p, x, cfg)
    assert ids.shape == (3, 8, 2) and w.shape == (3, 8, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux["load_balance"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz
    assert np.asarray(probs).min() >= 0


def test_capacity_padding():
    cfg = _cfg()
    assert capacity(1, cfg) == 8  # sublane-padded minimum
    assert capacity(64, cfg) % 8 == 0


def test_moe_backward_agrees():
    """Grad wrt params identical across dispatch modes (no-drop regime)."""
    key = jax.random.PRNGKey(1)
    D = 16
    x = jax.random.normal(key, (2, 16, D)) * 0.3

    def loss(p, dispatch):
        cfg = _cfg(dispatch, 4.0)
        out, _ = moe_apply(p, x, cfg, "silu")
        return jnp.sum(out**2)

    p = moe_init(key, D, _cfg(), "silu")
    g1 = jax.grad(lambda p: loss(p, "remap"))(p)
    g2 = jax.grad(lambda p: loss(p, "onehot"))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
