"""Pallas MTTKRP kernel: interpret-mode validation against the pure-jnp
oracles across shapes, dtypes, and memory-controller configurations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coo import SparseTensor, frostt_like, random_factors, synthetic_tensor
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.core.remap import plan_blocks
from repro.kernels.mttkrp_pallas import mttkrp_pallas_call, pad_factor, rank_padded
from repro.kernels.ops import (
    make_planned_mttkrp,
    mttkrp_auto,
    plan_cache_clear,
    plan_cache_stats,
)
from repro.kernels.ref import mttkrp_plan_ref, mttkrp_ref


def _totals(stats: dict) -> tuple[int, int]:
    """(hits, misses) totals of the kind-keyed plan-cache stats."""
    return stats["hits"], stats["misses"]


def _check(st_t, mode, rank, cfg=None, rtol=2e-4):
    facs = random_factors(jax.random.PRNGKey(0), st_t.shape, rank)
    out = mttkrp_auto(st_t, facs, mode, method="pallas", interpret=True, cfg=cfg)
    ref = mttkrp_ref(
        jnp.asarray(st_t.indices), jnp.asarray(st_t.values), facs, mode, st_t.shape[mode]
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=rtol, atol=rtol)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_kernel_all_modes(tiny_tensor, mode):
    _check(tiny_tensor, mode, 16)


@pytest.mark.parametrize("rank", [1, 8, 16, 32, 64, 128, 130])
def test_kernel_rank_sweep(tiny_tensor, rank):
    """Ranks across/past the 128-lane boundary (R_pad logic)."""
    _check(tiny_tensor, 0, rank)


@pytest.mark.parametrize(
    "tiles",
    [(8, 8, 8, 8), (16, 8, 32, 16), (64, 64, 64, 128), (128, 128, 128, 256)],
)
def test_kernel_controller_config_sweep(tiny_tensor, tiles):
    """The paper's programmable parameters (Sec. 5.2): every legal cache/DMA
    configuration computes the same MTTKRP."""
    ti, tj, tk, blk = tiles
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=ti, tile_j=tj, tile_k=tk),
        dma=DMAEngineConfig(blk=blk),
    )
    _check(tiny_tensor, 0, 16, cfg=cfg)


def test_kernel_bf16_inputs(tiny_tensor):
    facs = [f.astype(jnp.bfloat16) for f in random_factors(jax.random.PRNGKey(0), tiny_tensor.shape, 16)]
    op = make_planned_mttkrp(tiny_tensor, 0, 16, interpret=True)
    out = op.output(facs, tiny_tensor.shape[0])
    ref = mttkrp_ref(
        jnp.asarray(tiny_tensor.indices),
        jnp.asarray(tiny_tensor.values),
        [f.astype(jnp.float32) for f in facs],
        0,
        tiny_tensor.shape[0],
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=0.05, atol=0.05)


def test_kernel_vs_plan_ref(tiny_tensor):
    """Kernel output matches the layout-level oracle (block plan semantics),
    including padded rows."""
    plan = plan_blocks(tiny_tensor, 1, tile_i=32, tile_j=32, tile_k=32, blk=64)
    rank = 16
    rp = rank_padded(rank)
    facs = random_factors(jax.random.PRNGKey(4), tiny_tensor.shape, rank)
    pads = tuple(
        pad_factor(facs[m], rows, rp) for m, rows in zip(plan.in_modes, plan.in_rows)
    )
    ref = mttkrp_plan_ref(plan, pads, rp)
    nb = plan.nblocks
    out = mttkrp_pallas_call(
        jnp.asarray(plan.block_it),
        tuple(jnp.asarray(t) for t in plan.block_in),
        jnp.asarray(plan.vals).reshape(nb, plan.blk),
        jnp.asarray(plan.iloc).reshape(nb, plan.blk),
        tuple(jnp.asarray(l).reshape(nb, plan.blk) for l in plan.in_locs),
        pads,
        tile_i=plan.tile_i, in_tiles=plan.in_tiles,
        blk=plan.blk, out_rows=plan.out_rows, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("preset", ["4d_small", "5d_small"])
def test_kernel_higher_order_presets(preset):
    """Paper Table 2 has 3–5-mode tensors: the template-unrolled N-mode
    kernel must match the reference on the 4d/5d FROSTT-like presets for
    every output mode."""
    st_t = frostt_like(preset)
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=128, tile_j=128, tile_k=128),
        dma=DMAEngineConfig(blk=256),
    )
    for mode in range(st_t.nmodes):
        _check(st_t, mode, 8, cfg=cfg, rtol=5e-4)


@pytest.mark.parametrize("fixture", ["tensor4d", "tensor5d"])
@pytest.mark.parametrize("mode", [0, 1, 3])
def test_kernel_higher_order_vs_plan_ref(request, fixture, mode):
    """N-mode kernel vs the layout-level oracle, including padded rows."""
    st_t = request.getfixturevalue(fixture)
    plan = plan_blocks(st_t, mode, tile_i=16, tile_j=16, tile_k=16, blk=32)
    assert plan.n_in == st_t.nmodes - 1
    rank = 8
    rp = rank_padded(rank)
    facs = random_factors(jax.random.PRNGKey(6), st_t.shape, rank)
    pads = tuple(
        pad_factor(facs[m], rows, rp) for m, rows in zip(plan.in_modes, plan.in_rows)
    )
    ref = mttkrp_plan_ref(plan, pads, rp)
    op = make_planned_mttkrp(
        st_t, mode, rank,
        cfg=MemoryControllerConfig(
            cache=CacheEngineConfig(tile_i=16, tile_j=16, tile_k=16),
            dma=DMAEngineConfig(blk=32),
        ),
        interpret=True,
    )
    out = op.output(facs, st_t.shape[mode])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref)[: st_t.shape[mode], :rank], rtol=1e-4, atol=1e-4
    )


def test_mttkrp_auto_unsorted_stream_approach1(tiny_tensor):
    """Regression (PR 2): `mttkrp_auto` used to promise sorted_by_mode=True
    to XLA for the raw (unsorted) COO stream — `indices_are_sorted` is a
    correctness contract, not a hint.  The dispatcher must derive the flag
    from what the stream actually satisfies and still compute the exact
    MTTKRP on an unsorted stream."""
    rng = np.random.default_rng(11)
    perm = rng.permutation(tiny_tensor.nnz)
    shuffled = SparseTensor(
        tiny_tensor.indices[perm], tiny_tensor.values[perm], tiny_tensor.shape
    )
    assert not shuffled.is_sorted_by(0)
    facs = random_factors(jax.random.PRNGKey(8), shuffled.shape, 16)
    out = mttkrp_auto(shuffled, facs, 0, method="approach1")
    ref = mttkrp_ref(
        jnp.asarray(shuffled.indices), jnp.asarray(shuffled.values),
        facs, 0, shuffled.shape[0],
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
    # a sorted stream still takes the fast path
    srt = shuffled.sorted_by(0)
    out_s = mttkrp_auto(srt, facs, 0, method="approach1")
    ref_s = mttkrp_ref(
        jnp.asarray(srt.indices), jnp.asarray(srt.values), facs, 0, srt.shape[0]
    )
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(ref_s), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    nnz=st.integers(1, 300),
    dims=st.tuples(st.integers(4, 60), st.integers(4, 60), st.integers(4, 60)),
    mode=st.integers(0, 2),
    seed=st.integers(0, 99),
    blk=st.sampled_from([8, 32]),
)
def test_kernel_property_random_shapes(nnz, dims, mode, seed, blk):
    """Property: kernel == oracle for arbitrary tensors and DMA buffer sizes
    (tile/padding edge cases: tiny modes, empty tiles, one-element blocks)."""
    st_t = synthetic_tensor(dims, nnz, seed=seed, skew=0.6)
    cfg = MemoryControllerConfig(
        cache=CacheEngineConfig(tile_i=16, tile_j=16, tile_k=16),
        dma=DMAEngineConfig(blk=blk),
    )
    _check(st_t, mode, 8, cfg=cfg, rtol=5e-4)


def test_plan_cache_hits_and_counters(tiny_tensor):
    """mttkrp_auto(method='pallas') must not rebuild the BlockPlan on every
    call: same (tensor, mode, rank, cfg) -> cache hit; a different mode or
    config -> miss.  Counters feed bench_e2e."""
    import repro.kernels.ops as ops_mod

    plan_cache_clear()
    assert _totals(plan_cache_stats()) == (0, 0)
    calls = []
    orig = ops_mod.plan_blocks

    def counting(*a, **k):
        calls.append(a)
        return orig(*a, **k)

    facs = random_factors(jax.random.PRNGKey(0), tiny_tensor.shape, 8)
    try:
        ops_mod.plan_blocks = counting
        out1 = mttkrp_auto(tiny_tensor, facs, 0, method="pallas")
        out2 = mttkrp_auto(tiny_tensor, facs, 0, method="pallas")
        assert len(calls) == 1  # second call served from the plan cache
        assert _totals(plan_cache_stats()) == (1, 1)
        # mttkrp_auto's traffic is tracked under its own kernel kind
        assert plan_cache_stats()["by_kind"]["mttkrp"] == {"hits": 1, "misses": 1}
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        mttkrp_auto(tiny_tensor, facs, 1, method="pallas")  # new mode -> miss
        assert _totals(plan_cache_stats()) == (1, 2)
        cfg = MemoryControllerConfig(
            cache=CacheEngineConfig(tile_i=32, tile_j=32, tile_k=32),
            dma=DMAEngineConfig(blk=32),
        )
        mttkrp_auto(tiny_tensor, facs, 0, method="pallas", cfg=cfg)  # new cfg -> miss
        assert _totals(plan_cache_stats()) == (1, 3)
        assert len(calls) == 3
    finally:
        ops_mod.plan_blocks = orig
        plan_cache_clear()


def test_plan_cache_keys_on_content(tiny_tensor):
    """The cache key is a content fingerprint: a distinct SparseTensor object
    with identical contents hits; changing one value misses."""
    plan_cache_clear()
    facs = random_factors(jax.random.PRNGKey(1), tiny_tensor.shape, 8)
    mttkrp_auto(tiny_tensor, facs, 0, method="pallas")
    clone = SparseTensor(
        tiny_tensor.indices.copy(), tiny_tensor.values.copy(), tiny_tensor.shape
    )
    mttkrp_auto(clone, facs, 0, method="pallas")
    assert _totals(plan_cache_stats()) == (1, 1)
    bumped = SparseTensor(
        tiny_tensor.indices.copy(),
        np.concatenate([[np.float32(2.0) * tiny_tensor.values[0]], tiny_tensor.values[1:]]),
        tiny_tensor.shape,
    )
    mttkrp_auto(bumped, facs, 0, method="pallas")
    assert _totals(plan_cache_stats()) == (1, 2)
    plan_cache_clear()


def test_kernel_single_flush_traffic(tiny_tensor):
    """Approach-1 traffic property on the real layout: number of A-tile
    fills equals the number of occupied output tiles (each flushed once)."""
    plan = plan_blocks(tiny_tensor, 0, tile_i=16, tile_j=16, tile_k=16, blk=32)
    fills = plan.tile_fills()
    occupied = np.unique(tiny_tensor.indices[:, 0] // 16).size
    assert fills["A"] == occupied
    assert plan.a_tile_single_flush()
