"""The unified `decompose()` facade (repro/api.py): format dispatch is
bit-for-bit identical to the legacy per-format drivers, rank normalization
broadcasts per format, errors are caught at the facade, and the shared
`PlannedWorkspace.drive` pads each mode exactly ONCE per decomposition."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as hst

import repro.kernels.workspace as workspace_mod
from repro.api import decompose
from repro.core.coo import synthetic_tensor
from repro.core.cp_als import cp_als
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.tt import tt_als
from repro.tucker import tucker_hooi

SMALL_CFG = MemoryControllerConfig(
    cache=CacheEngineConfig(tile_i=16, tile_j=16, tile_k=16),
    dma=DMAEngineConfig(blk=32),
)


# ---------------------------------------------------------------------------
# facade == legacy drivers, bit for bit
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    nnz=hst.integers(1, 200),
    base=hst.tuples(hst.integers(4, 16), hst.integers(4, 16), hst.integers(4, 16)),
    extra=hst.sampled_from([(), (7,), (7, 6)]),
    rank=hst.integers(1, 4),
    seed=hst.integers(0, 99),
)
def test_decompose_matches_legacy_drivers(nnz, base, extra, rank, seed):
    """Property (stub-compatible): on 3/4/5-mode tensors, the facade's fit
    history equals the legacy `cp_als` / `tucker_hooi` / `tt_als` histories
    BIT FOR BIT — `decompose` holds no algorithm logic, it only normalizes
    the rank and dispatches."""
    dims = base + extra
    st_t = synthetic_tensor(dims, nnz, seed=seed, skew=0.5)
    # CP: 'approach1' is the eager compute-pattern baseline (CP's oracle role)
    a = decompose(st_t, rank, format="cp", method="approach1", iters=2, seed=seed)
    b = cp_als(st_t, rank, method="approach1", iters=2, seed=seed)
    assert a.fit_history == b.fit_history
    # Tucker: the pure-jnp reference
    tr = tuple(min(rank, 3) for _ in dims)
    a = decompose(st_t, tr, format="tucker", method="reference", iters=2, seed=seed)
    b = tucker_hooi(st_t, tr, method="reference", iters=2, seed=seed)
    assert a.fit_history == b.fit_history
    # TT: the pure-jnp reference, random init keyed by the same seed
    bond = (min(rank, 3),) * (len(dims) - 1)
    a = decompose(st_t, bond, format="tt", method="reference", iters=2,
                  seed=seed, init="random")
    b = tt_als(st_t, bond, method="reference", iters=2, seed=seed, init="random")
    assert a.fit_history == b.fit_history


def test_decompose_pallas_matches_legacy(tiny_tensor):
    """The planned-pallas path through the facade is the legacy planned path
    (same workspaces, same jitted sweeps), for all three formats."""
    a = decompose(tiny_tensor, 4, format="cp", iters=2, cfg=SMALL_CFG)
    b = cp_als(tiny_tensor, 4, method="pallas", iters=2, cfg=SMALL_CFG)
    assert a.fit_history == b.fit_history
    a = decompose(tiny_tensor, (3, 3, 3), format="tucker", iters=2, cfg=SMALL_CFG)
    b = tucker_hooi(tiny_tensor, (3, 3, 3), method="pallas", iters=2, cfg=SMALL_CFG)
    assert a.fit_history == b.fit_history
    a = decompose(tiny_tensor, (3, 3), format="tt", iters=2, cfg=SMALL_CFG,
                  init="random")
    b = tt_als(tiny_tensor, (3, 3), method="pallas", iters=2, cfg=SMALL_CFG,
               init="random")
    assert a.fit_history == b.fit_history


def test_decompose_rank_broadcast(tiny_tensor):
    """An int rank broadcasts per format: to all N modes for Tucker, to the
    N-1 interior bonds for TT."""
    a = decompose(tiny_tensor, 3, format="tucker", method="reference", iters=1)
    b = decompose(tiny_tensor, (3, 3, 3), format="tucker", method="reference", iters=1)
    assert a.fit_history == b.fit_history
    assert a.core.shape == (3, 3, 3)
    a = decompose(tiny_tensor, 3, format="tt", method="reference", iters=1,
                  init="random")
    assert a.tt_ranks == (3, 3)


def test_decompose_errors(tiny_tensor):
    with pytest.raises(ValueError, match="expected 'cp', 'tucker' or 'tt'"):
        decompose(tiny_tensor, 4, format="cpd")
    with pytest.raises(ValueError, match="single integer rank"):
        decompose(tiny_tensor, (4, 4, 4), format="cp")
    # format-specific validation still lives with the drivers
    with pytest.raises(ValueError, match="3 entries for a 3-mode tensor"):
        decompose(tiny_tensor, (4, 4, 4), format="tt")


# ---------------------------------------------------------------------------
# plan-amortization contract of the shared driver
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "format,rank",
    [("cp", 4), ("tucker", (3, 3, 3)), ("tt", (3, 3))],
)
def test_drive_pads_each_mode_exactly_once(tiny_tensor, monkeypatch, format, rank):
    """`PlannedWorkspace.drive` pads the factors ONCE for the whole
    decomposition — exactly one `pad_factor` call per mode through the
    shared driver, not nmodes x iters (the sweeps stay in padded space)."""
    calls = []
    real = workspace_mod.pad_factor

    def counting(f, rows, rp):
        calls.append((rows, rp))
        return real(f, rows, rp)

    monkeypatch.setattr(workspace_mod, "pad_factor", counting)
    kwargs = {"init": "random"} if format == "tt" else {}
    state = decompose(
        tiny_tensor, rank, format=format, iters=3, cfg=SMALL_CFG, **kwargs
    )
    assert len(state.fit_history) == 3
    assert len(calls) == tiny_tensor.nmodes
