"""Data pipeline: determinism, seekability, host sharding, prefetch."""
import numpy as np

from repro.data.pipeline import TokenPipeline, make_batch_iterator


def test_deterministic_and_seekable():
    p1 = TokenPipeline(vocab=1000, seq_len=32, global_batch=8, seed=3)
    p2 = TokenPipeline(vocab=1000, seq_len=32, global_batch=8, seed=3)
    b_a = p1.batch(17)
    b_b = p2.batch(17)  # fresh pipeline, direct seek
    np.testing.assert_array_equal(b_a["tokens"], b_b["tokens"])
    # different index -> different batch
    assert not np.array_equal(p1.batch(18)["tokens"], b_a["tokens"])


def test_labels_are_shifted_tokens():
    p = TokenPipeline(vocab=100, seq_len=16, global_batch=2, seed=0)
    b = p.batch(0)
    # labels[t] is the next token after tokens[t] in the underlying stream:
    # consecutive positions must chain (tokens[t+1] == labels[t])
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_slice_matches_global():
    p = TokenPipeline(vocab=500, seq_len=8, global_batch=8, seed=1)
    full = p.batch(3)
    lo = p.batch(3, host_slice=slice(0, 4))
    hi = p.batch(3, host_slice=slice(4, 8))
    np.testing.assert_array_equal(np.concatenate([lo["tokens"], hi["tokens"]]), full["tokens"])


def test_learnable_structure():
    """The Markov chain makes successors predictable: P(succ[t] | t) ~ 0.7."""
    p = TokenPipeline(vocab=200, seq_len=256, global_batch=4, seed=0, markov_order=0.7)
    b = p.batch(0)
    hits = (p._succ[b["tokens"]] == b["labels"]).mean()
    assert 0.6 < hits < 0.8, hits


def test_prefetch_iterator():
    p = TokenPipeline(vocab=100, seq_len=8, global_batch=2, seed=0)
    it = make_batch_iterator(p, start_index=5, depth=2)
    b0 = next(it)
    np.testing.assert_array_equal(b0["tokens"], p.batch(5)["tokens"])
    b1 = next(it)
    np.testing.assert_array_equal(b1["tokens"], p.batch(6)["tokens"])
    it.close()
