"""Mamba2 / SSD: the chunked matmul form must equal the sequential
recurrence for any shape, chunk size, and initial state."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import SSMConfig
from repro.models.ssm import (
    causal_conv1d,
    conv1d_decode_step,
    mamba_decode,
    mamba_init,
    mamba_init_cache,
    mamba_train,
    ssd_chunked,
    ssd_decode_step,
    ssd_reference,
)


def _ssd_inputs(key, B, S, H, P, G, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [1, 4, 16, 64])
def test_chunked_equals_reference(key, chunk):
    x, dt, A, Bm, Cm = _ssd_inputs(key, 2, 64, 4, 8, 2, 16)
    D = jnp.ones((4,))
    yr, hr = ssd_reference(x, dt, A, Bm, Cm, D)
    yc, hc = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yc), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hr), np.asarray(hc), rtol=1e-4, atol=1e-4)


def test_chunked_handles_ragged_tail(key):
    """S not a multiple of chunk: dt=0 padding leaves y and h unchanged."""
    x, dt, A, Bm, Cm = _ssd_inputs(key, 1, 23, 2, 4, 1, 8)
    yr, hr = ssd_reference(x, dt, A, Bm, Cm)
    yc, hc = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yc), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hr), np.asarray(hc), rtol=1e-4, atol=1e-4)


def test_initial_state_threading(key):
    """Splitting a sequence at any point and carrying h must equal one pass
    (the prefill-then-decode contract)."""
    x, dt, A, Bm, Cm = _ssd_inputs(key, 1, 32, 2, 4, 1, 8)
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    cut = 19
    y1, h1 = ssd_chunked(x[:, :cut], dt[:, :cut], A, Bm[:, :cut], Cm[:, :cut], chunk=8)
    y2, h2 = ssd_chunked(x[:, cut:], dt[:, cut:], A, Bm[:, cut:], Cm[:, cut:], h0=h1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=1e-4, atol=1e-4)


def test_decode_step_chain(key):
    """Token-by-token ssd_decode_step == full reference scan."""
    x, dt, A, Bm, Cm = _ssd_inputs(key, 2, 16, 2, 4, 1, 8)
    D = jnp.ones((2,))
    yr, hr = ssd_reference(x, dt, A, Bm, Cm, D)
    h = jnp.zeros((2, 2, 4, 8))
    ys = []
    for t in range(16):
        y, h = ssd_decode_step(h, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), rtol=1e-4, atol=1e-4)


def test_conv1d_decode_matches_train(key):
    B, S, C, K = 2, 12, 6, 4
    x = jax.random.normal(key, (B, S, C))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, C)) * 0.5
    b = jnp.zeros((C,))
    y_full, _ = causal_conv1d(x, w, b)
    state = jnp.zeros((B, K - 1, C))
    ys = []
    for t in range(S):
        y, state = conv1d_decode_step(x[:, t], w, b, state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)), np.asarray(y_full), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    S=st.integers(1, 48),
    chunk=st.sampled_from([1, 3, 8, 32]),
    H=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2]),
    seed=st.integers(0, 99),
)
def test_property_chunk_invariance(S, chunk, H, G, seed):
    if H % G:
        H = G
    key = jax.random.PRNGKey(seed)
    x, dt, A, Bm, Cm = _ssd_inputs(key, 1, S, H, 4, G, 4)
    yr, hr = ssd_reference(x, dt, A, Bm, Cm)
    yc, hc = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yc), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hr), np.asarray(hc), rtol=2e-3, atol=2e-3)


def test_mamba_block_roundtrip(key):
    cfg = type("C", (), {"ssm": SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=8, n_groups=1, chunk=8)})()
    d = 32
    p = mamba_init(key, d, cfg.ssm)
    x = jax.random.normal(key, (2, 16, d)) * 0.5
    full = mamba_train(p, x, cfg)
    cache = mamba_init_cache(2, d, cfg.ssm)
    outs = []
    for t in range(16):
        o, cache = mamba_decode(p, x[:, t : t + 1], cache, cfg)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), rtol=1e-4, atol=1e-4
    )
