"""Paper Table 1 / Sec. 3: Approach 1 vs Approach 2 vs the Pallas
memory-controller kernel.

Reports, per (tensor, mode):
  * the analytical external-traffic model (elements moved — Table 1),
  * measured XLA-CPU wall time for both pure-JAX lowerings (the *ordering*
    is what transfers: Approach 1's sorted segment-sum beats the scatter),
  * PMS-predicted TPU time for the Pallas layout.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coo import frostt_like, random_factors
from repro.core.hypergraph import approach1_traffic, approach2_traffic
from repro.core.mttkrp import mttkrp_approach1, mttkrp_approach2
from repro.core.pms import search
from repro.core.remap import remap_stable


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(rank: int = 16, preset: str = "small"):
    st = frostt_like(preset)
    facs = random_factors(jax.random.PRNGKey(0), st.shape, rank)
    rows = []
    for mode in range(st.nmodes):
        t1 = approach1_traffic(st, mode, rank)
        t2 = approach2_traffic(st, mode, rank)
        idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
        sidx, sval, _ = remap_stable(idx, val, mode)

        sec1 = _time(
            lambda i, v: mttkrp_approach1(i, v, facs, mode, st.shape[mode]), sidx, sval
        )
        sec2 = _time(
            lambda i, v: mttkrp_approach2(i, v, facs, mode, st.shape[mode]), idx, val
        )
        best = search(st, mode, rank, top_k=1)
        rows.append(
            dict(
                preset=preset,
                mode=mode,
                elems_a1=t1.total_elems,
                elems_a2=t2.total_elems,
                traffic_ratio=t2.total_elems / t1.total_elems,
                cpu_us_a1=sec1 * 1e6,
                cpu_us_a2=sec2 * 1e6,
                pms_tpu_us=best[0].t_total * 1e6 if best else float("nan"),
                pms_bottleneck=best[0].bottleneck if best else "-",
            )
        )
    return rows


def main():
    print("preset,mode,elems_a1,elems_a2,traffic_ratio,cpu_us_a1,cpu_us_a2,pms_tpu_us,bottleneck")
    for preset in ("tiny", "small", "medium"):
        for r in run(preset=preset):
            print(
                f"{r['preset']},{r['mode']},{r['elems_a1']},{r['elems_a2']},"
                f"{r['traffic_ratio']:.3f},{r['cpu_us_a1']:.0f},{r['cpu_us_a2']:.0f},"
                f"{r['pms_tpu_us']:.1f},{r['pms_bottleneck']}"
            )


if __name__ == "__main__":
    main()
