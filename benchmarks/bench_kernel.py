"""Pallas MTTKRP kernel layout quality: measured tile fills / padding /
single-flush property per memory-controller configuration, plus the PMS
三-term estimate.  (Wall-clock is meaningless in interpret mode; the layout
statistics ARE the kernel's performance on TPU — they count the HBM<->VMEM
DMAs the BlockSpec schedule will issue.)"""
from __future__ import annotations

from repro.core.coo import frostt_like
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.core.pms import predict_from_plan
from repro.core.remap import plan_blocks


def main():
    print("tensor,config,nblocks,padding,fills_A,fills_B,fills_C,single_flush,"
          "t_stream_us,t_factor_us,t_out_us,t_compute_us,bottleneck")
    for preset in ("small", "medium"):
        st = frostt_like(preset)
        for tiles in ((128, 128, 128, 128), (256, 256, 256, 256), (512, 512, 512, 512), (256, 512, 512, 128)):
            ti, tj, tk, blk = tiles
            cfg = MemoryControllerConfig(
                cache=CacheEngineConfig(tile_i=ti, tile_j=tj, tile_k=tk),
                dma=DMAEngineConfig(blk=blk),
            )
            plan = plan_blocks(st, 0, tile_i=ti, tile_j=tj, tile_k=tk, blk=blk)
            est = predict_from_plan(plan, 16, cfg)
            fills = plan.tile_fills()
            print(
                f"{preset},{ti}x{tj}x{tk}/{blk},{plan.nblocks},{plan.padding_fraction():.3f},"
                f"{fills['A']},{fills['B']},{fills['C']},{plan.a_tile_single_flush()},"
                f"{est.t_stream*1e6:.1f},{est.t_factor*1e6:.1f},{est.t_out*1e6:.1f},"
                f"{est.t_compute*1e6:.1f},{est.bottleneck}"
            )


if __name__ == "__main__":
    main()
