"""Pallas MTTKRP kernel layout quality: measured tile fills / padding /
single-flush property per memory-controller configuration, plus the PMS
three-term estimate.  (Wall-clock is meaningless in interpret mode; the layout
statistics ARE the kernel's performance on TPU — they count the HBM<->VMEM
DMAs the BlockSpec schedule will issue.)

`--fast` runs the CI smoke subset (small presets, two configurations).
"""
from __future__ import annotations

import argparse

from repro.core.coo import frostt_like
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.core.pms import predict_from_plan
from repro.core.remap import plan_blocks


def main(fast: bool = False):
    if fast:
        presets = ("small", "4d_small", "5d_small")
        configs = ((128, 128, 128, 128), (256, 256, 256, 256))
    else:
        presets = ("small", "medium", "4d_small", "5d_small")
        configs = (
            (128, 128, 128, 128),
            (256, 256, 256, 256),
            (512, 512, 512, 512),
            (256, 512, 512, 128),
        )
    print("tensor,nmodes,config,nblocks,padding,fills,single_flush,"
          "t_stream_us,t_factor_us,t_out_us,t_compute_us,bottleneck")
    for preset in presets:
        st = frostt_like(preset)
        for tiles in configs:
            ti, tj, tk, blk = tiles
            cfg = MemoryControllerConfig(
                cache=CacheEngineConfig(tile_i=ti, tile_j=tj, tile_k=tk),
                dma=DMAEngineConfig(blk=blk),
            )
            plan = plan_blocks(st, 0, tile_i=ti, tile_j=tj, tile_k=tk, blk=blk)
            est = predict_from_plan(plan, 16, cfg)
            fills = plan.tile_fills()
            fill_str = "/".join(f"{k}:{v}" for k, v in fills.items())
            print(
                f"{preset},{st.nmodes},{ti}x{tj}x{tk}/{blk},{plan.nblocks},"
                f"{plan.padding_fraction():.3f},{fill_str},{plan.a_tile_single_flush()},"
                f"{est.t_stream*1e6:.1f},{est.t_factor*1e6:.1f},{est.t_out*1e6:.1f},"
                f"{est.t_compute*1e6:.1f},{est.bottleneck}"
            )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke subset")
    main(fast=ap.parse_args().fast)
