"""Paper Sec. 3.1: the remap (Tensor Remapper) adds < 6% external traffic
for typical (N, R); measure the analytical ratio AND the on-device cost of
the remap relative to the mode's MTTKRP."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.coo import frostt_like, random_factors, synthetic_tensor
from repro.core.hypergraph import remap_overhead
from repro.core.mttkrp import mttkrp_approach1
from repro.core.remap import remap_radix, remap_stable


def _time(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main():
    print("tensor,n_modes,rank,traffic_overhead,remap_us,mttkrp_us,measured_frac,radix_us")
    for preset, nm in (("small", 3), ("4d_small", 4), ("5d_small", 5)):
        st = frostt_like(preset)
        for rank in (16, 64):
            ov = remap_overhead(st, 0, rank)
            idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
            facs = random_factors(jax.random.PRNGKey(0), st.shape, rank)
            t_remap = _time(lambda i, v: remap_stable(i, v, 1)[0], idx, val)
            t_radix = _time(
                lambda i, v: remap_radix(i, v, 1, st.shape[1], 1 << 10)[0], idx, val
            )
            sidx, sval, _ = remap_stable(idx, val, 0)
            t_mttkrp = _time(
                lambda i, v: mttkrp_approach1(i, v, facs, 0, st.shape[0]), sidx, sval
            )
            print(
                f"{preset},{nm},{rank},{ov:.4f},{t_remap*1e6:.0f},{t_mttkrp*1e6:.0f},"
                f"{t_remap/t_mttkrp:.3f},{t_radix*1e6:.0f}"
            )


if __name__ == "__main__":
    main()
