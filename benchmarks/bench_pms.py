"""Paper Sec. 5.2-5.3: the programmable-parameter design space.

(a) exhaustive module-by-module search per dataset (cache tiles x DMA blk)
    under the VMEM budget — the PMS picks different configurations for
    different tensor domains (the paper's core argument for programmability);
(b) PMS-model accuracy: predicted tile fills (analytic occupancy model) vs
    the exact fills measured from the built BlockPlan.
"""
from __future__ import annotations

import numpy as np

from repro.core.coo import frostt_like
from repro.core.hypergraph import stats
from repro.core.memctrl import CacheEngineConfig, DMAEngineConfig, MemoryControllerConfig
from repro.core.pms import predict_analytic, predict_from_plan, search
from repro.core.remap import plan_blocks


def main():
    print("== (a) per-domain optimal controller configuration ==")
    print("tensor,rank,tile_i,tile_j,tile_k,blk,pred_us,bottleneck,vmem_MiB")
    for preset in ("tiny", "small", "medium", "nell2_like"):
        st = frostt_like(preset)
        for rank in (16, 32):
            best = search(st, 0, rank, top_k=1)
            if not best:
                continue
            e = best[0]
            c, d = e.cfg.cache, e.cfg.dma
            print(
                f"{preset},{rank},{c.tile_i},{c.tile_j},{c.tile_k},{d.blk},"
                f"{e.t_total*1e6:.1f},{e.bottleneck},{e.vmem_bytes/2**20:.1f}"
            )

    print("\n== (b) PMS model vs measured layout (tile fills) ==")
    print("tensor,config,pred_blocks,exact_blocks,pred_us,exact_us,rel_err")
    st = frostt_like("small")
    hs = stats(st)
    for tiles in ((128, 128, 128, 128), (256, 256, 256, 256), (512, 512, 512, 512)):
        ti, tj, tk, blk = tiles
        cfg = MemoryControllerConfig(
            cache=CacheEngineConfig(tile_i=ti, tile_j=tj, tile_k=tk),
            dma=DMAEngineConfig(blk=blk),
        )
        plan = plan_blocks(st, 0, tile_i=ti, tile_j=tj, tile_k=tk, blk=blk)
        exact = predict_from_plan(plan, 16, cfg)
        approx = predict_analytic(hs, 0, 16, cfg)
        rel = abs(approx.t_total - exact.t_total) / exact.t_total
        print(
            f"small,{ti}x{tj}x{tk}/{blk},{approx.nblocks},{exact.nblocks},"
            f"{approx.t_total*1e6:.1f},{exact.t_total*1e6:.1f},{rel:.2f}"
        )


if __name__ == "__main__":
    main()
