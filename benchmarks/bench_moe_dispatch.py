"""The paper's technique on the LM side: MoE token->expert dispatch as
Approach 1 (remap/counting sort) vs Approach 2 (one-hot partial tensors).

Reports compiled HLO flops + bytes for each dispatch mode (XLA CPU numbers;
the *ratio* is the transferable quantity — the (Tg, E, C) one-hot dispatch
tensor is pure partial-sum traffic, exactly Table 1's |T|*R column), and
wall time on the host device.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.moe import moe_apply, moe_init


def measure(dispatch: str, G=4, Tg=1024, D=256, E=16, k=2):
    cfg = MoEConfig(num_experts=E, top_k=k, d_ff=512, capacity_factor=1.25, dispatch=dispatch)
    key = jax.random.PRNGKey(0)
    p = moe_init(key, D, cfg, "silu")
    x = jax.random.normal(key, (G, Tg, D), jnp.float32) * 0.3

    fn = jax.jit(lambda p, x: moe_apply(p, x, cfg, "silu")[0])
    lowered = fn.lower(p, x)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    out = fn(p, x)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        out = fn(p, x)
    out.block_until_ready()
    wall = (time.perf_counter() - t0) / 5
    return dict(
        dispatch=dispatch,
        flops=float(ca.get("flops", -1)),
        bytes=float(ca.get("bytes accessed", -1)),
        wall_us=wall * 1e6,
    )


def main():
    print("dispatch,flops,bytes,wall_us,notes")
    rows = [measure("remap"), measure("onehot")]
    for r in rows:
        print(f"{r['dispatch']},{r['flops']:.3e},{r['bytes']:.3e},{r['wall_us']:.0f},")
    if rows[0]["bytes"] > 0:
        print(f"# bytes ratio onehot/remap = {rows[1]['bytes']/rows[0]['bytes']:.2f} "
              f"(the paper's partial-sum traffic, Table 1)")
        print(f"# flops ratio onehot/remap = {rows[1]['flops']/rows[0]['flops']:.2f}")


if __name__ == "__main__":
    main()
