"""End-to-end fast-path benchmark: layout-generation cost (Tensor Remapper),
steady-state ALS iteration wall-clock, and the mttkrp_auto plan-cache — the
three quantities the paper (and GenTen / the authors' GPU follow-on) treat as
first-class measurements.  Writes the persistent trajectory file
`BENCH_kernel.json` at the repo root (schema: repro/bench.py) so every future
PR has a perf baseline to move.

Sections
  plan_build_*   `plan_blocks` (vectorized scatter build) vs
                 `plan_blocks_reference` (the per-group Python loop it
                 replaced), at two DMA block sizes.  blk=32 is the
                 many-small-groups regime where the interpreter loop dominates
                 (medium: ~200k groups); blk=256 also pays the padded-layout
                 materialization floor (99% padding on medium), which bounds
                 the achievable full-call speedup by memory bandwidth.
  als_iter_*     one full jitted ALS iteration (every mode's MTTKRP -> gram ->
                 solve -> normalize + on-device fit) for the planned Pallas
                 path (interpret mode on CPU) and the pure-JAX approaches.
  plan_cache     mttkrp_auto(method='pallas') keyed plan cache: first vs
                 cached call, hit/miss counters (mttkrp kind).
  tucker_*       the second workload on the same substrate: PlannedTucker
                 plan-build time, one jitted HOOI iteration (every mode's
                 TTMc -> Gram eigh -> factor update + core/fit), and the
                 tucker_auto side of the kind-keyed plan cache.
  tt_*           the third workload: PlannedTT plan-build time, one jitted
                 TT-ALS sweep (every mode's TT-core kernel -> kron(P,Q)
                 normal solve -> core update + fit), and the tt_auto side
                 of the kind-keyed plan cache.
  guard_overhead the resilience guards (repro.resilience) on the drive
                 loop: per-iteration wall-clock with guards off vs
                 GuardConfig(check_factors_every=1) — the fit-based
                 divergence tracker rides the existing host sync for free,
                 so the delta is one stacked isfinite reduction + sync per
                 iteration.  Acceptance: < 5% on als_iter_pallas.
  sharded_*      the distributed planned path (repro.dist.planned) on a
                 forced multi-device CPU host platform: workspace build
                 (per-mode partitions + shard-local layouts), one jitted
                 shard_map ALS sweep, and the partition balance.  Runs in a
                 subprocess because XLA_FLAGS=--xla_force_host_platform_
                 device_count must be set before jax initializes.
  pms_accuracy_* predicted-vs-achieved PMS accounting (repro.obs.calibrate):
                 each format's exact per-plan roofline prediction
                 (`pms_estimates` summed over modes) joined against the
                 measured steady-state sweep, reported as predicted_s /
                 measured_s / achieved_pct per (format, preset).  On CPU
                 interpret-mode Pallas achieved_pct is far below 100 (the
                 model describes TPU hardware); its trajectory across PRs is
                 the regression signal.  The medium preset pins a
                 big-input-tile config (PMS_MEDIUM_CFG) — the default
                 256-cube tiles put ~470k grid steps per sweep through the
                 interpreter, which is hours, while 4096-row input tiles
                 collapse that to a few thousand blocks.
  pms_calibration  default-spec vs measured-spec accounting (repro.tune):
                 a TPUSpec is fitted to this machine (microbenchmarks +
                 block-sweep least squares) and one measured CP sweep is
                 joined against the roofline prediction under both specs —
                 the measured spec's achieved_pct must land strictly closer
                 to 100% (docs/autotune.md).

  PYTHONPATH=src python benchmarks/bench_e2e.py [--fast] [--out PATH]

Non-clobber contract: the committed BENCH_kernel.json at the repo root is
the *full-run* baseline trajectory.  `--fast` (the CI smoke subset) and
`benchmarks/run.py --quick` must never overwrite it — `main` refuses the
baseline path in fast mode (see `_resolve_out`), instead of relying on the
caller picking a scratch path by convention.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench import result_record, write_report
from repro.core.coo import frostt_like, random_factors
from repro.core.cp_als import _sweep_streams
from repro.core.memctrl import CacheEngineConfig, MemoryControllerConfig
from repro.core.remap import plan_blocks, plan_blocks_reference
from repro.kernels import ops

ROOT = Path(__file__).resolve().parent.parent
BASELINE_PATH = ROOT / "BENCH_kernel.json"

# The medium-preset calibration config: interpret-mode wall clock tracks the
# grid-step count, and medium at the default 256-cube tiles is ~470k steps
# per sweep (hours on the CPU interpreter).  4096-row input tiles keep the
# same stream and collapse the block count to a few thousand.
PMS_MEDIUM_CFG = MemoryControllerConfig(
    cache=CacheEngineConfig(tile_i=256, tile_j=4096, tile_k=4096)
)


def _resolve_out(out: str | None, fast: bool) -> Path:
    """Enforce the non-clobber contract: fast/scratch runs may write anywhere
    EXCEPT the committed full-run baseline at the repo root."""
    path = Path(out) if out else BASELINE_PATH
    if fast and path.resolve() == BASELINE_PATH.resolve():
        raise SystemExit(
            f"refusing to overwrite the committed full-run baseline "
            f"{BASELINE_PATH} with a --fast subset: pass --out <scratch path> "
            f"(benchmarks/run.py --quick uses a tempdir), or run without "
            f"--fast to regenerate the baseline"
        )
    return path

# blk=256 is the kernel default; blk=32 is the layout-generation stress regime
# (groups on the scaled presets hold only a few non-zeros each, so the padded
# output stays small and the per-group loop is the whole cost).
PLAN_CONFIGS = (("blk256", 256), ("blk32", 32))


def _norm_x_sq(st) -> jax.Array:
    return jnp.asarray(float(np.sum(st.values.astype(np.float64) ** 2)), jnp.float32)


def bench_plan_build(presets, results, reps: int):
    print("== plan build: vectorized plan_blocks vs reference loop")
    for preset in presets:
        st = frostt_like(preset)
        for cname, blk in PLAN_CONFIGS:
            t_vec = min(
                _timed(lambda: plan_blocks(st, 0, blk=blk)) for _ in range(reps)
            )
            ref_reps = min(2, reps) if preset in ("medium", "large") else reps
            t_ref = min(
                _timed(lambda: plan_blocks_reference(st, 0, blk=blk))
                for _ in range(ref_reps)
            )
            speedup = t_ref / t_vec
            name = f"plan_build_{cname}"
            results += [
                result_record(name, preset, "reference_s", t_ref, "s"),
                result_record(name, preset, "vectorized_s", t_vec, "s"),
                result_record(name, preset, "speedup_x", speedup, "x"),
            ]
            print(f"  {preset:10s} {cname:7s} reference={t_ref:8.3f}s "
                  f"vectorized={t_vec:8.3f}s  speedup={speedup:6.1f}x")


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def bench_als_iter(presets, results, rank: int, reps: int):
    print("== steady-state ALS iteration (one jitted sweep, all modes + fit)")
    key = jax.random.PRNGKey(0)
    for preset in presets:
        st = frostt_like(preset)
        nxs = _norm_x_sq(st)

        # Planned Pallas path (interpret mode on CPU — the BlockSpec DMA
        # schedule is the TPU performance model; wall-clock here tracks the
        # grid-step count, not MXU throughput).
        ws = ops.make_planned_cp_als(st, rank, interpret=True)
        facs = ws.pad_factors(random_factors(key, st.shape, rank))
        idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
        facs, lam, fit = ws.sweep(facs, idx, val, nxs, first=True)
        facs, lam, fit = ws.sweep(facs, idx, val, nxs, first=False)  # compile steady state
        jax.block_until_ready(fit)
        t0 = time.perf_counter()
        for _ in range(reps):
            facs, lam, fit = ws.sweep(facs, idx, val, nxs, first=False)
        jax.block_until_ready(fit)
        t_pallas = (time.perf_counter() - t0) / reps
        results.append(result_record("als_iter_pallas", preset, "iter_s", t_pallas, "s"))
        print(f"  {preset:10s} pallas(interpret) iter={t_pallas:8.3f}s "
              f"(plans: {ws.plan_bytes()/2**20:.1f} MiB)")

        streams = [st.sorted_by(m) for m in range(st.nmodes)]
        sidx = tuple(jnp.asarray(s.indices) for s in streams)
        sval = tuple(jnp.asarray(s.values) for s in streams)
        for method in ("approach1", "approach2"):
            ft = tuple(random_factors(key, st.shape, rank))
            ft, lam, fit = _sweep_streams(
                ft, sidx, sval, nxs, shape=st.shape, method=method, first=True)
            ft, lam, fit = _sweep_streams(
                ft, sidx, sval, nxs, shape=st.shape, method=method, first=False)
            jax.block_until_ready(fit)
            t0 = time.perf_counter()
            for _ in range(reps):
                ft, lam, fit = _sweep_streams(
                    ft, sidx, sval, nxs, shape=st.shape, method=method, first=False)
            jax.block_until_ready(fit)
            t = (time.perf_counter() - t0) / reps
            results.append(result_record(f"als_iter_{method}", preset, "iter_s", t, "s"))
            print(f"  {preset:10s} {method:17s} iter={t:8.3f}s")


def bench_guard_overhead(results, preset: str, rank: int, iters: int):
    """Numerical guards on the steady-state drive loop (same sweep the
    als_iter_pallas section times, driven through `PlannedWorkspace.drive`):
    guards off vs the heaviest cadence (check_factors_every=1)."""
    print("== guard overhead (drive loop, guards off vs check_factors_every=1)")
    from repro.core.loop import GuardConfig

    st = frostt_like(preset)
    f0 = random_factors(jax.random.PRNGKey(0), st.shape, rank)
    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    nxs = _norm_x_sq(st)
    ws = ops.make_planned_cp_als(st, rank, interpret=True)
    gc = GuardConfig(policy="raise", check_factors_every=1)
    ws.drive(f0, (idx, val, nxs), iters=2)  # compile first + steady sweeps
    ws.drive(f0, (idx, val, nxs), iters=2, guards=gc)  # + the finite check
    t_off = min(
        _timed(lambda: ws.drive(f0, (idx, val, nxs), iters=iters))
        for _ in range(2)
    ) / iters
    t_on = min(
        _timed(lambda: ws.drive(f0, (idx, val, nxs), iters=iters, guards=gc))
        for _ in range(2)
    ) / iters
    frac = (t_on - t_off) / t_off
    results += [
        result_record("guard_overhead", preset, "iter_off_s", t_off, "s"),
        result_record("guard_overhead", preset, "iter_on_s", t_on, "s"),
        result_record("guard_overhead", preset, "overhead_frac", frac, "ratio"),
    ]
    print(f"  {preset:10s} off={t_off:.3f}s on={t_on:.3f}s "
          f"overhead={frac:+.1%}")


def bench_plan_cache(results, preset: str, rank: int):
    print("== mttkrp_auto plan cache (keyed on tensor fingerprint)")
    st = frostt_like(preset)
    facs = random_factors(jax.random.PRNGKey(0), st.shape, rank)
    ops.plan_cache_clear()
    t_first = _timed(lambda: jax.block_until_ready(ops.mttkrp_auto(st, facs, 0)))
    t_cached = min(
        _timed(lambda: jax.block_until_ready(ops.mttkrp_auto(st, facs, 0)))
        for _ in range(2)
    )
    stats = ops.plan_cache_stats()
    results += [
        result_record("plan_cache", preset, "first_call_s", t_first, "s"),
        result_record("plan_cache", preset, "cached_call_s", t_cached, "s"),
        result_record("plan_cache", preset, "hits", stats["hits"], "count"),
        result_record("plan_cache", preset, "misses", stats["misses"], "count"),
    ]
    print(f"  {preset:10s} first={t_first:.3f}s cached={t_cached:.3f}s "
          f"hits={stats['hits']} misses={stats['misses']}")


def bench_tucker(results, presets, core_rank: int, reps: int):
    """Sparse Tucker HOOI on the planned TTM-chain kernel: layout-build cost,
    steady-state jitted iteration, and the ttmc side of the plan cache."""
    print("== tucker: plan build / jitted HOOI iteration / tucker_auto cache")
    from repro.tucker import init_tucker_factors, make_planned_tucker

    key = jax.random.PRNGKey(0)
    for preset in presets:
        st = frostt_like(preset)
        ranks = (core_rank,) * st.nmodes
        nxs = _norm_x_sq(st)

        built = []
        t_plan = _timed(lambda: built.append(make_planned_tucker(st, ranks, interpret=True)))
        ws = built[0]
        facs = ws.pad_factors(init_tucker_factors(key, st.shape, ranks))
        facs, core, fit = ws.sweep(facs, nxs)
        facs, core, fit = ws.sweep(facs, nxs)  # compile + steady state
        jax.block_until_ready(fit)
        t0 = time.perf_counter()
        for _ in range(reps):
            facs, core, fit = ws.sweep(facs, nxs)
        jax.block_until_ready(fit)
        t_iter = (time.perf_counter() - t0) / reps
        results += [
            result_record("tucker_plan_build", preset, "plan_s", t_plan, "s"),
            result_record("tucker_hooi_iter", preset, "iter_s", t_iter, "s"),
        ]
        print(f"  {preset:10s} plan={t_plan:8.3f}s hooi(interpret) iter={t_iter:8.3f}s "
              f"(plans: {ws.plan_bytes()/2**20:.1f} MiB, core ranks {ranks})")

    # kind-keyed plan cache, ttmc side (mirrors bench_plan_cache)
    st = frostt_like("tiny")
    facs = random_factors(jax.random.PRNGKey(0), st.shape, core_rank)
    ops.plan_cache_clear()
    t_first = _timed(lambda: jax.block_until_ready(ops.tucker_auto(st, facs, 0)))
    t_cached = min(
        _timed(lambda: jax.block_until_ready(ops.tucker_auto(st, facs, 0)))
        for _ in range(2)
    )
    stats = ops.plan_cache_stats()["by_kind"]["ttmc"]
    results += [
        result_record("tucker_plan_cache", "tiny", "first_call_s", t_first, "s"),
        result_record("tucker_plan_cache", "tiny", "cached_call_s", t_cached, "s"),
        result_record("tucker_plan_cache", "tiny", "hits", stats["hits"], "count"),
        result_record("tucker_plan_cache", "tiny", "misses", stats["misses"], "count"),
    ]
    print(f"  tiny       first={t_first:.3f}s cached={t_cached:.3f}s "
          f"hits={stats['hits']} misses={stats['misses']} (ttmc kind)")


def bench_tt(results, presets, bond_rank: int, reps: int):
    """Tensor-train ALS on the planned TT-core kernel: layout-build cost,
    steady-state jitted sweep, and the tt side of the plan cache."""
    print("== tt: plan build / jitted TT-ALS sweep / tt_auto cache")
    from repro.tt import core_to_matrix, init_tt_cores, make_planned_tt

    key = jax.random.PRNGKey(0)
    for preset in presets:
        st = frostt_like(preset)
        tt_ranks = (bond_rank,) * (st.nmodes - 1)
        nxs = _norm_x_sq(st)

        built = []
        t_plan = _timed(lambda: built.append(make_planned_tt(st, tt_ranks, interpret=True)))
        ws = built[0]
        cores = init_tt_cores(key, st.shape, tt_ranks)
        facs = ws.pad_factors([core_to_matrix(c) for c in cores])
        idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
        facs, _, fit = ws.sweep(facs, idx, val, nxs)
        facs, _, fit = ws.sweep(facs, idx, val, nxs)  # compile + steady state
        jax.block_until_ready(fit)
        t0 = time.perf_counter()
        for _ in range(reps):
            facs, _, fit = ws.sweep(facs, idx, val, nxs)
        jax.block_until_ready(fit)
        t_iter = (time.perf_counter() - t0) / reps
        results += [
            result_record("tt_plan_build", preset, "plan_s", t_plan, "s"),
            result_record("tt_als_iter", preset, "iter_s", t_iter, "s"),
        ]
        print(f"  {preset:10s} plan={t_plan:8.3f}s tt-als(interpret) iter={t_iter:8.3f}s "
              f"(plans: {ws.plan_bytes()/2**20:.1f} MiB, bond ranks {tt_ranks})")

    # kind-keyed plan cache, tt side (mirrors bench_plan_cache)
    st = frostt_like("tiny")
    cores = init_tt_cores(jax.random.PRNGKey(0), st.shape, (bond_rank,) * (st.nmodes - 1))
    ops.plan_cache_clear()
    t_first = _timed(lambda: jax.block_until_ready(ops.tt_auto(st, cores, 0)))
    t_cached = min(
        _timed(lambda: jax.block_until_ready(ops.tt_auto(st, cores, 0)))
        for _ in range(2)
    )
    stats = ops.plan_cache_stats()["by_kind"]["tt"]
    results += [
        result_record("tt_plan_cache", "tiny", "first_call_s", t_first, "s"),
        result_record("tt_plan_cache", "tiny", "cached_call_s", t_cached, "s"),
        result_record("tt_plan_cache", "tiny", "hits", stats["hits"], "count"),
        result_record("tt_plan_cache", "tiny", "misses", stats["misses"], "count"),
    ]
    print(f"  tiny       first={t_first:.3f}s cached={t_cached:.3f}s "
          f"hits={stats['hits']} misses={stats['misses']} (tt kind)")


_SHARDED_BENCH_CODE = """
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.core.coo import frostt_like, random_factors
from repro.dist.sharding import stream_imbalance
from repro.kernels.ops import make_sharded_planned_cp_als

preset, rank, devices, reps = {preset!r}, {rank}, {devices}, {reps}
assert jax.device_count() == devices, jax.devices()
st = frostt_like(preset)
t0 = time.perf_counter()
ws = make_sharded_planned_cp_als(st, rank, devices=devices)
t_build = time.perf_counter() - t0
facs = ws.pad_factors(random_factors(jax.random.PRNGKey(0), st.shape, rank))
nxs = jnp.asarray(float(np.sum(st.values.astype(np.float64) ** 2)), jnp.float32)
facs, lam, fit = ws.sweep(facs, nxs, first=True)
facs, lam, fit = ws.sweep(facs, nxs, first=False)  # compile steady state
jax.block_until_ready(fit)
t0 = time.perf_counter()
for _ in range(reps):
    facs, lam, fit = ws.sweep(facs, nxs, first=False)
jax.block_until_ready(fit)
print("RESULT " + json.dumps({{
    "build_s": t_build,
    "iter_s": (time.perf_counter() - t0) / reps,
    "imbalance_x": stream_imbalance(ws.stacks[0].shard_nnz),
    "plan_mib": ws.plan_bytes() / 2**20,
}}))
"""


def _steady_sweep_s(step, reps: int) -> float:
    """Steady-state seconds per sweep: two throwaway calls (compile + warm),
    then the mean of `reps` timed calls."""
    jax.block_until_ready(step())
    jax.block_until_ready(step())
    t0 = time.perf_counter()
    for _ in range(reps):
        fit = step()
    jax.block_until_ready(fit)
    return (time.perf_counter() - t0) / reps


def bench_pms_accuracy(results, presets, rank: int, core_rank: int,
                       bond_rank: int, reps: int):
    """Predicted-vs-achieved PMS accounting (repro.obs.calibrate): every
    format's exact per-plan prediction joined against its measured
    steady-state sweep on the same built workspace."""
    print("== pms accuracy: exact roofline prediction vs measured sweep")
    from repro.obs.calibrate import accuracy_records, calibration_row
    from repro.tt import core_to_matrix, init_tt_cores, make_planned_tt
    from repro.tucker import init_tucker_factors, make_planned_tucker

    key = jax.random.PRNGKey(0)
    rows = []
    for preset in presets:
        st = frostt_like(preset)
        nxs = _norm_x_sq(st)
        idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
        cfg = PMS_MEDIUM_CFG if preset == "medium" else None
        local_reps = 1 if preset == "medium" else reps

        ws = ops.make_planned_cp_als(st, rank, cfg=cfg, interpret=True)
        state = {"f": ws.pad_factors(random_factors(key, st.shape, rank))}

        def step_cp():
            state["f"], _, fit = ws.sweep(state["f"], idx, val, nxs, first=False)
            return fit

        rows.append(calibration_row(
            ws, _steady_sweep_s(step_cp, local_reps),
            format="cp", preset=preset,
        ))

        ranks = (core_rank,) * st.nmodes
        ws = make_planned_tucker(st, ranks, cfg=cfg, interpret=True)
        state = {"f": ws.pad_factors(init_tucker_factors(key, st.shape, ranks))}

        def step_tk():
            state["f"], _, fit = ws.sweep(state["f"], nxs)
            return fit

        rows.append(calibration_row(
            ws, _steady_sweep_s(step_tk, local_reps),
            format="tucker", preset=preset,
        ))

        tt_ranks = (bond_rank,) * (st.nmodes - 1)
        ws = make_planned_tt(st, tt_ranks, cfg=cfg, interpret=True)
        cores = init_tt_cores(key, st.shape, tt_ranks)
        state = {"f": ws.pad_factors([core_to_matrix(c) for c in cores])}

        def step_tt():
            state["f"], _, fit = ws.sweep(state["f"], idx, val, nxs)
            return fit

        rows.append(calibration_row(
            ws, _steady_sweep_s(step_tt, local_reps),
            format="tt", preset=preset,
        ))

    results += accuracy_records(rows)
    for r in rows:
        print(f"  {r.preset:10s} {r.format:7s} predicted={r.predicted_s:.3e}s "
              f"measured={r.measured_s:8.3f}s achieved={r.achieved_pct:.4f}%")


def bench_pms_calibration(results, preset: str, rank: int, reps: int):
    """Default-spec vs measured-spec PMS accounting (repro.tune): fit a
    TPUSpec to this machine (microbenchmarks + block-sweep least squares),
    then join ONE measured CP sweep on `preset` against the roofline
    prediction under both specs.  Acceptance (ISSUE 10): the measured spec's
    achieved_pct is strictly closer to 100% than the default's — the
    datasheet constants describe TPU silicon, not the backend that actually
    ran."""
    print("== pms calibration: default vs measured TPUSpec achieved_pct")
    from repro.obs.calibrate import calibration_row
    from repro.tune import calibrate

    cal = calibrate(preset="tiny", reps=reps)
    st = frostt_like(preset)
    nxs = _norm_x_sq(st)
    idx, val = jnp.asarray(st.indices), jnp.asarray(st.values)
    ws = ops.make_planned_cp_als(st, rank, interpret=True)
    state = {"f": ws.pad_factors(random_factors(jax.random.PRNGKey(0), st.shape, rank))}

    def step():
        state["f"], _, fit = ws.sweep(state["f"], idx, val, nxs, first=False)
        return fit

    measured_s = _steady_sweep_s(step, reps)
    default = calibration_row(ws, measured_s, format="cp", preset=preset)
    measured = calibration_row(
        ws, measured_s, format="cp", preset=preset, spec=cal.spec
    )
    results += [
        result_record("pms_calibration", preset, "measured_sweep_s", measured_s, "s"),
        result_record("pms_calibration", preset, "achieved_pct_default",
                      default.achieved_pct, "%"),
        result_record("pms_calibration", preset, "achieved_pct_measured",
                      measured.achieved_pct, "%"),
        result_record("pms_calibration", preset, "hbm_bw_fitted",
                      cal.spec.hbm_bw, "B/s"),
        result_record("pms_calibration", preset, "peak_flops_f32_fitted",
                      cal.spec.peak_flops_f32, "flop/s"),
    ]
    closer = abs(measured.achieved_pct - 100) < abs(default.achieved_pct - 100)
    print(f"  {preset:10s} sweep={measured_s:8.3f}s "
          f"achieved: default={default.achieved_pct:.4f}% "
          f"measured={measured.achieved_pct:.1f}% "
          f"({'measured closer to 100%' if closer else 'NOT closer — check fit'})")


def bench_sharded(results, presets, rank: int, devices: int, reps: int):
    """Distributed planned CP-ALS on a forced multi-device host platform:
    subprocess-spawned (the device count locks at first jax init), reporting
    workspace build, steady-state shard_map sweep, and partition balance."""
    print(f"== sharded planned path ({devices} forced host devices, subprocess)")
    for preset in presets:
        code = _SHARDED_BENCH_CODE.format(
            preset=preset, rank=rank, devices=devices, reps=reps
        )
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = str(ROOT / "src")
        out = subprocess.run(
            [sys.executable, "-c", code], env=env, capture_output=True,
            text=True, timeout=900, cwd=ROOT,
        )
        if out.returncode != 0:
            raise RuntimeError(
                f"sharded bench subprocess failed:\n{out.stdout}\n{out.stderr[-3000:]}"
            )
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
        r = json.loads(line[len("RESULT "):])
        results += [
            result_record("sharded_plan_build", preset, "build_s", r["build_s"], "s"),
            result_record("sharded_als_iter", preset, "iter_s", r["iter_s"], "s"),
            result_record("sharded_als_iter", preset, "devices", devices, "count"),
            result_record("sharded_partition", preset, "imbalance_x", r["imbalance_x"], "x"),
        ]
        print(f"  {preset:10s} build={r['build_s']:7.3f}s sweep={r['iter_s']:7.3f}s "
              f"imbalance={r['imbalance_x']:.2f}x plans={r['plan_mib']:.1f} MiB "
              f"({devices} devices)")


def main(fast: bool = False, out: str | None = None) -> dict:
    path = _resolve_out(out, fast)
    plan_presets = ("small", "4d_small", "5d_small") if fast else (
        "small", "medium", "4d_small", "5d_small")
    als_presets = ("small", "4d_small", "5d_small")
    tucker_presets = ("tiny",) if fast else ("small", "4d_small")
    sharded_presets = ("tiny",) if fast else ("tiny", "small")
    reps = 1 if fast else 3
    rank = 16

    results: list[dict] = []
    t0 = time.time()
    bench_plan_build(plan_presets, results, reps=max(2, reps))
    bench_als_iter(als_presets, results, rank=rank, reps=reps)
    bench_plan_cache(results, preset="tiny", rank=rank)
    bench_guard_overhead(results, preset="small", rank=rank,
                         iters=3 if fast else 6)
    bench_tucker(results, tucker_presets, core_rank=4, reps=reps)
    bench_tt(results, tucker_presets, bond_rank=4, reps=reps)
    pms_presets = ("tiny",) if fast else ("small", "medium")
    bench_pms_accuracy(results, pms_presets, rank=rank, core_rank=4,
                       bond_rank=4, reps=reps)
    bench_pms_calibration(results, preset="tiny" if fast else "small",
                          rank=rank, reps=reps)
    bench_sharded(results, sharded_presets, rank=rank, devices=2, reps=reps)

    report = write_report(path, results)
    print(f"[bench_e2e] {len(results)} results -> {path} "
          f"(commit {report['commit'][:12]}, {time.time()-t0:.1f}s total)")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke subset")
    ap.add_argument("--out", default=None, help="output path (default: repo-root BENCH_kernel.json)")
    a = ap.parse_args()
    main(fast=a.fast, out=a.out)
