"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Methodology
-----------
XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip count
(verified empirically), so the scanned full-step module undercounts.  The
dry-run therefore lowers two UNROLLED cost probes per cell — depth = 1x and
2x the layer-pattern period, scan_unroll=True, num_microbatches=1 — giving
exact per-device costs c(1), c(2).  Linear extrapolation:

    per_period = c(2) - c(1);   base = c(1) - per_period
    full(depth n_reps) = base + n_reps * per_period

(`base` captures embedding + head + optimizer-free overhead; the optimizer
and grad pieces scale with depth and live inside per_period.)  Microbatching
does not change FLOPs; it re-reads the accumulator, which we fold into the
memory term as (mb-1) * accum_bytes.

Terms (per device == per chip; the partitioned module is per-device):
    compute    = flops / PEAK_FLOPS
    memory     = bytes_accessed / HBM_BW
    collective = sum over collective ops of wire bytes / ICI_BW, where wire
                 bytes uses ring factors: all-reduce 2(n-1)/n, all-gather /
                 reduce-scatter (n-1)/n, all-to-all (n-1)/n, permute 1.
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI (the
brief's constants; single-link conservative).
"""
from __future__ import annotations

import glob
import json
import math
import os

from repro.core.memctrl import TPUSpec

# Hardware constants sourced from the one authoritative definition
# (memctrl.TPUSpec) — tests/test_tune.py pins them in sync so this module
# can never drift from what the PMS prices against again.
_SPEC = TPUSpec()
PEAK_FLOPS = _SPEC.peak_flops
HBM_BW = _SPEC.hbm_bw
ICI_BW = _SPEC.ici_bw_per_link
HBM_BYTES = _SPEC.hbm_bytes

RING = {
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}

# dominant mesh-axis size for ring factors (16 on both meshes here)
AXIS_N = 16


def wire_bytes(collectives: dict) -> float:
    total = 0.0
    for kind, d in collectives.items():
        total += d["bytes"] * RING.get(kind, lambda n: 1.0)(AXIS_N)
    return total


def model_flops_train(cfg, tokens: int) -> float:
    return 6.0 * cfg.active_param_count() * tokens


def model_flops_decode(cfg, batch: int) -> float:
    return 2.0 * cfg.active_param_count() * batch  # one new token per seq


def extrapolate(rec: dict, n_reps: int) -> dict | None:
    """Exact full-depth per-device costs from the two unrolled probes."""
    p = rec.get("probes")
    if not p or "depth1" not in p or "depth2" not in p:
        return None
    c1, c2 = p["depth1"], p["depth2"]

    def full(key):
        per = c2[key] - c1[key]
        base = c1[key] - per
        return base + n_reps * per

    coll1 = wire_bytes(c1.get("collectives", {}))
    coll2 = wire_bytes(c2.get("collectives", {}))
    coll_full = (c1 and (coll1 - (coll2 - coll1))) + n_reps * (coll2 - coll1)
    out = {
        "flops": full("flops"),
        "bytes": full("bytes_accessed"),
        "coll_bytes": max(coll_full, 0.0),
    }
    # microbatched accumulation re-reads/writes the grad buffer per microbatch
    mb = rec.get("num_microbatches") or 1
    if mb > 1 and rec.get("memory"):
        accum = rec["memory"]["argument_bytes"] * 0.25  # ~ grad-tree bytes
        out["bytes"] += (mb - 1) * accum
    return out


def analyze_cell(rec: dict, cfg) -> dict | None:
    n_reps = cfg.n_layers // cfg.period
    ext = extrapolate(rec, n_reps)
    if ext is None:
        return None
    t_compute = ext["flops"] / PEAK_FLOPS
    t_memory = ext["bytes"] / HBM_BW
    t_coll = ext["coll_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    shape = rec["shape"]
    nchips = rec["nchips"]
    if shape.startswith("train"):
        from repro.configs.base import SHAPES

        sc = SHAPES[shape]
        mf = model_flops_train(cfg, sc.seq_len * sc.global_batch) / nchips
    else:
        from repro.configs.base import SHAPES

        sc = SHAPES[shape]
        if sc.kind == "prefill":
            mf = 2.0 * cfg.active_param_count() * sc.seq_len * sc.global_batch / nchips
        else:
            mf = model_flops_decode(cfg, sc.global_batch) / nchips
    t_total = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": shape,
        "mesh": rec["mesh"],
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": ext["flops"],
        "useful_flop_ratio": mf / ext["flops"] if ext["flops"] > 0 else float("nan"),
        "roofline_fraction": (mf / PEAK_FLOPS) / t_total if t_total > 0 else float("nan"),
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30 if rec.get("memory") else None,
        "fits_hbm": rec["memory"]["peak_bytes"] <= HBM_BYTES if rec.get("memory") else None,
    }


def load_artifacts(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(art_dir: str = "artifacts/dryrun", mesh: str = "single") -> list[dict]:
    from repro.configs import get_config

    rows = []
    for rec in load_artifacts(art_dir):
        if rec.get("skipped") or rec.get("mesh") != mesh:
            continue
        cfg = get_config(rec["arch"])
        row = analyze_cell(rec, cfg)
        if row:
            rows.append(row)
    return rows


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck | "
           "useful/HLO | roofline frac | peak GiB |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['bottleneck']} | {r['useful_flop_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2%} | {r['peak_gib']:.2f} |"
        )
    return "\n".join(out)


def main():
    rows = table()
    if not rows:
        print("[roofline] no probe artifacts found — run "
              "`python -m repro.launch.dryrun --matrix --probe` first")
        return
    print(render_markdown(rows))
    with open("artifacts/roofline.json", "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
