"""Benchmark aggregator: one section per paper table/figure + the LM-side
dispatch experiment and (if dry-run artifacts exist) the roofline table.

  PYTHONPATH=src python -m benchmarks.run [--quick]

--quick runs only the kernel-side sections (traffic models, remapper, PMS,
kernel layout, and the end-to-end fast path covering BOTH decompositions —
CP-ALS and Tucker HOOI), skipping the LM-side extras.

Non-clobber contract: the end-to-end section always writes to a tempdir
scratch path, so neither mode can overwrite the committed full-run baseline
`BENCH_kernel.json` at the repo root.  This is *enforced*, not conventional:
`bench_e2e._resolve_out` refuses the baseline path for any fast/subset run
(regenerate the baseline with a full `PYTHONPATH=src python
benchmarks/bench_e2e.py`).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path


def _section(title: str):
    print(f"\n{'='*72}\n== {title}\n{'='*72}")


def main(quick: bool = False) -> None:
    t0 = time.time()

    _section("Table 1 / Sec.3 — Approach 1 vs Approach 2 (traffic + time)")
    from . import bench_approaches
    bench_approaches.main()

    _section("Sec 3.1 — Tensor Remapper overhead (<6% claim)")
    from . import bench_remap
    bench_remap.main()

    _section("Sec 5.2/5.3 — PMS design-space search + model accuracy")
    from . import bench_pms
    bench_pms.main()

    _section("Kernel memory-layout quality (BlockSpec DMA schedule)")
    from . import bench_kernel
    bench_kernel.main()

    _section("End-to-end fast path (plan build / jitted CP-ALS iter / "
             "Tucker HOOI iter / plan caches)")
    import tempfile
    from . import bench_e2e
    # Scratch path (bench_e2e additionally *refuses* the committed baseline
    # path in fast mode — see its _resolve_out guard).
    with tempfile.TemporaryDirectory() as td:
        out = f"{td}/BENCH_kernel.json"
        assert Path(out).resolve() != bench_e2e.BASELINE_PATH.resolve()
        bench_e2e.main(fast=True, out=out)

    if not quick:
        _section("MoE dispatch: the paper's approaches on the LM side")
        from . import bench_moe_dispatch
        bench_moe_dispatch.main()

        _section("Roofline (from dry-run artifacts, if present)")
        from . import roofline
        roofline.main()

    print(f"\n[benchmarks] total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="kernel-side sections only (both decompositions)")
    main(quick=ap.parse_args().quick)
