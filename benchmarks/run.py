"""Benchmark aggregator: one section per paper table/figure + the LM-side
dispatch experiment and (if dry-run artifacts exist) the roofline table.

  PYTHONPATH=src python -m benchmarks.run [--quick]

--quick runs only the kernel-side sections (traffic models, remapper, PMS,
kernel layout, and the end-to-end fast path covering BOTH decompositions —
CP-ALS and Tucker HOOI), skipping the LM-side extras.  The end-to-end
section always writes to a scratch path so neither mode clobbers the
committed full-run baseline JSON at the repo root.
"""
from __future__ import annotations

import argparse
import time


def _section(title: str):
    print(f"\n{'='*72}\n== {title}\n{'='*72}")


def main(quick: bool = False) -> None:
    t0 = time.time()

    _section("Table 1 / Sec.3 — Approach 1 vs Approach 2 (traffic + time)")
    from . import bench_approaches
    bench_approaches.main()

    _section("Sec 3.1 — Tensor Remapper overhead (<6% claim)")
    from . import bench_remap
    bench_remap.main()

    _section("Sec 5.2/5.3 — PMS design-space search + model accuracy")
    from . import bench_pms
    bench_pms.main()

    _section("Kernel memory-layout quality (BlockSpec DMA schedule)")
    from . import bench_kernel
    bench_kernel.main()

    _section("End-to-end fast path (plan build / jitted CP-ALS iter / "
             "Tucker HOOI iter / plan caches)")
    import tempfile
    from . import bench_e2e
    # Write to a scratch path: the fast-mode subset must not clobber the
    # committed full-run baseline at the repo root.
    with tempfile.TemporaryDirectory() as td:
        bench_e2e.main(fast=True, out=f"{td}/BENCH_kernel.json")

    if not quick:
        _section("MoE dispatch: the paper's approaches on the LM side")
        from . import bench_moe_dispatch
        bench_moe_dispatch.main()

        _section("Roofline (from dry-run artifacts, if present)")
        from . import roofline
        roofline.main()

    print(f"\n[benchmarks] total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="kernel-side sections only (both decompositions)")
    main(quick=ap.parse_args().quick)
